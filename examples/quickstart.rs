//! Quickstart: the SLiM pipeline on a single layer, via the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks paper Fig. 1 end to end on one weight matrix: SLiM-Quant (Alg. 1)
//! → Wanda 2:4 pruning → SLiM-LoRA (Alg. 2), printing the error budget at
//! each stage, then compares against Naive-LoRA and no-adapters.

use slim::compress::{compress_layer, CompressConfig, LayerCalib};
use slim::lowrank::LoraMethod;
use slim::quant::QuantMethod;
use slim::rng::Pcg32;
use slim::sparse::{PruneMethod, SparsityPattern};
use slim::tensor::Matrix;

fn main() {
    let mut rng = Pcg32::seeded(42);
    // A realistic layer: Laplace-ish weights, a few hot input channels.
    let (d_in, d_out) = (512, 384);
    let w = Matrix::from_fn(d_in, d_out, |_, _| rng.laplace(0.03));
    let mut acts = Matrix::randn(256, d_in, 1.0, &mut rng);
    for i in 0..acts.rows() {
        for j in 0..16 {
            let v = acts.get(i, j) * 7.0;
            acts.set(i, j, v);
        }
    }
    let calib = LayerCalib::from_activations(acts.clone());

    println!("SLiM quickstart — one {d_in}x{d_out} layer, 4-bit + 2:4 + rank-10% adapters\n");
    let base = CompressConfig {
        quant: QuantMethod::SlimQuantW,
        bits: 4,
        prune: PruneMethod::Wanda,
        pattern: Some(SparsityPattern::TWO_FOUR),
        lora: LoraMethod::Slim,
        rank_ratio: 0.1,
        quantize_adapters: false,
    };

    for (label, lora) in [
        ("no adapters        ", LoraMethod::None),
        ("Naive-LoRA         ", LoraMethod::Naive),
        ("SLiM-LoRA (paper)  ", LoraMethod::Slim),
    ] {
        let cfg = CompressConfig { lora, ..base };
        let out = compress_layer(&w, &calib, &cfg);
        // Output error ‖X(W_eff − W)‖ — what OBS-style compression minimizes.
        let out_err = acts.matmul(&out.effective().sub(&w)).fro_norm();
        println!(
            "{label} E_Q={:8.4}  E_S={:8.4}  ‖W-Ŵ‖²={:8.4}  ‖X(W-Ŵ)‖={:8.3}",
            out.e_quant, out.e_sparse, out.e_final, out_err
        );
    }

    let out = compress_layer(&w, &calib, &base);
    println!(
        "\nmask is exact 2:4: {} | base sparsity: {:.1}% | adapter rank: {}",
        out.mask.satisfies_nofm(2, 4),
        out.wc.sparsity() * 100.0,
        out.rank()
    );
    println!("→ SLiM-LoRA should show the lowest saliency/output error of the three.");
}
