//! Observability example: serve a burst through a speculative route, then
//! scrape all three export surfaces — structured JSON metrics, Prometheus
//! text, and a Perfetto-loadable Chrome trace.
//!
//! ```bash
//! cargo run --release --example observability
//! ```
//!
//! Registers a speculative + chunked-prefill route (the busiest lifecycle:
//! enqueue → admit → prefill chunks → draft/verify ticks → retire), fires
//! concurrent clients over the TCP front-end, then:
//!
//! * `{"cmd":"metrics"}` — per-route structured metrics (counters,
//!   per-stage busy seconds, histogram percentiles) + the legacy one-line
//!   summary;
//! * `{"cmd":"metrics_prom"}` — the same registry as Prometheus text
//!   exposition;
//! * `{"cmd":"trace"}` — the flight recorder's lifecycle ring as Chrome
//!   trace-event JSON, written to `trace.json` (or
//!   `$BENCH_OUT_DIR/trace.json`): open it in <https://ui.perfetto.dev>
//!   or `chrome://tracing` to see each request as a timeline lane.
//!
//! Uses randomly initialized weights so it runs instantly; CI runs it as a
//! smoke step and uploads the trace artifact.

use slim::model::{by_name, init};
use slim::rng::Pcg32;
use slim::server::{api, Engine, Router, SchedPolicy};
use slim::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = "sim-125m";
    let cfg = by_name(model).expect("known config");
    let mut rng = Pcg32::seeded(11);
    let weights = Arc::new(init(&cfg, &mut rng));

    // Speculative route: compressed-draft/dense-target twins over the same
    // weights keep the example instant while exercising the full
    // draft/verify lifecycle the trace is meant to show.
    let target = Engine::new(model, cfg.clone(), weights.clone(), None);
    let draft = Engine::new("sim-125m-draft", cfg.clone(), weights, None);
    let mut router = Router::new();
    let policy = SchedPolicy {
        max_slots: 4,
        draft_k: 3,
        chunk_tokens: 8,
        step_tokens: 24,
        ..Default::default()
    };
    router.register_speculative(target, draft, policy);
    let router = Arc::new(router);

    let (tx, rx) = std::sync::mpsc::channel();
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = api::serve(router, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
    }
    let addr = rx.recv_timeout(Duration::from_secs(10))?;
    println!("[serve] speculative route listening on {addr} (4 slots, draft_k 3)");

    // A concurrent burst so the trace shows interleaved request lanes.
    let n_clients = 8usize;
    println!("[load ] {n_clients} clients, prompts 3-12 tokens, max_new 4-9");
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            let mut client = api::Client::connect(addr).expect("connect");
            let plen = 3 + c % 10;
            let prompt: Vec<u32> = (0..plen).map(|j| (8 + c * 11 + j * 5) as u32 % 500).collect();
            let toks = client.generate("sim-125m", &prompt, 4 + c % 6).expect("generate");
            toks.len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    println!("[done ] {total} tokens served");

    let mut client = api::Client::connect(addr)?;

    // 1. Structured JSON metrics, per route.
    let resp = client.call(&Json::parse(r#"{"cmd":"metrics"}"#).unwrap())?;
    let summary = resp.get("summary").and_then(Json::as_str).unwrap_or("?");
    println!("[stats] {summary}");
    let route = resp.get("routes").and_then(|r| r.get(model)).expect("route metrics");
    for key in ["requests", "tokens", "spec"] {
        println!(
            "[json ] {model}.{key} = {}",
            route.get(key).map(Json::to_string_compact).unwrap_or_default()
        );
    }
    let p95 = route
        .get("request_latency_seconds")
        .and_then(|h| h.get("p95"))
        .and_then(Json::as_f64)
        .expect("latency p95");
    println!("[json ] {model}.request_latency_seconds.p95 = {:.1}ms", p95 * 1e3);

    // 2. Prometheus text exposition.
    let prom = client.call(&Json::parse(r#"{"cmd":"metrics_prom"}"#).unwrap())?;
    let text = prom.get("text").and_then(Json::as_str).expect("prom text");
    let shown: Vec<&str> = text
        .lines()
        .filter(|l| l.starts_with("slim_requests_total") || l.starts_with("slim_stage_busy"))
        .collect();
    println!("[prom ] {} lines, e.g.:", text.lines().count());
    for line in shown.iter().take(6) {
        println!("[prom ]   {line}");
    }

    // 3. Perfetto trace of every request lifecycle.
    let resp = client.call(&Json::parse(r#"{"cmd":"trace"}"#).unwrap())?;
    let trace = resp.get("trace").expect("trace");
    let n_events =
        trace.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len).unwrap_or(0);
    let path = slim::util::bench_out_path("trace.json");
    std::fs::write(&path, trace.to_string_compact())?;
    println!(
        "[trace] {n_events} events → {} (load in https://ui.perfetto.dev)",
        path.display()
    );

    assert!(n_events > 0, "flight recorder captured the burst");
    assert!(p95 > 0.0, "latency histogram populated");
    router.shutdown();
    println!("\nOK: metrics JSON + Prometheus exposition + Perfetto trace all exported.");
    Ok(())
}
