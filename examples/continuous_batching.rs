//! Continuous-batching serving example: per-sequence KV cache slots,
//! in-flight admission, early retirement via stop tokens.
//!
//! ```bash
//! cargo run --release --example continuous_batching
//! ```
//!
//! Registers a sim model on the continuous [`Scheduler`] route, fires
//! concurrent clients with mixed-length prompts and generation budgets
//! over the TCP front-end, then spot-checks the core invariant: tokens
//! served under continuous batching are identical to a solo decode of the
//! same request. Uses randomly initialized weights so it runs instantly
//! (see `serve_compressed` for the full compress-then-serve pipeline).

use slim::model::{by_name, init};
use slim::rng::Pcg32;
use slim::server::{api, Engine, GenRequest, Router, SchedPolicy};
use slim::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = "sim-125m";
    let cfg = by_name(model).expect("known config");
    let mut rng = Pcg32::seeded(7);
    let weights = Arc::new(init(&cfg, &mut rng));

    // Two engines over the same weights: one serves continuously, one is
    // the solo-decode reference for the equivalence check.
    let reference = Engine::new(model, cfg.clone(), weights.clone(), None);
    let mut router = Router::new();
    router.register_continuous(
        Engine::new(model, cfg.clone(), weights, None),
        SchedPolicy { max_slots: 4, ..Default::default() },
    );
    let router = Arc::new(router);

    // Bind on an ephemeral port and serve in the background.
    let (tx, rx) = std::sync::mpsc::channel();
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = api::serve(router, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
    }
    let addr = rx.recv_timeout(Duration::from_secs(10))?;
    println!("[serve] continuous scheduler listening on {addr} (4 cache slots)");

    // Concurrent clients with mixed prompt lengths and budgets — more
    // clients than slots, so retired slots must be recycled.
    let n_clients = 10usize;
    println!("[load ] {n_clients} clients, prompts 1-10 tokens, max_new 3-8");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            let mut client = api::Client::connect(addr).expect("connect");
            let plen = 1 + c % 10;
            let prompt: Vec<u32> = (0..plen).map(|j| (8 + c * 13 + j * 3) as u32 % 500).collect();
            let max_new = 3 + c % 6;
            let toks = client.generate("sim-125m", &prompt, max_new).expect("generate");
            assert_eq!(toks.len(), max_new);
            (prompt, max_new, toks)
        }));
    }
    let served: Vec<(Vec<u32>, usize, Vec<u32>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let total: usize = served.iter().map(|(_, _, t)| t.len()).sum();
    println!(
        "[done ] {total} tokens in {:.2}s across {n_clients} interleaved sequences",
        t0.elapsed().as_secs_f64()
    );

    // Invariant: continuous batching is solo-equivalent, whatever was
    // in flight alongside each request.
    for (prompt, max_new, toks) in &served {
        let req = GenRequest::new(0, prompt.clone(), *max_new);
        let solo = reference.generate_batch(&[req]);
        assert_eq!(toks, &solo[0].tokens, "continuous batching must match solo decode");
    }
    println!("[check] all {n_clients} outputs token-for-token equal to solo decode");

    // Early retirement: stop the generation at its own second token.
    let probe_req = GenRequest::new(0, vec![5, 6, 7], 8);
    let probe = reference.generate_batch(&[probe_req]);
    let stop = probe[0].tokens[1];
    let mut client = api::Client::connect(addr)?;
    let resp = client.call(&Json::parse(&format!(
        r#"{{"model":"{model}","prompt":[5,6,7],"max_new":8,"stop":{stop}}}"#
    ))
    .unwrap())?;
    let stopped = resp.get("tokens").and_then(Json::as_arr).unwrap().len();
    println!("[stop ] stop={stop} retired after {stopped}/8 tokens, freeing its slot early");

    println!("[stats] {}", router.registry.summary());
    router.shutdown();
    println!("\nOK: continuous batching served mixed-length traffic with solo-equivalent output.");
    Ok(())
}
