//! Chat session example: a multi-turn conversation over the TCP wire
//! protocol, with streamed token delivery and seeded sampling.
//!
//! ```bash
//! cargo run --release --example chat_session
//! ```
//!
//! Exercises the v2 protocol end to end (see `docs/PROTOCOL.md`):
//!
//! * `{"cmd":"models"}` — the route advertises its session capacity and
//!   streaming support;
//! * `{"cmd":"session_open"}` → three `session_append` turns with
//!   `"stream":true` and temperature/top-k/top-p/seed sampling — each
//!   turn prefills only its new tokens because the server keeps the
//!   conversation's KV cache slot parked between turns;
//! * a fresh one-shot generate over the full transcript reproduces the
//!   last turn's reply exactly (same seed ⇒ same tokens, resumed or not);
//! * `session_drop`, after which the session id fails typed
//!   (`unknown_session`).
//!
//! Uses randomly initialized weights so it runs instantly; CI runs it as
//! a smoke step.

use slim::model::{by_name, init};
use slim::rng::Pcg32;
use slim::server::{api, Engine, Router, SchedPolicy};
use slim::util::json::{n, obj, s, Json};
use std::sync::Arc;
use std::time::Duration;

const MODEL: &str = "sim-125m";
const MAX_NEW: usize = 6;
const SEED: u64 = 42;

fn tokens_json(tokens: &[u32]) -> Json {
    Json::Arr(tokens.iter().map(|&t| n(t as f64)).collect())
}

fn sampling_fields(fields: &mut Vec<(&'static str, Json)>) {
    fields.push(("temperature", n(0.8)));
    fields.push(("top_k", n(40.0)));
    fields.push(("top_p", n(0.95)));
    fields.push(("seed", n(SEED as f64)));
}

/// Read streamed frames until the terminal one; returns the reply tokens.
fn drain_stream(client: &mut api::Client) -> anyhow::Result<Vec<u32>> {
    let mut streamed: Vec<u32> = Vec::new();
    loop {
        let frame = client.recv()?;
        match frame.get("event").and_then(Json::as_str) {
            Some("token") => {
                let tok = frame.get("token").and_then(Json::as_usize).expect("token id");
                print!(" {tok}");
                streamed.push(tok as u32);
            }
            Some("done") => {
                println!();
                let done: Vec<u32> = frame
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .expect("tokens")
                    .iter()
                    .filter_map(|v| v.as_usize().map(|u| u as u32))
                    .collect();
                assert_eq!(done, streamed, "token frames must equal the final result");
                return Ok(streamed);
            }
            _ => anyhow::bail!("stream failed: {}", frame.to_string_compact()),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = by_name(MODEL).expect("known config");
    let mut rng = Pcg32::seeded(7);
    let weights = Arc::new(init(&cfg, &mut rng));
    let engine = Engine::new(MODEL, cfg, weights, None);
    let mut router = Router::new();
    let policy = SchedPolicy { max_slots: 4, max_sessions: 4, ..Default::default() };
    router.register_continuous(engine, policy);
    let router = Arc::new(router);

    let (tx, rx) = std::sync::mpsc::channel();
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = api::serve(router, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
    }
    let addr = rx.recv_timeout(Duration::from_secs(10))?;
    println!("[serve] continuous route listening on {addr} (4 slots, 4 sessions)");

    let mut client = api::Client::connect(addr)?;

    // The route advertises its session + streaming capability.
    let models = client.call(&Json::parse(r#"{"v":2,"cmd":"models"}"#).unwrap())?;
    let entry = models.get("models").and_then(Json::as_arr).expect("models")[0].clone();
    println!(
        "[route] mode={} admit={} sessions={} streaming={}",
        entry.get("mode").and_then(Json::as_str).unwrap_or("?"),
        entry.get("admit").and_then(Json::as_str).unwrap_or("?"),
        entry.get("sessions").and_then(Json::as_usize).unwrap_or(0),
        entry.get("streaming").and_then(Json::as_bool).unwrap_or(false),
    );
    assert!(entry.get("sessions").and_then(Json::as_usize).unwrap_or(0) > 0);

    // Open the conversation.
    let req = obj(vec![("v", n(2.0)), ("cmd", s("session_open")), ("model", s(MODEL))]);
    let opened = client.call(&req)?;
    let sid = opened.get("session").and_then(Json::as_usize).expect("session id");
    println!("[sess ] opened session {sid}");

    // Three streamed turns; the transcript accumulates user tokens and
    // sampled replies.
    let turns: [Vec<u32>; 3] = [vec![5, 6, 7], vec![30, 31], vec![90]];
    let mut transcript: Vec<u32> = Vec::new();
    let mut last_reply: Vec<u32> = Vec::new();
    for (i, user) in turns.iter().enumerate() {
        let mut fields = vec![
            ("v", n(2.0)),
            ("cmd", s("session_append")),
            ("model", s(MODEL)),
            ("session", n(sid as f64)),
            ("tokens", tokens_json(user)),
            ("max_new", n(MAX_NEW as f64)),
            ("stream", Json::Bool(true)),
        ];
        sampling_fields(&mut fields);
        client.send(&obj(fields))?;
        print!("[turn{}] user {user:?} →", i + 1);
        let reply = drain_stream(&mut client)?;
        assert_eq!(reply.len(), MAX_NEW);
        transcript.extend_from_slice(user);
        last_reply = reply.clone();
        transcript.extend_from_slice(&reply);
    }

    // Seeded sampling is path-invariant: a fresh one-shot request over
    // the transcript (minus the last reply) reproduces the last turn's
    // reply token-for-token, even though the session turns resumed a
    // parked KV slot and prefilled only their new tokens.
    let prompt = &transcript[..transcript.len() - last_reply.len()];
    let mut fields = vec![
        ("v", n(2.0)),
        ("model", s(MODEL)),
        ("prompt", tokens_json(prompt)),
        ("max_new", n(MAX_NEW as f64)),
    ];
    sampling_fields(&mut fields);
    let resp = client.call(&obj(fields))?;
    let solo: Vec<u32> = resp
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("tokens")
        .iter()
        .filter_map(|v| v.as_usize().map(|u| u as u32))
        .collect();
    assert_eq!(solo, last_reply, "session-resumed turn must match the one-shot replay");
    println!("[check] one-shot replay over {} prompt tokens matches turn 3", prompt.len());

    // Drop the session; the id fails typed afterwards.
    let req = obj(vec![
        ("v", n(2.0)),
        ("cmd", s("session_drop")),
        ("model", s(MODEL)),
        ("session", n(sid as f64)),
    ]);
    let dropped = client.call(&req)?;
    assert_eq!(dropped.get("dropped").and_then(Json::as_usize), Some(sid));
    let fields = vec![
        ("v", n(2.0)),
        ("cmd", s("session_append")),
        ("model", s(MODEL)),
        ("session", n(sid as f64)),
        ("tokens", tokens_json(&[4])),
    ];
    let gone = client.call(&obj(fields))?;
    let code = gone.get("error").and_then(|e| e.get("code")).and_then(Json::as_str);
    assert_eq!(code, Some("unknown_session"));
    println!("[sess ] dropped session {sid}; further appends fail with unknown_session");

    // Streamed delivery fed the inter-token latency histogram.
    let m = client.call(&Json::parse(r#"{"cmd":"metrics"}"#).unwrap())?;
    let gaps = m
        .get("routes")
        .and_then(|r| r.get(MODEL))
        .and_then(|r| r.get("inter_token_seconds"))
        .and_then(|h| h.get("count"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    println!("[stats] {gaps} inter-token gaps recorded across the streamed turns");
    assert!(gaps > 0.0, "streamed turns must record inter-token latency");

    router.shutdown();
    println!("\nOK: streamed multi-turn session with seeded sampling served and verified.");
    Ok(())
}
