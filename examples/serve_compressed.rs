//! Serving example: launch the router + TCP server over a SLiM-compressed
//! model, fire concurrent batched requests, and report latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_compressed
//! ```
//!
//! This is the L3 serving path of DESIGN.md: the dynamic batcher coalesces
//! concurrent clients into decode batches; metrics report mean batch size,
//! p50/p99 latency and decode throughput.

use slim::compress::Preset;
use slim::experiments::Ctx;
use slim::server::{api, BatchPolicy, Engine, Router};
use slim::sparse::SparsityPattern;
use slim::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = "sim-125m";
    println!("[setup] training/loading {model} + SLiM compression (2:4 + 4-bit + adapters)");
    let ctx = Ctx::new(true)?;
    let b = ctx.bundle(model)?;
    let cm = ctx.compress(&b, Preset::SlimLora, Some(SparsityPattern::TWO_FOUR), 4);

    // Kernel-backed serving: decode matmuls run on packed int4-2:4 kernels
    // through the KV-cached forward pass (not dense f32 overrides).
    let kernels = slim::model::CompressedWeights::from_model(&cm);
    let census: Vec<String> =
        kernels.kernel_census().iter().map(|(k, n)| format!("{n}x {k}")).collect();
    println!(
        "[setup] packed kernels: {} ({} weight bytes/step)",
        census.join(", "),
        kernels.weight_bytes()
    );
    let engine = Engine::with_kernels(
        model,
        b.cfg.clone(),
        Arc::new(b.weights.clone()),
        Arc::new(kernels),
    );
    let mut router = Router::new();
    router.register(
        engine,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(4) },
    );
    let router = Arc::new(router);

    // Bind on an ephemeral port and serve in the background.
    let (tx, rx) = std::sync::mpsc::channel();
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = api::serve(router, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
    }
    let addr = rx.recv_timeout(Duration::from_secs(10))?;
    println!("[serve] listening on {addr}");

    // Fire concurrent clients.
    let n_clients = 16;
    let reqs_per_client = 6;
    let max_new = 12;
    println!("[load ] {n_clients} clients x {reqs_per_client} requests, {max_new} new tokens each");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            let mut client = api::Client::connect(addr).expect("connect");
            let mut total = 0usize;
            for r in 0..reqs_per_client {
                let prompt = vec![8 + ((c * 7 + r) % 128) as u32, 2];
                let toks = client.generate("sim-125m", &prompt, max_new).expect("generate");
                total += toks.len();
            }
            total
        }));
    }
    let total_tokens: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();

    let tok_per_s = total_tokens as f64 / wall;
    println!("[done ] {total_tokens} tokens in {wall:.2}s ({tok_per_s:.1} tok/s end-to-end)");
    println!("[stats] {}", router.registry.summary());

    // Metrics over the wire too.
    let mut client = api::Client::connect(addr)?;
    let resp = client.call(&Json::parse(r#"{"cmd":"metrics"}"#).unwrap())?;
    println!("[wire ] {}", resp.to_string_compact());

    let metrics = router.route_metrics(model).expect("route metrics");
    assert!(metrics.mean_batch_size() > 1.0, "batching should coalesce requests");
    let mean_batch = metrics.mean_batch_size();
    println!("\nOK: mean batch size {mean_batch:.2} > 1 — dynamic batching engaged.");
    router.shutdown();
    Ok(())
}
