//! End-to-end driver (the repo's flagship example; results recorded in
//! EXPERIMENTS.md §E2E):
//!
//!   1. trains a sim transformer from scratch **through the AOT train-step
//!      artifact** (Rust drives, HLO computes), logging the loss curve;
//!   2. compresses it with the full SLiM pipeline (SLiM-Quant → Wanda 2:4 →
//!      SLiM-LoRA) and the main baselines;
//!   3. evaluates perplexity + 6-task zero-shot accuracy for each;
//!   4. runs the paper's PEFT fine-tuning on the SLiM model.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_and_eval
//! ```

use slim::compress::Preset;
use slim::data::{Corpus, CorpusSpec};
use slim::eval;
use slim::experiments::Ctx;
use slim::model::Batch;
use slim::runtime::Runtime;
use slim::sparse::SparsityPattern;
use slim::train;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "sim-350m".to_string());
    let rt = Runtime::load(Runtime::default_dir())?;
    let corpus = Corpus::generate(CorpusSpec::SynthWeb, 120_000);
    let cfg = slim::model::by_name(&model).expect("known model");

    // ── 1. pretraining through the AOT artifact ─────────────────────────
    let steps = 500;
    println!("[1/4] training {model} for {steps} steps via train_step_{model}.hlo.txt");
    let t0 = std::time::Instant::now();
    let report = train::pretrain(&rt, &cfg, &corpus, steps, 0xe2e)?;
    println!(
        "      done in {:.1}s — loss curve (every 50): {}",
        t0.elapsed().as_secs_f64(),
        report
            .losses
            .iter()
            .step_by(50)
            .map(|l| format!("{l:.2}"))
            .collect::<Vec<_>>()
            .join(" → ")
    );
    let weights = report.weights;

    // ── 2+3. compress with each method and evaluate ──────────────────────
    println!("[2/4] calibrating ({} sequences) and compressing", 8);
    let mut rng = slim::rng::Pcg32::seeded(1);
    let toks = corpus.calibration(8, cfg.max_seq, &mut rng);
    let batch = Batch::new(toks, 8, cfg.max_seq);
    let mut taps = slim::model::ActivationTap::new();
    slim::model::forward(&cfg, &weights, &batch, Some(&mut taps), None);

    let dense_ppl = eval::perplexity(&cfg, &weights, None, &corpus, 10);
    let dense_acc = eval::zero_shot(&cfg, &weights, None, &corpus, 60);
    println!("[3/4] dense:            ppl {:6.2}  acc {:5.2}%", dense_ppl, dense_acc.average);

    let pattern = SparsityPattern::TWO_FOUR;
    let mut slim_cm = None;
    for preset in [
        Preset::MagnitudeGroupAbsMax,
        Preset::WandaGroupAbsMax,
        Preset::SparseGptGroupOptq,
        Preset::NaiveLora,
        Preset::SlimLora,
        Preset::SlimLoraQ,
    ] {
        let ccfg = preset.config(Some(pattern), 4);
        let cm = slim::model::compress_model(&cfg, &weights, &taps, &ccfg);
        let ppl = eval::perplexity(&cfg, &weights, Some(&cm.overrides), &corpus, 10);
        let acc = eval::zero_shot(&cfg, &weights, Some(&cm.overrides), &corpus, 60);
        let (m, q) = preset.label();
        println!("      {m:<22} {q:<14} ppl {ppl:6.2}  acc {:5.2}%", acc.average);
        if preset == Preset::SlimLora {
            slim_cm = Some(cm);
        }
    }

    // ── 4. the paper's PEFT recipe on the SLiM model ─────────────────────
    println!("[4/4] fine-tuning SLiM-LoRA adapters (frozen base, paper §3.4)");
    let mut cm = slim_cm.unwrap();
    let losses = train::finetune_adapters(&rt, &cfg, &weights, &mut cm, &corpus, 40, false)?;
    let ppl_ft = eval::perplexity(&cfg, &weights, Some(&cm.overrides), &corpus, 10);
    let acc_ft = eval::zero_shot(&cfg, &weights, Some(&cm.overrides), &corpus, 60);
    println!(
        "      FT loss {:.3} → {:.3} | SLiM-LoRA + FT: ppl {:6.2}  acc {:5.2}%",
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0),
        ppl_ft,
        acc_ft.average
    );
    println!("\nper-task accuracy (SLiM-LoRA + FT):");
    for (task, acc) in &acc_ft.per_task {
        println!("      {task:<22} {acc:5.1}%");
    }
    // Keep the Ctx type exercised for docs discoverability.
    let _ = Ctx::new(true);
    Ok(())
}
