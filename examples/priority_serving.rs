//! Priority / fair-share serving example: two clients share two cache
//! slots, one of them floods the queue with a long prompt plus a batch of
//! bulk requests, and the admission policy decides who waits.
//!
//! ```bash
//! cargo run --release --example priority_serving
//! ```
//!
//! Client 1 ("bulk", priority 0) submits a long prompt and five follow-up
//! requests in one burst; client 2 ("interactive", priority 1) submits six
//! short requests right behind them. The same workload runs twice:
//!
//! * **FIFO** — arrival order rules, so the interactive client queues
//!   behind the whole bulk burst and its TTFT inflates;
//! * **fair-share** — admission round-robins across client ids and honors
//!   `priority`, so interactive requests jump the bulk backlog the moment
//!   a slot frees (and chunked prefill keeps the long prompt from
//!   monopolizing the step loop meanwhile).
//!
//! Per-request TTFT comes back in `GenResult::ttft_s` (measured by the
//! scheduler at first-token time), so the per-client comparison needs no
//! server-side instrumentation.

use slim::model::{by_name, init};
use slim::rng::Pcg32;
use slim::server::{AdmitPolicy, Engine, RequestOpts, Router, SchedPolicy};
use std::sync::Arc;

/// (client id, priority, prompt, max_new) for the whole burst, bulk first.
fn workload(vocab: u32) -> Vec<(u64, i32, Vec<u32>, usize)> {
    let mut rng = Pcg32::seeded(42);
    let mut reqs = Vec::new();
    // Bulk client 1: one long prompt (48 tokens ≈ 6× the short ones)...
    let long: Vec<u32> = (0..48).map(|_| rng.below(vocab)).collect();
    reqs.push((1u64, 0i32, long, 12usize));
    // ...then five medium follow-ups.
    for _ in 0..5 {
        let prompt: Vec<u32> = (0..8).map(|_| rng.below(vocab)).collect();
        reqs.push((1, 0, prompt, 8));
    }
    // Interactive client 2: six short, high-priority requests.
    for _ in 0..6 {
        let prompt: Vec<u32> = (0..6).map(|_| rng.below(vocab)).collect();
        reqs.push((2, 1, prompt, 4));
    }
    reqs
}

/// Serve the burst under `admit`; return (client, ttft_ms) per request.
fn run(admit: AdmitPolicy) -> anyhow::Result<Vec<(u64, f64)>> {
    let model = "sim-125m";
    let cfg = by_name(model).expect("known config");
    let mut rng = Pcg32::seeded(7);
    let weights = Arc::new(init(&cfg, &mut rng));
    let mut router = Router::new();
    router.register_continuous(
        Engine::new(model, cfg.clone(), weights, None),
        // Two slots force admission decisions; small chunk/budget values
        // exercise chunked prefill on the long prompt.
        SchedPolicy { max_slots: 2, chunk_tokens: 8, step_tokens: 12, admit, ..Default::default() },
    );
    let mut rxs = Vec::new();
    for (client_id, priority, prompt, max_new) in workload(cfg.vocab as u32) {
        let opts = RequestOpts { max_new, priority, client_id, ..Default::default() };
        rxs.push((client_id, router.submit_with(model, prompt, opts)?));
    }
    let mut out = Vec::new();
    for (client, rx) in rxs {
        let res = rx.recv_timeout(std::time::Duration::from_secs(60))?;
        out.push((client, res.ttft_s.expect("scheduler reports ttft") * 1e3));
    }
    router.shutdown();
    Ok(out)
}

fn stats(ttfts: &[f64]) -> (f64, f64) {
    let mut v = ttfts.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = v.iter().sum::<f64>() / v.len().max(1) as f64;
    (mean, v.last().copied().unwrap_or(0.0))
}

fn main() -> anyhow::Result<()> {
    println!("priority serving — 2 slots, bulk burst (client 1) vs interactive (client 2)\n");
    println!(
        "{:<12} {:<13} {:>10} {:>12} {:>12}",
        "policy", "client", "requests", "ttft_mean", "ttft_max"
    );
    let mut interactive_mean = Vec::new();
    for admit in [AdmitPolicy::Fifo, AdmitPolicy::FairShare] {
        let results = run(admit)?;
        for (client, label) in [(1u64, "bulk(p0)"), (2u64, "interact(p1)")] {
            let ttfts: Vec<f64> =
                results.iter().filter(|(c, _)| *c == client).map(|(_, t)| *t).collect();
            let (mean, max) = stats(&ttfts);
            println!(
                "{:<12} {:<13} {:>10} {:>10.1}ms {:>10.1}ms",
                admit.name(),
                label,
                ttfts.len(),
                mean,
                max
            );
            if client == 2 {
                interactive_mean.push(mean);
            }
        }
    }
    if let [fifo, fair] = interactive_mean[..] {
        println!(
            "\ninteractive mean TTFT: {:.1}ms under FIFO → {:.1}ms under fair-share ({:+.1}%)",
            fifo,
            fair,
            100.0 * (fair / fifo - 1.0)
        );
        println!(
            "(fair-share + priority lets the interactive client jump the bulk backlog; FIFO\n\
             makes it wait for every bulk request submitted before it)"
        );
    }
    Ok(())
}
