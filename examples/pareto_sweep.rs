//! Pareto sweep (paper Figure 2): accuracy vs model bytes across the sim
//! family and compression methods, via the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example pareto_sweep
//! ```
//!
//! The paper's claim: at equal byte budget, a larger SLiM-compressed model
//! beats a smaller dense one. The example prints the (bytes, accuracy)
//! points and checks the claim pairwise.

use slim::compress::Preset;
use slim::experiments::Ctx;
use slim::model::size::{model_bytes, SizeSpec};
use slim::sparse::SparsityPattern;
use slim::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(true)?;
    let models = ["sim-125m", "sim-350m", "sim-llama-7b"];

    #[derive(Debug)]
    struct Point {
        model: &'static str,
        method: &'static str,
        bytes: u64,
        acc: f64,
    }
    let mut points = Vec::new();

    for name in models {
        let b = ctx.bundle(name)?;
        points.push(Point {
            model: name,
            method: "dense",
            bytes: model_bytes(&b.cfg, &SizeSpec::dense()),
            acc: ctx.acc(&b, None),
        });
        let cm = ctx.compress(&b, Preset::SlimLoraQ, Some(SparsityPattern::TWO_FOUR), 4);
        points.push(Point {
            model: name,
            method: "SLiM-LoRA^Q",
            bytes: model_bytes(&b.cfg, &SizeSpec::slim(true)),
            acc: ctx.acc(&b, Some(&cm.overrides)),
        });
    }

    points.sort_by_key(|p| p.bytes);
    println!("{:<14} {:<12} {:>10} {:>8}", "model", "method", "bytes", "acc%");
    for p in &points {
        println!("{:<14} {:<12} {:>10} {:>8.2}", p.model, p.method, fmt_bytes(p.bytes), p.acc);
    }

    // Pareto check: compressed larger model vs dense smaller model at
    // comparable-or-smaller bytes.
    let mut wins = 0;
    let mut comparisons = 0;
    for big in points.iter().filter(|p| p.method != "dense") {
        for small in points.iter().filter(|p| p.method == "dense") {
            if big.bytes <= small.bytes * 11 / 10 && big.model != small.model {
                comparisons += 1;
                if big.acc >= small.acc {
                    wins += 1;
                }
            }
        }
    }
    println!("\nPareto: compressed-model wins {wins}/{comparisons} comparable-budget matchups");
    Ok(())
}
