"""L2 model tests: shapes, causality, loss behavior, train/ft steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def dense_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in M.param_specs(cfg):
        if name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".b") or name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(0, 0.02, shape).astype(np.float32)))
    return out


def compressed_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in M.compressed_param_specs(cfg):
        if name.endswith(".scale"):
            out.append(jnp.full(shape, 0.08, jnp.float32))
        elif name.endswith(".mask"):
            out.append(jnp.asarray((rng.random(shape) > 0.5).astype(np.float32)))
        elif name.endswith(".wq"):
            out.append(jnp.asarray(rng.integers(-7, 8, shape).astype(np.float32)))
        elif name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".b") or name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(0, 0.02, shape).astype(np.float32)))
    return out


CFG = M.by_name("sim-125m")


def toks(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, (b, s)), dtype=jnp.int32)


def test_fwd_shape_and_finite():
    params = dense_params(CFG)
    logits = M.fwd(CFG, params, toks(2, 16))
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_untrained_loss_near_uniform():
    params = dense_params(CFG)
    l = float(M.loss(CFG, params, toks(4, 32)))
    assert abs(l - np.log(CFG.vocab)) < 0.5


def test_causality():
    params = dense_params(CFG)
    t1 = toks(1, 16, seed=1)
    t2 = t1.at[0, 15].set((t1[0, 15] + 1) % CFG.vocab)
    l1 = M.fwd(CFG, params, t1)
    l2 = M.fwd(CFG, params, t2)
    np.testing.assert_allclose(
        np.asarray(l1[0, :14]), np.asarray(l2[0, :14]), atol=1e-5
    )


def test_train_step_reduces_loss():
    params = dense_params(CFG)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    batch = toks(8, 32, seed=2)
    losses = []
    step_fn = jax.jit(lambda p, m, v, s, t: M.train_step(CFG, p, m, v, s, 3e-3, t))
    for step in range(12):
        params, m, v, l = step_fn(params, m, v, float(step + 1), batch)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.3, losses


def test_clm_fwd_matches_dense_when_uncompressed():
    """With mask=1 and wq = round(w/alpha*levels) at 8 bits, clm_fwd must
    approximate the dense fwd closely."""
    params = dense_params(CFG, seed=3)
    named = dict(zip([n for n, _ in M.param_specs(CFG)], params))
    bits, levels = 8, 127.0
    cps = []
    for name, shape in M.compressed_param_specs(CFG):
        if name.endswith(".wq"):
            w = named[name[:-3]]
            alpha = float(jnp.max(jnp.abs(w)))
            cps.append(jnp.round(jnp.clip(w / alpha, -1, 1) * levels))
        elif name.endswith(".scale"):
            w = named[name[:-6]]
            cps.append(jnp.full((1, 1), float(jnp.max(jnp.abs(w))), jnp.float32))
        elif name.endswith(".mask"):
            cps.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".l") or name.endswith(".r"):
            cps.append(jnp.zeros(shape, jnp.float32))
        else:
            cps.append(named[name])
    dense = M.fwd(CFG, params, toks(1, 16))
    comp = M.clm_fwd(CFG, cps, toks(1, 16), bits=bits)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(dense), rtol=0.05, atol=0.05)


def test_ft_step_only_updates_adapters():
    cps = compressed_params(CFG, seed=4)
    t_idx = M.trainable_adapter_indices(CFG)
    m = [jnp.zeros_like(cps[i]) for i in t_idx]
    v = [jnp.zeros_like(cps[i]) for i in t_idx]
    new_t, _, _, l = M.ft_step(CFG, cps, m, v, 1.0, 1e-2, toks(2, 16, seed=5))
    assert np.isfinite(float(l))
    changed = sum(
        float(jnp.abs(nt - cps[i]).max()) > 0 for nt, i in zip(new_t, t_idx)
    )
    assert changed == len(t_idx), f"only {changed}/{len(t_idx)} adapters updated"


def test_ft_steps_reduce_loss():
    cps = compressed_params(CFG, seed=6)
    t_idx = M.trainable_adapter_indices(CFG)
    m = [jnp.zeros_like(cps[i]) for i in t_idx]
    v = [jnp.zeros_like(cps[i]) for i in t_idx]
    batch = toks(4, 32, seed=7)
    step_fn = jax.jit(lambda c, m, v, s, t: M.ft_step(CFG, c, m, v, s, 1e-2, t))
    l0 = None
    for step in range(8):
        new_t, m, v, l = step_fn(cps, m, v, float(step + 1), batch)
        for i, t in zip(t_idx, new_t):
            cps[i] = t
        l0 = float(l) if l0 is None else l0
    assert float(l) < l0, (float(l), l0)


def test_param_spec_orders_cover_family():
    for cfg in M.FAMILY:
        specs = M.param_specs(cfg)
        names = [n for n, _ in specs]
        assert len(set(names)) == len(names)
        assert names[0] == "embed.tok" and names[-1] == "final_ln.b"
        cspecs = M.compressed_param_specs(cfg)
        lin_tensors = [n for n, _ in cspecs if n.endswith(".wq")]
        assert len(lin_tensors) == 6 * cfg.n_layers


@pytest.mark.parametrize("name", ["sim-125m", "sim-350m"])
def test_adapter_rank_rule(name):
    cfg = M.by_name(name)
    assert M.adapter_rank(cfg, "mlp.fc1") == max(1, round(0.1 * cfg.d_model))
