"""L1 kernel correctness: Pallas vs pure-jnp oracle (the core signal).

Hypothesis sweeps shapes, ranks, bitwidths and block sizes; fixed cases pin
edge geometries (ragged tiles, rank 1, single row).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import fake_quant_ref, quant_scan_ref, slim_matmul_ref
from compile.kernels.quant_scan import quant_scan
from compile.kernels.slim_matmul import slim_matmul


def make_inputs(rng, m, d_in, d_out, rank, bits):
    levels = 2 ** (bits - 1) - 1
    x = jnp.asarray(rng.normal(0, 1, (m, d_in)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-levels, levels + 1, (d_in, d_out)).astype(np.float32))
    scale = jnp.asarray(rng.uniform(0.05, 0.5, (1, 1)).astype(np.float32))
    mask = jnp.asarray((rng.random((d_in, d_out)) > 0.5).astype(np.float32))
    l = jnp.asarray(rng.normal(0, 0.1, (d_in, rank)).astype(np.float32))
    r = jnp.asarray(rng.normal(0, 0.1, (rank, d_out)).astype(np.float32))
    return x, wq, scale, mask, l, r


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    d_in=st.integers(2, 96),
    d_out=st.integers(2, 160),
    rank=st.integers(1, 16),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_slim_matmul_matches_ref(m, d_in, d_out, rank, bits, seed):
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, m, d_in, d_out, rank, bits)
    got = slim_matmul(*args, bits=bits)
    want = slim_matmul_ref(*args, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,d_in,d_out,rank",
    [(1, 4, 4, 1), (128, 64, 64, 6), (130, 64, 257, 7), (64, 256, 1024, 26)],
)
def test_slim_matmul_fixed_geometries(m, d_in, d_out, rank):
    rng = np.random.default_rng(7)
    args = make_inputs(rng, m, d_in, d_out, rank, 4)
    got = slim_matmul(*args, bits=4)
    want = slim_matmul_ref(*args, bits=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_slim_matmul_block_sizes_equal():
    rng = np.random.default_rng(3)
    args = make_inputs(rng, 96, 48, 80, 5, 4)
    a = slim_matmul(*args, bits=4, block_m=32, block_n=16)
    b = slim_matmul(*args, bits=4, block_m=128, block_n=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_slim_matmul_zero_mask_leaves_only_adapters():
    rng = np.random.default_rng(4)
    x, wq, scale, mask, l, r = make_inputs(rng, 8, 16, 12, 3, 4)
    mask = jnp.zeros_like(mask)
    got = slim_matmul(x, wq, scale, mask, l, r, bits=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray((x @ l) @ r), rtol=1e-5, atol=1e-6)


def test_slim_matmul_grad_matches_ref():
    """Custom VJP vs autodiff of the jnp reference (adapters + input)."""
    rng = np.random.default_rng(5)
    x, wq, scale, mask, l, r = make_inputs(rng, 12, 24, 20, 4, 4)

    def f_kernel(x, l, r):
        return jnp.sum(slim_matmul(x, wq, scale, mask, l, r, bits=4) ** 2)

    def f_ref(x, l, r):
        return jnp.sum(slim_matmul_ref(x, wq, scale, mask, l, r, bits=4) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, l, r)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, l, r)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ───────────────────────── quant_scan ────────────────────────────────────

@settings(max_examples=20, deadline=None)
@given(
    nbins=st.integers(8, 600),
    k=st.integers(1, 80),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_scan_matches_ref(nbins, k, bits, seed):
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(np.sort(rng.uniform(0.001, 1.0, (1, nbins))).astype(np.float32))
    pdf = rng.random((1, nbins)).astype(np.float32)
    pdf = jnp.asarray(pdf / pdf.sum())
    alphas = jnp.asarray(rng.uniform(0.01, 1.2, (1, k)).astype(np.float32))
    got = quant_scan(centers, pdf, alphas, bits=bits)
    want = quant_scan_ref(centers, pdf, alphas, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-7)


def test_quant_scan_error_shape_has_interior_minimum():
    """Bell-shaped |W| → E(alpha) dips in the interior (paper Fig. 1 logic)."""
    rng = np.random.default_rng(11)
    data = np.abs(rng.normal(0, 1, 200_000)).astype(np.float32)
    hist, edges = np.histogram(data, bins=512)
    centers = jnp.asarray(((edges[:-1] + edges[1:]) / 2).reshape(1, -1).astype(np.float32))
    pdf = jnp.asarray((hist / hist.sum()).reshape(1, -1).astype(np.float32))
    alphas = jnp.asarray(np.linspace(0.05, data.max(), 64).reshape(1, -1).astype(np.float32))
    errs = np.asarray(quant_scan(centers, pdf, alphas, bits=4))[0]
    best = errs.argmin()
    assert 0 < best < 63, f"interior optimum expected, got {best}"
    assert errs[best] < errs[0] and errs[best] < errs[-1]


def test_fake_quant_ref_idempotent():
    w = jnp.asarray(np.linspace(-2, 2, 41).astype(np.float32))
    q1 = fake_quant_ref(w, 1.5, 4)
    q2 = fake_quant_ref(q1, 1.5, 4)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)
