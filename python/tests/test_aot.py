"""AOT manifest + HLO text round-trip tests.

Lowers a small entry in-process, checks the HLO text parses structurally,
and validates manifest invariants the Rust runtime depends on.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_to_hlo_text_produces_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_emitter_writes_manifest(tmp_path):
    em = aot.Emitter(str(tmp_path))

    def fn(x):
        return (x * 2.0,)

    em.emit("double", fn, [aot.spec("x", (3, 3))], {"kind": "test"})
    em.finish()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    entry = manifest["entries"][0]
    assert entry["name"] == "double"
    assert entry["inputs"] == [{"name": "x", "shape": [3, 3], "dtype": "f32"}]
    assert entry["outputs"][0]["shape"] == [3, 3]
    assert (tmp_path / "double.hlo.txt").exists()


def test_train_step_entry_shapes(tmp_path):
    """The train_step artifact must expose params+m+v+step+lr+tokens inputs
    and params+m+v+loss outputs in the documented order."""
    em = aot.Emitter(str(tmp_path))
    cfg = M.by_name("sim-125m")
    aot.emit_model(em, cfg, with_compressed=False)
    em.finish()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    entries = {e["name"]: e for e in manifest["entries"]}
    n = len(M.param_specs(cfg))
    ts = entries[f"train_step_{cfg.name}"]
    assert len(ts["inputs"]) == 3 * n + 3
    assert len(ts["outputs"]) == 3 * n + 1
    assert ts["inputs"][-1]["dtype"] == "i32"
    assert ts["meta"]["n_params"] == n
    # loss entry: single scalar output
    ll = entries[f"lm_loss_{cfg.name}"]
    assert ll["outputs"][0]["shape"] == []


def test_quick_configs_subset_of_family():
    names = {c.name for c in M.FAMILY}
    assert set(aot.QUICK) <= names
