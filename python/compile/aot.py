"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.

The interchange format is HLO *text*, not serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per model config we emit:
  train_step_<name>   — fused AdamW pretraining step
  lm_loss_<name>      — mean next-token NLL (perplexity eval)
  lm_fwd_<name>       — dense logits
  clm_fwd_<name>      — compressed logits (L1 Pallas kernel on every linear)
  ft_step_<name>      — PEFT AdamW on adapters (paper §3.4)
plus the standalone kernels:
  layer_fwd_<m>x<din>x<dout>r<rank> — the fused compressed-linear kernel
  quant_scan          — SLiM-Quant alpha error scan

`manifest.json` records, for every entry, the positional input/output specs
(name, shape, dtype) that rust/src/runtime uses to marshal Weights into HLO
arguments.

Usage: python -m compile.aot --out ../artifacts [--configs sim-125m,...]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quant_scan import quant_scan
from .kernels.slim_matmul import slim_matmul

# Fixed AOT batch geometries (documented in the manifest).
TRAIN_B, EVAL_B, FWD_B, FT_B, SEQ = 16, 8, 4, 8, 64

# Configs that get the (larger) compressed/FT graphs.
QUICK = ["sim-125m", "sim-350m", "sim-1.3b", "sim-llama-7b"]


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def zeros_like_specs(specs):
    return [jnp.zeros(tuple(s["shape"]),
                      jnp.int32 if s["dtype"] == "i32" else jnp.float32)
            for s in specs]


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_specs, meta=None):
        """Lower fn(*example_args) and write <name>.hlo.txt."""
        args = zeros_like_specs(in_specs)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        flat, _ = jax.tree_util.tree_flatten(out_avals)
        outputs = [spec(f"out{i}", a.shape,
                        "i32" if str(a.dtype).startswith("int") else "f32")
                   for i, a in enumerate(flat)]
        self.entries.append({
            "name": name, "file": fname,
            "inputs": in_specs, "outputs": outputs,
            "meta": meta or {},
        })
        print(f"  wrote {fname} ({len(text)//1024} KiB, "
              f"{len(in_specs)} inputs, {len(outputs)} outputs)")

    def finish(self):
        manifest = {"version": 1, "entries": self.entries}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.entries)} entries")


def dense_param_inspecs(cfg):
    return [spec(n, s) for n, s in M.param_specs(cfg)]


def compressed_param_inspecs(cfg):
    return [spec(n, s) for n, s in M.compressed_param_specs(cfg)]


def emit_model(em, cfg, with_compressed):
    pspecs = dense_param_inspecs(cfg)
    n_params = len(pspecs)
    tok = lambda b: spec("tokens", (b, SEQ), "i32")
    meta = {"config": cfg.name, "seq": SEQ, "n_params": n_params}

    # train_step(params, m, v, step, lr, tokens)
    def _train(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        step, lr, tokens = args[3 * n_params], args[3 * n_params + 1], args[3 * n_params + 2]
        new_p, new_m, new_v, l = M.train_step(cfg, params, m, v, step, lr, tokens)
        return (*new_p, *new_m, *new_v, l)

    train_in = (pspecs
                + [spec(f"m.{s['name']}", s["shape"]) for s in pspecs]
                + [spec(f"v.{s['name']}", s["shape"]) for s in pspecs]
                + [spec("step", (1, 1)), spec("lr", (1, 1)), tok(TRAIN_B)])

    def _train_wrap(*args):
        step = args[3 * n_params][0, 0]
        lr = args[3 * n_params + 1][0, 0]
        tokens = args[3 * n_params + 2]
        return _train(*args[:3 * n_params], step, lr, tokens)

    em.emit(f"train_step_{cfg.name}", _train_wrap, train_in,
            {**meta, "batch": TRAIN_B, "kind": "train_step"})

    # lm_loss(params, tokens)
    def _loss(*args):
        return (M.loss(cfg, list(args[:n_params]), args[n_params]),)

    em.emit(f"lm_loss_{cfg.name}", _loss, pspecs + [tok(EVAL_B)],
            {**meta, "batch": EVAL_B, "kind": "lm_loss"})

    # lm_fwd(params, tokens)
    def _fwd(*args):
        return (M.fwd(cfg, list(args[:n_params]), args[n_params]),)

    em.emit(f"lm_fwd_{cfg.name}", _fwd, pspecs + [tok(FWD_B)],
            {**meta, "batch": FWD_B, "kind": "lm_fwd"})

    if not with_compressed:
        return

    cspecs = compressed_param_inspecs(cfg)
    n_c = len(cspecs)

    # clm_fwd(cparams, tokens) — Pallas kernel on every linear.
    def _cfwd(*args):
        return (M.clm_fwd(cfg, list(args[:n_c]), args[n_c]),)

    em.emit(f"clm_fwd_{cfg.name}", _cfwd, cspecs + [tok(FWD_B)],
            {**meta, "batch": FWD_B, "kind": "clm_fwd", "n_cparams": n_c})

    # ft_step(cparams, m, v, step, lr, tokens) over adapters only.
    t_idx = M.trainable_adapter_indices(cfg)
    n_t = len(t_idx)
    tspecs = [cspecs[i] for i in t_idx]
    ft_in = (cspecs
             + [spec(f"m.{s['name']}", s["shape"]) for s in tspecs]
             + [spec(f"v.{s['name']}", s["shape"]) for s in tspecs]
             + [spec("step", (1, 1)), spec("lr", (1, 1)), tok(FT_B)])

    def _ft(*args):
        cparams = list(args[:n_c])
        m = list(args[n_c:n_c + n_t])
        v = list(args[n_c + n_t:n_c + 2 * n_t])
        step = args[n_c + 2 * n_t][0, 0]
        lr = args[n_c + 2 * n_t + 1][0, 0]
        tokens = args[n_c + 2 * n_t + 2]
        new_t, new_m, new_v, l = M.ft_step(cfg, cparams, m, v, step, lr, tokens)
        return (*new_t, *new_m, *new_v, l)

    em.emit(f"ft_step_{cfg.name}", _ft, ft_in,
            {**meta, "batch": FT_B, "kind": "ft_step", "n_cparams": n_c,
             "n_trainable": n_t, "trainable_indices": t_idx})


def emit_kernels(em):
    # Standalone fused compressed-linear kernel at two representative shapes.
    for (m, din, dout) in [(64, 256, 256), (64, 256, 1024)]:
        rank = max(1, round(0.1 * min(din, dout)))
        ins = [
            spec("x", (m, din)), spec("wq", (din, dout)), spec("scale", (1, 1)),
            spec("mask", (din, dout)), spec("l", (din, rank)), spec("r", (rank, dout)),
        ]

        def _k(x, wq, scale, mask, l, r):
            return (slim_matmul(x, wq, scale, mask, l, r),)

        em.emit(f"layer_fwd_{m}x{din}x{dout}r{rank}", _k, ins,
                {"kind": "layer_fwd", "m": m, "d_in": din, "d_out": dout, "rank": rank})

    # SLiM-Quant error scan.
    nbins, k = 2048, 64
    ins = [spec("centers", (1, nbins)), spec("pdf", (1, nbins)), spec("alphas", (1, k))]

    def _q(centers, pdf, alphas):
        return (quant_scan(centers, pdf, alphas),)

    em.emit("quant_scan", _q, ins, {"kind": "quant_scan", "nbins": nbins, "k": k})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default=",".join(c.name for c in M.FAMILY),
                    help="comma-separated config names")
    ap.add_argument("--compressed", default=",".join(QUICK),
                    help="configs that also get clm_fwd/ft_step graphs")
    args = ap.parse_args()

    em = Emitter(args.out)
    want_comp = set(filter(None, args.compressed.split(",")))
    for name in filter(None, args.configs.split(",")):
        cfg = M.by_name(name)
        print(f"[{name}]")
        emit_model(em, cfg, with_compressed=name in want_comp)
    print("[kernels]")
    emit_kernels(em)
    em.finish()


if __name__ == "__main__":
    sys.exit(main())
