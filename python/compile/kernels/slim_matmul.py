"""L1 Pallas kernel: fused compressed linear layer.

The paper's inference hot-spot (its Sparse-Marlin CUDA kernel) re-thought
for TPU-style execution (DESIGN.md §Hardware-Adaptation):

    y = x @ (dequant(Wq) * mask) + (x @ L) @ R

* ``wq`` arrives as integer codes stored in f32 (symmetric, codes in
  [-(2^{q-1}-1), 2^{q-1}-1]); dequant is a fused elementwise prologue on the
  VPU: ``w = wq * (alpha / levels) * mask``.
* The dense core targets the MXU: a [bm, d_in] x [d_in, bn] tile matmul with
  f32 accumulation (bf16-ready on real TPU).
* The low-rank branch reuses the same x tile: ``(x @ L) @ R`` adds two small
  MXU matmuls — rank r = 0.1 d keeps them <2% of the FLOPs.
* BlockSpec tiles: grid over (M/bm, N/bn); x and w tiles stream HBM→VMEM per
  grid step, exactly the role threadblock tiling plays in Marlin. With the
  default bm=bn=128 the VMEM footprint is x-tile 64KB + w-tile 64KB + out
  64KB + L/R ≪ 16MB.

CPU PJRT cannot execute Mosaic custom-calls, so ``interpret=True`` is
mandatory here; correctness is asserted against ``ref.py`` in pytest and the
kernel lowers into the same HLO the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (clamped to the problem size at call time).
BLOCK_M = 128
BLOCK_N = 128


def _kernel(x_ref, wq_ref, scale_ref, mask_ref, l_ref, r_ref, o_ref, *, levels):
    """One (bm, bn) output tile."""
    x = x_ref[...]                      # [bm, d_in]
    wq = wq_ref[...]                    # [d_in, bn]  (codes as f32)
    mask = mask_ref[...]                # [d_in, bn]
    alpha = scale_ref[0, 0]
    # Fused dequant prologue (VPU): codes -> weights, sparsity applied.
    w = wq * (alpha / levels) * mask
    # Dense MXU tile matmul, f32 accumulation.
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # Low-rank branch shares the x tile: two skinny MXU matmuls.
    xl = jnp.dot(x, l_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(xl, r_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _slim_matmul_vjp(x, wq, scale, mask, l, r, bits):
    return _slim_matmul_impl(x, wq, scale, mask, l, r, bits=bits)


def _vjp_fwd(x, wq, scale, mask, l, r, bits):
    y = _slim_matmul_impl(x, wq, scale, mask, l, r, bits=bits)
    return y, (x, wq, scale, mask, l, r)


def _vjp_bwd(bits, res, g):
    """Backward in plain jnp (the pallas_call primitive has no transpose
    rule in interpret mode). The compressed base weights get straight-
    through zero grads — they are frozen during PEFT (paper §3.4); the
    adapters get exact grads."""
    x, wq, scale, mask, l, r = res
    levels = float(2 ** (bits - 1) - 1)
    w = wq * (scale[0, 0] / levels) * mask
    dx = g @ w.T + (g @ r.T) @ l.T
    dl = x.T @ (g @ r.T)
    dr = (x @ l).T @ g
    zero = lambda a: jnp.zeros_like(a)
    return dx, zero(wq), zero(scale), zero(mask), dl, dr


_slim_matmul_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def slim_matmul(x, wq, scale, mask, l, r, *, bits=4, block_m=BLOCK_M, block_n=BLOCK_N):
    """Fused compressed linear: ``x @ (dequant(wq)*mask) + (x@l)@r``.

    Differentiable w.r.t. (x, l, r) via a custom VJP; the forward always
    runs the Pallas kernel.

    Args:
      x:     [m, d_in] f32 activations.
      wq:    [d_in, d_out] f32 integer codes.
      scale: [1, 1] f32 per-tensor scale (alpha).
      mask:  [d_in, d_out] f32 0/1 sparsity mask.
      l:     [d_in, rank] f32 left adapter.
      r:     [rank, d_out] f32 right adapter.
      bits:  quantization bit-width (levels = 2^{bits-1} - 1).
    Returns:
      [m, d_out] f32.
    """
    return _slim_matmul_vjp(x, wq, scale, mask, l, r, bits)


def _slim_matmul_impl(x, wq, scale, mask, l, r, *, bits=4, block_m=BLOCK_M, block_n=BLOCK_N):
    """The raw Pallas call (forward only)."""
    m, d_in = x.shape
    d_in2, d_out = wq.shape
    assert d_in == d_in2, (x.shape, wq.shape)
    rank = l.shape[1]
    assert l.shape == (d_in, rank) and r.shape == (rank, d_out)
    levels = float(2 ** (bits - 1) - 1)

    bm = min(block_m, m)
    bn = min(block_n, d_out)
    grid = (pl.cdiv(m, bm), pl.cdiv(d_out, bn))

    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_in), lambda i, j: (i, 0)),       # x row tile
            pl.BlockSpec((d_in, bn), lambda i, j: (0, j)),       # w col tile
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),           # alpha
            pl.BlockSpec((d_in, bn), lambda i, j: (0, j)),       # mask tile
            pl.BlockSpec((d_in, rank), lambda i, j: (0, 0)),     # L (resident)
            pl.BlockSpec((rank, bn), lambda i, j: (0, j)),       # R col tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, wq, scale, mask, l, r)


def dense_matmul_ref_shape(m, d_in, d_out, rank):
    """Shape helper used by aot.py manifests."""
    return dict(
        x=(m, d_in),
        wq=(d_in, d_out),
        scale=(1, 1),
        mask=(d_in, d_out),
        l=(d_in, rank),
        r=(rank, d_out),
        out=(m, d_out),
    )
