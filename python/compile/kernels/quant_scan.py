"""L1 Pallas kernel: SLiM-Quant error scan (paper Alg. 1 `EstimateError`).

Evaluates the probabilistic quantization objective

    E_Q(alpha) = E_quant(alpha) + E_clip(alpha)
               = sum_bins pdf(c) * err(c; alpha)^2

for a whole grid of candidate alphas in one launch. Each grid step owns one
alpha tile and reduces over the histogram (resident in VMEM — histograms are
<= 20k bins = 80KB, well under budget). The multigrid search in the Rust
pipeline calls this through the AOT artifact when offloading is enabled.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_A = 32  # alphas per grid step


def _kernel(centers_ref, pdf_ref, alphas_ref, o_ref, *, levels):
    centers = centers_ref[...]          # [1, nbins]
    pdf = pdf_ref[...]                  # [1, nbins]
    alphas = alphas_ref[...]            # [1, ba]
    # Broadcast: [ba, nbins]
    c = centers
    a = alphas.reshape(-1, 1)
    step = a / levels
    # In-range quantization error vs clip error (paper Eq. 5/6).
    q = jnp.round(c / jnp.maximum(step, 1e-30)) * step
    e_quant = jnp.where(c <= a, c - q, 0.0)
    e_clip = jnp.where(c > a, c - a, 0.0)
    err = (e_quant + e_clip) ** 2
    o_ref[...] = jnp.sum(err * pdf, axis=1).reshape(1, -1)


def quant_scan(centers, pdf, alphas, *, bits=4, block_a=BLOCK_A):
    """Expected reconstruction error per candidate alpha.

    Args:
      centers: [1, nbins] f32 histogram bin centers of |W|.
      pdf:     [1, nbins] f32 normalized bin mass.
      alphas:  [1, k] f32 candidate scales (must be > 0).
    Returns:
      [1, k] f32 errors E_quant + E_clip.
    """
    _, nbins = centers.shape
    _, k = alphas.shape
    levels = float(2 ** (bits - 1) - 1)
    ba = min(block_a, k)
    grid = (pl.cdiv(k, ba),)
    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nbins), lambda i: (0, 0)),
            pl.BlockSpec((1, nbins), lambda i: (0, 0)),
            pl.BlockSpec((1, ba), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, ba), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=True,
    )(centers, pdf, alphas)
