"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest asserts `slim_matmul == slim_matmul_ref` and `quant_scan ==
quant_scan_ref` over hypothesis-swept shapes/ranks/bitwidths; the Rust side
additionally cross-checks the AOT'd kernels against its own CPU
implementations.
"""

import jax.numpy as jnp


def slim_matmul_ref(x, wq, scale, mask, l, r, *, bits=4):
    """y = x @ (dequant(wq) * mask) + (x @ l) @ r, straight jnp."""
    levels = float(2 ** (bits - 1) - 1)
    w = wq * (scale[0, 0] / levels) * mask
    return x @ w + (x @ l) @ r


def quant_scan_ref(centers, pdf, alphas, *, bits=4):
    """E_quant + E_clip per alpha (paper Eq. 5-7), straight jnp."""
    levels = float(2 ** (bits - 1) - 1)
    c = centers  # [1, nbins]
    a = alphas.reshape(-1, 1)  # [k, 1]
    step = a / levels
    q = jnp.round(c / jnp.maximum(step, 1e-30)) * step
    e_quant = jnp.where(c <= a, c - q, 0.0)
    e_clip = jnp.where(c > a, c - a, 0.0)
    err = (e_quant + e_clip) ** 2
    return jnp.sum(err * pdf, axis=1).reshape(1, -1)


def fake_quant_ref(w, alpha, bits):
    """Symmetric fake-quant (matches rust quant::fake_quant_value)."""
    levels = float(2 ** (bits - 1) - 1)
    t = jnp.clip(w / alpha, -1.0, 1.0)
    return jnp.round(t * levels) * alpha / levels
