"""L2: the sim-family transformer in JAX (build-time only).

Matches ``rust/src/model/transformer.rs`` op-for-op (pre-LN, tanh-GELU,
LN eps 1e-5, causal softmax, tied embeddings, no attention biases) so the
native Rust forward and the AOT HLO agree numerically.

Entry points lowered by aot.py:
  * ``fwd(params, tokens)``        — logits [B, S, V] (dense weights)
  * ``loss(params, tokens)``       — mean next-token NLL
  * ``train_step(...)``            — fused AdamW pretraining step
  * ``clm_fwd(cparams, tokens)``   — compressed forward; every linear runs
    through the L1 Pallas kernel (quantized codes + mask + adapters)
  * ``ft_step(...)``               — PEFT: AdamW on adapters only, frozen
    compressed base weights (paper §3.4)

Parameter orders are mirrored in ``rust/src/model/weights.rs::param_order``
and ``runtime::marshal``; aot.py records them in the manifest.
"""

import jax
import jax.numpy as jnp

from .kernels.slim_matmul import slim_matmul

LN_EPS = 1e-5


# ───────────────────────── configs (mirror rust model::config) ──────────

class Config:
    def __init__(self, name, d_model, n_layers, n_heads, d_ff_ratio=4,
                 vocab=512, max_seq=64):
        self.name = name
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff_ratio = d_ff_ratio
        self.vocab = vocab
        self.max_seq = max_seq

    @property
    def d_ff(self):
        return self.d_model * self.d_ff_ratio

    @property
    def d_head(self):
        return self.d_model // self.n_heads


FAMILY = [
    Config("sim-125m", 64, 2, 2),
    Config("sim-350m", 96, 3, 3),
    Config("sim-1.3b", 128, 4, 4),
    Config("sim-2.7b", 160, 4, 4),
    Config("sim-6.7b", 192, 5, 4),
    Config("sim-13b", 224, 6, 4),
    Config("sim-llama-7b", 208, 5, 4),
    Config("sim-llama-13b", 256, 6, 4),
]


def by_name(name):
    for c in FAMILY:
        if c.name == name:
            return c
    raise KeyError(name)


LINEARS = ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.fc1", "mlp.fc2"]


def linear_shape(cfg, suffix):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "attn.wq": (d, d), "attn.wk": (d, d), "attn.wv": (d, d),
        "attn.wo": (d, d), "mlp.fc1": (d, ff), "mlp.fc2": (ff, d),
    }[suffix]


def adapter_rank(cfg, suffix):
    d_in, d_out = linear_shape(cfg, suffix)
    return max(1, round(0.1 * min(d_in, d_out)))


def param_specs(cfg):
    """Dense parameter order: [(name, shape)] — matches rust param_order."""
    d, ff = cfg.d_model, cfg.d_ff
    specs = [("embed.tok", (cfg.vocab, d)), ("embed.pos", (cfg.max_seq, d))]
    for b in range(cfg.n_layers):
        p = f"block{b}."
        specs += [
            (p + "ln1.g", (1, d)), (p + "ln1.b", (1, d)),
            (p + "attn.wq", (d, d)), (p + "attn.wk", (d, d)),
            (p + "attn.wv", (d, d)), (p + "attn.wo", (d, d)),
            (p + "ln2.g", (1, d)), (p + "ln2.b", (1, d)),
            (p + "mlp.fc1", (d, ff)), (p + "mlp.fc1_b", (1, ff)),
            (p + "mlp.fc2", (ff, d)), (p + "mlp.fc2_b", (1, d)),
        ]
    specs += [("final_ln.g", (1, d)), ("final_ln.b", (1, d))]
    return specs


def compressed_param_specs(cfg):
    """Compressed parameter order: each linear becomes 5 tensors
    (wq codes, scale, mask, l, r); everything else stays dense."""
    d, ff = cfg.d_model, cfg.d_ff
    specs = [("embed.tok", (cfg.vocab, d)), ("embed.pos", (cfg.max_seq, d))]
    for b in range(cfg.n_layers):
        p = f"block{b}."
        specs += [(p + "ln1.g", (1, d)), (p + "ln1.b", (1, d))]
        for lin in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]:
            din, dout = linear_shape(cfg, lin)
            r = adapter_rank(cfg, lin)
            specs += [
                (p + lin + ".wq", (din, dout)), (p + lin + ".scale", (1, 1)),
                (p + lin + ".mask", (din, dout)),
                (p + lin + ".l", (din, r)), (p + lin + ".r", (r, dout)),
            ]
        specs += [(p + "ln2.g", (1, d)), (p + "ln2.b", (1, d))]
        for lin, bias in [("mlp.fc1", (1, ff)), ("mlp.fc2", (1, d))]:
            din, dout = linear_shape(cfg, lin)
            r = adapter_rank(cfg, lin)
            specs += [
                (p + lin + ".wq", (din, dout)), (p + lin + ".scale", (1, 1)),
                (p + lin + ".mask", (din, dout)),
                (p + lin + ".l", (din, r)), (p + lin + ".r", (r, dout)),
            ]
            specs += [(p + lin + "_b", bias)]
    specs += [("final_ln.g", (1, d)), ("final_ln.b", (1, d))]
    return specs


def trainable_adapter_indices(cfg):
    """Indices into compressed_param_specs that are adapters (l, r) — the
    only tensors ft_step updates."""
    return [i for i, (n, _) in enumerate(compressed_param_specs(cfg))
            if n.endswith(".l") or n.endswith(".r")]


# ─────────────────────────── model ops ──────────────────────────────────

def layernorm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g[0] + b[0]


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def attention(cfg, h, wq, wk, wv, wo):
    """h: [B, S, d] (already layer-normed)."""
    B, S, d = h.shape
    nh, dh = cfg.n_heads, cfg.d_head

    def split(m):
        return m.reshape(B, S, nh, dh).transpose(0, 2, 1, 3)  # [B,nh,S,dh]

    q, k, v = split(h @ wq), split(h @ wk), split(h @ wv)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((S, S), dtype=bool))
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bhtd->bhsd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, S, d)
    return ctx @ wo


def _block_dense(cfg, x, p):
    h = layernorm(x, p["ln1.g"], p["ln1.b"])
    x = x + attention(cfg, h, p["attn.wq"], p["attn.wk"], p["attn.wv"], p["attn.wo"])
    h2 = layernorm(x, p["ln2.g"], p["ln2.b"])
    u = gelu(h2 @ p["mlp.fc1"] + p["mlp.fc1_b"][0])
    x = x + (u @ p["mlp.fc2"] + p["mlp.fc2_b"][0])
    return x


def fwd(cfg, params, tokens):
    """Dense forward. params: flat list in param_specs order. tokens: i32
    [B, S]. Returns logits [B, S, V]."""
    specs = param_specs(cfg)
    named = dict(zip([n for n, _ in specs], params))
    B, S = tokens.shape
    x = named["embed.tok"][tokens] + named["embed.pos"][:S][None, :, :]
    for b in range(cfg.n_layers):
        p = {k[len(f"block{b}."):]: v for k, v in named.items()
             if k.startswith(f"block{b}.")}
        x = _block_dense(cfg, x, p)
    x = layernorm(x, named["final_ln.g"], named["final_ln.b"])
    return x @ named["embed.tok"].T


def loss(cfg, params, tokens):
    """Mean next-token NLL (positions 0..S-2 predict 1..S-1)."""
    logits = fwd(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ─────────────────────── AdamW pretraining step ─────────────────────────

ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.999, 1e-8, 0.01


def train_step(cfg, params, m, v, step, lr, tokens):
    """One fused AdamW step. All of (params, m, v) are flat lists; `step`
    is the 1-based step count as f32 scalar; `lr` f32 scalar.
    Returns (new_params, new_m, new_v, loss)."""
    lval, grads = jax.value_and_grad(lambda ps: loss(cfg, ps, tokens))(params)
    b1t = ADAM_B1 ** step
    b2t = ADAM_B2 ** step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * g * g
        mhat = mi / (1 - b1t)
        vhat = vi / (1 - b2t)
        upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * p
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, lval


# ─────────────────── compressed forward (L1 kernel path) ────────────────

def _clinear(named, name, x2d, bits=4):
    """Apply one compressed linear via the Pallas kernel."""
    return slim_matmul(
        x2d,
        named[name + ".wq"], named[name + ".scale"], named[name + ".mask"],
        named[name + ".l"], named[name + ".r"], bits=bits,
    )


def clm_fwd(cfg, cparams, tokens, bits=4):
    """Compressed forward: logits [B, S, V]. Every linear layer runs the
    fused dequant+mask+low-rank Pallas kernel."""
    specs = compressed_param_specs(cfg)
    named = dict(zip([n for n, _ in specs], cparams))
    B, S = tokens.shape
    d = cfg.d_model
    x = named["embed.tok"][tokens] + named["embed.pos"][:S][None, :, :]

    def lin(name, h):
        out = _clinear(named, name, h.reshape(B * S, -1), bits=bits)
        return out.reshape(B, S, -1)

    for b in range(cfg.n_layers):
        p = f"block{b}."
        h = layernorm(x, named[p + "ln1.g"], named[p + "ln1.b"])
        q, k, v = lin(p + "attn.wq", h), lin(p + "attn.wk", h), lin(p + "attn.wv", h)
        nh, dh = cfg.n_heads, cfg.d_head

        def split(mm):
            return mm.reshape(B, S, nh, dh).transpose(0, 2, 1, 3)

        scores = jnp.einsum("bhsd,bhtd->bhst", split(q), split(k)) / jnp.sqrt(float(dh))
        causal = jnp.tril(jnp.ones((S, S), dtype=bool))
        scores = jnp.where(causal, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,bhtd->bhsd", probs, split(v))
        ctx = ctx.transpose(0, 2, 1, 3).reshape(B, S, d)
        x = x + lin(p + "attn.wo", ctx)

        h2 = layernorm(x, named[p + "ln2.g"], named[p + "ln2.b"])
        u = gelu(lin(p + "mlp.fc1", h2) + named[p + "mlp.fc1_b"][0])
        x = x + lin(p + "mlp.fc2", u) + named[p + "mlp.fc2_b"][0]
    x = layernorm(x, named["final_ln.g"], named["final_ln.b"])
    return x @ named["embed.tok"].T


def clm_loss(cfg, cparams, tokens, bits=4):
    logits = clm_fwd(cfg, cparams, tokens, bits=bits)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ───────────────── PEFT fine-tuning step (paper §3.4) ───────────────────

def ft_step(cfg, cparams, m, v, step, lr, tokens, bits=4):
    """AdamW on the adapters (l, r) only; compressed base weights frozen.
    `m`/`v` are optimizer state lists over the *trainable* subset, in the
    order of trainable_adapter_indices. Returns
    (new_trainables, new_m, new_v, loss)."""
    t_idx = trainable_adapter_indices(cfg)

    def loss_of(trainables):
        full = list(cparams)
        for i, t in zip(t_idx, trainables):
            full[i] = t
        return clm_loss(cfg, full, tokens, bits=bits)

    trainables = [cparams[i] for i in t_idx]
    lval, grads = jax.value_and_grad(loss_of)(trainables)
    b1t = ADAM_B1 ** step
    b2t = ADAM_B2 ** step
    new_t, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(trainables, grads, m, v):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * g * g
        mhat = mi / (1 - b1t)
        vhat = vi / (1 - b2t)
        new_t.append(p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS)))
        new_m.append(mi)
        new_v.append(vi)
    return new_t, new_m, new_v, lval
