//! Deterministic, seedable pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we carry our own PCG32 core plus
//! the distribution samplers the repo needs (uniform, Gaussian via
//! Box–Muller, Zipf for the synthetic corpus, categorical). Everything in the
//! repo that draws randomness takes an explicit `Pcg32` so experiments are
//! reproducible end to end from a single seed.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014). Small state, good statistical
/// quality, and `const`-friendly seeding.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u32) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal sample (Box–Muller, one branch cached would be
    /// marginally faster; we keep it allocation-free and simple).
    pub fn gauss(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 > 1e-12 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gauss()
    }

    /// Laplace sample with the given scale (models LLM weight tails better
    /// than Gaussian; used by synthetic-weight tests).
    pub fn laplace(&mut self, scale: f32) -> f32 {
        let u = self.f64() - 0.5;
        let s = if u >= 0.0 { 1.0 } else { -1.0 };
        (-s * (1.0 - 2.0 * u.abs()).ln() * scale as f64) as f32
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over `{0, .., n-1}` using inverse-CDF on a
/// cumulative table. Used by the synthetic corpus generator to get a
/// realistic long-tailed token frequency profile.
pub struct Zipf {
    cdf: Vec<f32>,
}

impl Zipf {
    /// Build a sampler over `n` items with exponent `s` (s≈1 for natural
    /// language).
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one rank (0 = most frequent).
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f32();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(100, 1.0);
        let mut r = Pcg32::seeded(9);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(21);
        let mut xs: Vec<u32> = (0..57).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(33);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }
}
