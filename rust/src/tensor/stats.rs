//! Histograms and distribution statistics.
//!
//! SLiM-Quant (paper Alg. 1) works on the histogram of |W|: the error
//! integrals `E_quant`/`E_clip` are evaluated by numerical integration over
//! the histogram bins, which shares error computation between all elements
//! falling into the same bin (paper Apx T). This module provides that
//! histogram plus a few generic summary statistics.

use super::Matrix;

/// Uniform-bin histogram over `[0, max]`.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bin centers, `len = bins`.
    pub centers: Vec<f32>,
    /// Normalized mass per bin (sums to 1 unless the input was empty).
    pub pdf: Vec<f32>,
    /// Bin width.
    pub width: f32,
    /// Upper edge of the histogram (max observed value).
    pub max: f32,
}

/// Histogram of `|x|` over the matrix with the paper's bin-count rule:
/// `max(512, min(numel/1000, 20_000))`.
pub fn histogram(w: &Matrix) -> Histogram {
    let bins = paper_bin_count(w.len());
    histogram_with_bins(w.data(), bins)
}

/// The bin-count rule from paper Apx T.
pub fn paper_bin_count(numel: usize) -> usize {
    (numel / 1000).clamp(512, 20_000)
}

/// Histogram of `|x|` with an explicit bin count.
pub fn histogram_with_bins(data: &[f32], bins: usize) -> Histogram {
    assert!(bins > 0);
    let max = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max == 0.0 || data.is_empty() {
        return Histogram {
            centers: (0..bins).map(|i| (i as f32 + 0.5) / bins as f32).collect(),
            pdf: vec![0.0; bins],
            width: 1.0 / bins as f32,
            max: 0.0,
        };
    }
    let width = max / bins as f32;
    let mut counts = vec![0u64; bins];
    for &x in data {
        let b = ((x.abs() / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let n = data.len() as f32;
    Histogram {
        centers: (0..bins).map(|i| (i as f32 + 0.5) * width).collect(),
        pdf: counts.iter().map(|&c| c as f32 / n).collect(),
        width,
        max,
    }
}

impl Histogram {
    /// Number of bins.
    pub fn bins(&self) -> usize {
        self.centers.len()
    }

    /// Mean of the represented |x| distribution.
    pub fn mean(&self) -> f32 {
        self.centers
            .iter()
            .zip(self.pdf.iter())
            .map(|(&c, &p)| c * p)
            .sum()
    }
}

/// Summary statistics over a slice (mean, std, min, max) with f64
/// accumulation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub mean: f32,
    pub std: f32,
    pub min: f32,
    pub max: f32,
}

/// Compute [`Summary`] statistics.
pub fn summary(data: &[f32]) -> Summary {
    if data.is_empty() {
        return Summary { mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = data.len() as f64;
    let mean = data.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in data {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    Summary { mean: mean as f32, std: var.sqrt() as f32, min: lo, max: hi }
}

/// Kurtosis (Fisher, excess) — used to characterize weight-tail heaviness in
/// the quantizer diagnostics.
pub fn kurtosis(data: &[f32]) -> f32 {
    let s = summary(data);
    if s.std == 0.0 || data.is_empty() {
        return 0.0;
    }
    let n = data.len() as f64;
    let m = s.mean as f64;
    let sd = s.std as f64;
    let m4 = data.iter().map(|&x| ((x as f64 - m) / sd).powi(4)).sum::<f64>() / n;
    (m4 - 3.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn bin_count_rule() {
        assert_eq!(paper_bin_count(1000), 512);
        assert_eq!(paper_bin_count(1_000_000), 1000);
        assert_eq!(paper_bin_count(100_000_000), 20_000);
    }

    #[test]
    fn histogram_mass_sums_to_one() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(100, 100, 0.5, &mut rng);
        let h = histogram(&w);
        let total: f32 = h.pdf.iter().sum();
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
        assert!(h.max > 0.0);
    }

    #[test]
    fn histogram_locates_mass() {
        // All values equal → all mass in the last bin.
        let data = vec![2.0f32; 100];
        let h = histogram_with_bins(&data, 10);
        assert!((h.pdf[9] - 1.0).abs() < 1e-6);
        assert_eq!(h.max, 2.0);
    }

    #[test]
    fn histogram_of_zeros() {
        let h = histogram_with_bins(&[0.0; 10], 8);
        assert_eq!(h.max, 0.0);
        assert!(h.pdf.iter().all(|&p| p == 0.0));
    }

    #[test]
    fn histogram_mean_close_to_abs_mean() {
        let mut rng = Pcg32::seeded(2);
        let data: Vec<f32> = (0..100_000).map(|_| rng.gauss()).collect();
        let h = histogram_with_bins(&data, 1000);
        let abs_mean = data.iter().map(|x| x.abs()).sum::<f32>() / data.len() as f32;
        assert!((h.mean() - abs_mean).abs() < 0.01, "{} vs {}", h.mean(), abs_mean);
    }

    #[test]
    fn summary_basic() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-6);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn gaussian_kurtosis_near_zero() {
        let mut rng = Pcg32::seeded(3);
        let data: Vec<f32> = (0..50_000).map(|_| rng.gauss()).collect();
        assert!(kurtosis(&data).abs() < 0.15);
    }

    #[test]
    fn laplace_kurtosis_positive() {
        let mut rng = Pcg32::seeded(4);
        let data: Vec<f32> = (0..50_000).map(|_| rng.laplace(1.0)).collect();
        assert!(kurtosis(&data) > 1.5, "laplace excess kurtosis should be ~3");
    }
}
