//! Dense row-major f32 matrices — the numeric substrate for the whole
//! compression pipeline.
//!
//! The vendored crate set has no ndarray/nalgebra, so this module carries a
//! small, fast `Matrix` type: row-major `Vec<f32>` storage, a blocked and
//! threaded matmul tuned for the sizes the pipeline uses (≤ a few thousand),
//! and the reductions the compression algorithms need (norms, column stats,
//! histograms).

mod ops;
mod stats;

pub use ops::{matmul, matmul_at_b, matmul_a_bt, matmul_half};
pub(crate) use ops::{gemm, gemm_abt, gemm_abt_half, gemm_half, num_threads, PAR_THRESHOLD};
pub use stats::{
    histogram, histogram_with_bins, kurtosis, paper_bin_count, summary, Histogram, Summary,
};

use crate::rng::Pcg32;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{}, ‖·‖={:.4})", self.rows, self.cols, self.fro_norm())
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape {}x{} vs len {}", rows, cols, data.len());
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Identity-like matrix (1.0 on the main diagonal).
    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix with the given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg32) -> Self {
        let data = (0..rows * cols).map(|_| rng.gauss() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw row-major buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
    }

    /// Elementwise binary op into a new matrix.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// self + other.
    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    /// self - other.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a * b)
    }

    /// Scale by a constant.
    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|x| x * s)
    }

    /// In-place `self += s * other` (axpy).
    pub fn axpy(&mut self, s: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
    }

    /// Maximum |x| over all elements.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Per-column mean of |x| (activation statistics use this).
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (a, &x) in acc.iter_mut().zip(row.iter()) {
                *a += x.abs() as f64;
            }
        }
        acc.iter().map(|&a| (a / self.rows as f64) as f32).collect()
    }

    /// Per-column L2 norm (Wanda's activation metric).
    pub fn col_l2_norm(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (a, &x) in acc.iter_mut().zip(row.iter()) {
                *a += (x as f64) * (x as f64);
            }
        }
        acc.iter().map(|&a| a.sqrt() as f32).collect()
    }

    /// Multiply each row i by `d[i]` — i.e. `diag(d) · self`.
    pub fn scale_rows(&self, d: &[f32]) -> Matrix {
        assert_eq!(d.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let s = d[i];
            for x in out.row_mut(i) {
                *x *= s;
            }
        }
        out
    }

    /// Multiply each column j by `d[j]` — i.e. `self · diag(d)`.
    pub fn scale_cols(&self, d: &[f32]) -> Matrix {
        assert_eq!(d.len(), self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            for (x, &s) in out.row_mut(i).iter_mut().zip(d.iter()) {
                *x *= s;
            }
        }
        out
    }

    /// Extract a sub-block (row range, col range) as a copy.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write a sub-block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Matrix) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            self.row_mut(r0 + i)[c0..c0 + b.cols].copy_from_slice(b.row(i));
        }
    }

    /// Fraction of exactly-zero entries.
    pub fn sparsity(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f32 / self.data.len() as f32
    }

    /// Matrix product `self · other` (threaded, blocked).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        ops::matmul(self, other)
    }

    /// Relative Frobenius distance ‖self − other‖ / ‖other‖.
    pub fn rel_err(&self, other: &Matrix) -> f32 {
        let denom = other.fro_norm().max(1e-12);
        self.sub(other).fro_norm() / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Pcg32::seeded(1);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data(), &[5.0, 5.0, 5.0, 5.0]);
        assert_eq!(a.sub(&b).data(), &[-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.hadamard(&b).data(), &[4.0, 6.0, 6.0, 4.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn norms_and_stats() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
        assert_eq!(m.sparsity(), 0.5);
        assert!((m.mean() - 1.75).abs() < 1e-6);
    }

    #[test]
    fn col_stats() {
        let m = Matrix::from_vec(2, 3, vec![1.0, -2.0, 2.0, -3.0, 2.0, 0.0]);
        let am = m.col_abs_mean();
        assert_eq!(am, vec![2.0, 2.0, 1.0]);
        let l2 = m.col_l2_norm();
        assert!((l2[0] - (10f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn diag_scaling() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let r = m.scale_rows(&[2.0, 10.0]);
        assert_eq!(r.data(), &[2.0, 4.0, 30.0, 40.0]);
        let c = m.scale_cols(&[2.0, 10.0]);
        assert_eq!(c.data(), &[2.0, 20.0, 6.0, 40.0]);
    }

    #[test]
    fn blocks() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let b = m.block(1, 3, 2, 4);
        assert_eq!(b.data(), &[6.0, 7.0, 10.0, 11.0]);
        let mut m2 = Matrix::zeros(4, 4);
        m2.set_block(1, 2, &b);
        assert_eq!(m2.get(2, 3), 11.0);
        assert_eq!(m2.get(0, 0), 0.0);
    }

    #[test]
    fn axpy_works() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[1.5, 2.5, 3.5]);
    }
}
