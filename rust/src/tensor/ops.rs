//! Matrix products: blocked, threaded, f32.
//!
//! Loop order (i, k, j) keeps the B-row and C-row accesses contiguous so the
//! compiler auto-vectorizes the inner loop; rows of the output are
//! partitioned across `std::thread::scope` workers. These serve both the
//! compression pipeline (Hessians, saliency, SVD steps) and the measured
//! dense baseline in the speedup experiments.

use super::Matrix;

/// Threshold (in f32 multiply-adds) below which threading is not worth it.
/// Shared with the packed kernels in [`crate::kernels`] so they parallelize
/// at the same sizes as this dense baseline.
pub(crate) const PAR_THRESHOLD: usize = 64 * 64 * 64;

pub(crate) fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Serial row-major GEMM `out = A·B` over raw slices (`a`: m×k, `b`: k×n,
/// `out`: m×n, assumed zero-initialized). The (i, k, j) loop order keeps the
/// B-row and out-row accesses contiguous for auto-vectorization; exact-zero
/// A entries are skipped (pruned weights and masked attention probabilities
/// cost nothing). This is the inner kernel both [`matmul`]'s threaded row
/// chunks and the blocked attention tiles (`model::attention`) run on.
pub(crate) fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
        }
    }
}

/// Serial `out = A·Bᵀ` over raw slices (`a`: m×k, `b`: n×k, `out`: m×n) —
/// dot-product form; both operands are walked row-wise, so it is
/// cache-friendly on row-major tiles. Shared by [`matmul_a_bt`]'s threaded
/// row chunks and the attention score tiles (`model::attention`).
pub(crate) fn gemm_abt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let flops = m * k * n;
    let a_data = a.data();
    let b_data = b.data();

    let kernel = |rows: std::ops::Range<usize>, out: &mut [f32]| {
        // out covers rows `rows` of C, row-major, n columns each.
        gemm(&a_data[rows.start * k..rows.end * k], b_data, rows.end - rows.start, k, n, out);
    };

    if flops < PAR_THRESHOLD || m < 2 {
        kernel(0..m, c.data_mut());
        return c;
    }

    let nt = num_threads().min(m);
    let chunk = m.div_ceil(nt);
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            let range = start..end;
            s.spawn(move || kernel(range, head));
            start = end;
        }
    });
    c
}

/// C = Aᵀ · B without materializing Aᵀ (used for Hessian `XᵀX` and
/// saliency products where A is a tall activation matrix).
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "AᵀB shape mismatch: {:?} {:?}", a.shape(), b.shape());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    // Accumulate rank-1 updates row-by-row of A/B: C += a_rowᵀ · b_row.
    // Parallelize across column-blocks of C to avoid write contention.
    let nt = if m * n * k < PAR_THRESHOLD { 1 } else { num_threads().min(m) };
    let chunk = m.div_ceil(nt);
    let a_data = a.data();
    let b_data = b.data();
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            s.spawn(move || {
                for r in 0..k {
                    let arow = &a_data[r * m..(r + 1) * m];
                    let brow = &b_data[r * n..(r + 1) * n];
                    for (ri, i) in (start..end).enumerate() {
                        let av = arow[i];
                        if av == 0.0 {
                            continue;
                        }
                        let crow = &mut head[ri * n..(ri + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            });
            start = end;
        }
    });
    c
}

/// C = A · Bᵀ without materializing Bᵀ (dot-product form; both operands are
/// walked row-wise so it is cache-friendly when B is row-major).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "ABᵀ shape mismatch: {:?} {:?}", a.shape(), b.shape());
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    let nt = if m * n * k < PAR_THRESHOLD { 1 } else { num_threads().min(m) };
    let chunk = m.div_ceil(nt);
    let a_data = a.data();
    let b_data = b.data();
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            s.spawn(move || {
                gemm_abt(&a_data[start * k..end * k], b_data, end - start, k, n, head);
            });
            start = end;
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for k in 0..a.cols() {
                    acc += a.get(i, k) as f64 * b.get(k, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Pcg32::seeded(42);
        for &(m, k, n) in &[(5usize, 7usize, 3usize), (64, 64, 64), (33, 129, 65), (200, 50, 120)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            assert!(c.rel_err(&r) < 1e-5, "({m},{k},{n}) err {}", c.rel_err(&r));
        }
    }

    #[test]
    fn matmul_threaded_path() {
        // Big enough to cross PAR_THRESHOLD.
        let mut rng = Pcg32::seeded(43);
        let a = Matrix::randn(128, 96, 1.0, &mut rng);
        let b = Matrix::randn(96, 112, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(44);
        let a = Matrix::randn(70, 40, 1.0, &mut rng);
        let b = Matrix::randn(70, 30, 1.0, &mut rng);
        let c = matmul_at_b(&a, &b);
        let r = matmul(&a.transpose(), &b);
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(45);
        let a = Matrix::randn(50, 60, 1.0, &mut rng);
        let b = Matrix::randn(35, 60, 1.0, &mut rng);
        let c = matmul_a_bt(&a, &b);
        let r = matmul(&a, &b.transpose());
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(46);
        let a = Matrix::randn(20, 20, 1.0, &mut rng);
        let i = Matrix::eye(20);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-6);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-6);
    }
}
