//! Matrix products: blocked, threaded, f32 — plus half-operand variants.
//!
//! Loop order (i, k, j) keeps the B-row and C-row accesses contiguous so the
//! compiler auto-vectorizes the inner loop; rows of the output are
//! partitioned across `std::thread::scope` workers. These serve both the
//! compression pipeline (Hessians, saliency, SVD steps) and the measured
//! dense baseline in the speedup experiments.
//!
//! The `*_half` variants ([`gemm_half`], [`gemm_abt_half`], [`matmul_half`])
//! read the B operand as 16-bit half-precision codes (f16 or bf16 — the
//! caller passes the scalar decoder, keeping this module independent of
//! `quant`) and **accumulate in f32**, in exactly the same loop order as
//! their f32 twins. Decoding inline halves the memory traffic on the
//! bandwidth-bound decode path (half-width KV tiles, half-storage dense and
//! adapter weights) while producing bit-identical results to
//! decode-to-scratch followed by the f32 kernel.

use super::Matrix;

/// Threshold (in f32 multiply-adds) below which threading is not worth it.
/// Shared with the packed kernels in [`crate::kernels`] so they parallelize
/// at the same sizes as this dense baseline.
pub(crate) const PAR_THRESHOLD: usize = 64 * 64 * 64;

pub(crate) fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Serial row-major GEMM `out = A·B` over raw slices (`a`: m×k, `b`: k×n,
/// `out`: m×n, assumed zero-initialized). The (i, k, j) loop order keeps the
/// B-row and out-row accesses contiguous for auto-vectorization; exact-zero
/// A entries are skipped (pruned weights and masked attention probabilities
/// cost nothing). This is the inner kernel both [`matmul`]'s threaded row
/// chunks and the blocked attention tiles (`model::attention`) run on.
pub(crate) fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
        }
    }
}

/// Serial `out = A·Bᵀ` over raw slices (`a`: m×k, `b`: n×k, `out`: m×n) —
/// dot-product form; both operands are walked row-wise, so it is
/// cache-friendly on row-major tiles. Shared by [`matmul_a_bt`]'s threaded
/// row chunks and the attention score tiles (`model::attention`).
pub(crate) fn gemm_abt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// [`gemm`] with a half-width B: `out = A·decode(B)` (`a`: m×k f32, `b`:
/// k×n 16-bit codes, `out`: m×n f32, assumed zero-initialized). Same
/// (i, k, j) loop order and zero-`A` skip as [`gemm`]; B elements are
/// decoded inline (each code is touched once per A-row), so the result is
/// bit-identical to decoding B to a scratch f32 buffer and calling [`gemm`]
/// — without the scratch traffic. Backs the half-precision P·V attention
/// tiles and the half-storage dense kernel.
pub(crate) fn gemm_half(
    a: &[f32],
    b: &[u16],
    m: usize,
    k: usize,
    n: usize,
    decode: impl Fn(u16) -> f32 + Copy,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * decode(bv);
            }
        }
    }
}

/// [`gemm_abt`] with a half-width B: `out = A·decode(B)ᵀ` (`a`: m×k f32,
/// `b`: n×k 16-bit codes, `out`: m×n f32). Dot-product form with inline
/// decode; bit-identical to decode-then-[`gemm_abt`]. Backs the
/// half-precision Q·Kᵀ attention score tiles.
pub(crate) fn gemm_abt_half(
    a: &[f32],
    b: &[u16],
    m: usize,
    k: usize,
    n: usize,
    decode: impl Fn(u16) -> f32 + Copy,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * decode(bv);
            }
            out[i * n + j] = acc;
        }
    }
}

/// C = A · decode(B) where B is a k×n row-major slice of 16-bit half codes
/// — the threaded entry point for half-storage weight matrices
/// (`kernels::dense::HalfDenseKernel`). Mirrors [`matmul`]'s row-chunk
/// partitioning; `decode` is a plain `fn` pointer so dispatch happens once
/// per call.
pub fn matmul_half(a: &Matrix, b: &[u16], k: usize, n: usize, decode: fn(u16) -> f32) -> Matrix {
    assert_eq!(a.cols(), k, "matmul_half shape mismatch: {:?} x {k}x{n}", a.shape());
    assert_eq!(b.len(), k * n, "matmul_half B len {} vs {k}x{n}", b.len());
    let m = a.rows();
    let mut c = Matrix::zeros(m, n);
    let flops = m * k * n;
    let a_data = a.data();

    let kernel = |rows: std::ops::Range<usize>, out: &mut [f32]| {
        gemm_half(
            &a_data[rows.start * k..rows.end * k],
            b,
            rows.end - rows.start,
            k,
            n,
            decode,
            out,
        );
    };

    if flops < PAR_THRESHOLD || m < 2 {
        kernel(0..m, c.data_mut());
        return c;
    }

    let nt = num_threads().min(m);
    let chunk = m.div_ceil(nt);
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            let range = start..end;
            s.spawn(move || kernel(range, head));
            start = end;
        }
    });
    c
}

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let flops = m * k * n;
    let a_data = a.data();
    let b_data = b.data();

    let kernel = |rows: std::ops::Range<usize>, out: &mut [f32]| {
        // out covers rows `rows` of C, row-major, n columns each.
        gemm(&a_data[rows.start * k..rows.end * k], b_data, rows.end - rows.start, k, n, out);
    };

    if flops < PAR_THRESHOLD || m < 2 {
        kernel(0..m, c.data_mut());
        return c;
    }

    let nt = num_threads().min(m);
    let chunk = m.div_ceil(nt);
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            let range = start..end;
            s.spawn(move || kernel(range, head));
            start = end;
        }
    });
    c
}

/// C = Aᵀ · B without materializing Aᵀ (used for Hessian `XᵀX` and
/// saliency products where A is a tall activation matrix).
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "AᵀB shape mismatch: {:?} {:?}", a.shape(), b.shape());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    // Accumulate rank-1 updates row-by-row of A/B: C += a_rowᵀ · b_row.
    // Parallelize across column-blocks of C to avoid write contention.
    let nt = if m * n * k < PAR_THRESHOLD { 1 } else { num_threads().min(m) };
    let chunk = m.div_ceil(nt);
    let a_data = a.data();
    let b_data = b.data();
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            s.spawn(move || {
                for r in 0..k {
                    let arow = &a_data[r * m..(r + 1) * m];
                    let brow = &b_data[r * n..(r + 1) * n];
                    for (ri, i) in (start..end).enumerate() {
                        let av = arow[i];
                        if av == 0.0 {
                            continue;
                        }
                        let crow = &mut head[ri * n..(ri + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            });
            start = end;
        }
    });
    c
}

/// C = A · Bᵀ without materializing Bᵀ (dot-product form; both operands are
/// walked row-wise so it is cache-friendly when B is row-major).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "ABᵀ shape mismatch: {:?} {:?}", a.shape(), b.shape());
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    let nt = if m * n * k < PAR_THRESHOLD { 1 } else { num_threads().min(m) };
    let chunk = m.div_ceil(nt);
    let a_data = a.data();
    let b_data = b.data();
    let cdata = c.data_mut();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        while start < m {
            let end = (start + chunk).min(m);
            let (head, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            s.spawn(move || {
                gemm_abt(&a_data[start * k..end * k], b_data, end - start, k, n, head);
            });
            start = end;
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for k in 0..a.cols() {
                    acc += a.get(i, k) as f64 * b.get(k, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Pcg32::seeded(42);
        for &(m, k, n) in &[(5usize, 7usize, 3usize), (64, 64, 64), (33, 129, 65), (200, 50, 120)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            assert!(c.rel_err(&r) < 1e-5, "({m},{k},{n}) err {}", c.rel_err(&r));
        }
    }

    #[test]
    fn matmul_threaded_path() {
        // Big enough to cross PAR_THRESHOLD.
        let mut rng = Pcg32::seeded(43);
        let a = Matrix::randn(128, 96, 1.0, &mut rng);
        let b = Matrix::randn(96, 112, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(44);
        let a = Matrix::randn(70, 40, 1.0, &mut rng);
        let b = Matrix::randn(70, 30, 1.0, &mut rng);
        let c = matmul_at_b(&a, &b);
        let r = matmul(&a.transpose(), &b);
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(45);
        let a = Matrix::randn(50, 60, 1.0, &mut rng);
        let b = Matrix::randn(35, 60, 1.0, &mut rng);
        let c = matmul_a_bt(&a, &b);
        let r = matmul(&a, &b.transpose());
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn half_gemms_match_decode_then_f32() {
        use crate::quant::half::{encode_vec, HalfKind};
        let mut rng = Pcg32::seeded(47);
        for kind in [HalfKind::F16, HalfKind::Bf16] {
            let dec = kind.decoder();
            let (m, k, n) = (7usize, 13usize, 9usize);
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let bf: Vec<f32> = (0..k * n).map(|_| rng.gauss()).collect();
            let bits = encode_vec(kind, &bf);
            // Decode-to-scratch reference.
            let scratch: Vec<f32> = bits.iter().map(|&h| dec(h)).collect();

            let mut want = vec![0.0f32; m * n];
            gemm(a.data(), &scratch, m, k, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm_half(a.data(), &bits, m, k, n, dec, &mut got);
            assert_eq!(got, want, "gemm_half {kind:?}");

            // ABᵀ form: reinterpret the same bits as n×k.
            let mut want_t = vec![0.0f32; m * n];
            gemm_abt(a.data(), &scratch[..n * k], m, k, n, &mut want_t);
            let mut got_t = vec![0.0f32; m * n];
            gemm_abt_half(a.data(), &bits[..n * k], m, k, n, dec, &mut got_t);
            assert_eq!(got_t, want_t, "gemm_abt_half {kind:?}");
        }
    }

    #[test]
    fn matmul_half_matches_threaded_f32() {
        use crate::quant::half::{encode_vec, HalfKind};
        let mut rng = Pcg32::seeded(48);
        // Big enough to cross PAR_THRESHOLD so the threaded path runs.
        let (m, k, n) = (96usize, 80usize, 64usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let bf: Vec<f32> = (0..k * n).map(|_| rng.gauss()).collect();
        for kind in [HalfKind::F16, HalfKind::Bf16] {
            let bits = encode_vec(kind, &bf);
            let dec = kind.decoder();
            let scratch: Vec<f32> = bits.iter().map(|&h| dec(h)).collect();
            let want = matmul(&a, &Matrix::from_vec(k, n, scratch));
            let got = matmul_half(&a, &bits, k, n, dec);
            assert_eq!(got.data(), want.data(), "{kind:?}");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(46);
        let a = Matrix::randn(20, 20, 1.0, &mut rng);
        let i = Matrix::eye(20);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-6);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-6);
    }
}
