//! One-shot low-rank adapters (paper §3.2–§3.3) — the core contribution.
//!
//! Given original weights `W` and compressed weights `W^C` (quantized +
//! pruned), all methods compute `L ∈ R^{d_in×r}, R ∈ R^{r×d_out}` such that
//! `W ≈ W^C + L·R`, without any training:
//!
//! * [`naive`] — **Naive-LoRA**: truncated SVD of the raw error `W − W^C`.
//! * [`slim_lora`] — **SLiM-LoRA** (Alg. 2): truncated SVD of the
//!   *saliency-transformed* error `F(W − W^C) = diag(x)(W − W^C)`, then the
//!   inverse transform recovers `L`. `F` is invertible and additive, which
//!   is what makes the closed form valid (Eq. 8–11).
//! * [`l2qer`] — **L²QER**: like SLiM-LoRA but compensating *only* the
//!   quantization error — the reason it underperforms under joint
//!   sparsity+quantization in Table 1.
//! * [`adapter_quant`] — §3.3: group-AbsMax 4-bit quantization of L and R
//!   (`SLiM-LoRA^Q`).

pub mod adapter_quant;
pub mod l2qer;
pub mod naive;
pub mod slim_lora;

use crate::tensor::Matrix;

/// Which adapter method to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoraMethod {
    /// No adapters.
    None,
    /// Naive-LoRA: plain SVD of the error.
    Naive,
    /// SLiM-LoRA: saliency-weighted SVD (the paper's method).
    Slim,
    /// L²QER: saliency SVD of the quantization error only.
    L2qer,
}

impl LoraMethod {
    pub fn parse(s: &str) -> Option<LoraMethod> {
        Some(match s {
            "none" => LoraMethod::None,
            "naive" | "naive-lora" => LoraMethod::Naive,
            "slim" | "slim-lora" => LoraMethod::Slim,
            "l2qer" => LoraMethod::L2qer,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LoraMethod::None => "none",
            LoraMethod::Naive => "Naive-LoRA",
            LoraMethod::Slim => "SLiM-LoRA",
            LoraMethod::L2qer => "L2QER",
        }
    }
}

/// A computed adapter pair.
#[derive(Clone, Debug)]
pub struct Adapters {
    /// Left adapter, d_in × r.
    pub l: Matrix,
    /// Right adapter, r × d_out.
    pub r: Matrix,
}

impl Adapters {
    /// The dense correction `L·R`.
    pub fn product(&self) -> Matrix {
        self.l.matmul(&self.r)
    }

    /// Adapter rank.
    pub fn rank(&self) -> usize {
        self.l.cols()
    }

    /// Parameter count of both factors.
    pub fn param_count(&self) -> usize {
        self.l.len() + self.r.len()
    }
}

/// Paper default: adapter rank = 10% of the hidden dimension (Apx T).
pub fn default_rank(d_in: usize, d_out: usize) -> usize {
    ((d_in.min(d_out) as f64) * 0.1).round().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_rank_defaults() {
        assert_eq!(LoraMethod::parse("slim-lora"), Some(LoraMethod::Slim));
        assert_eq!(LoraMethod::parse("x"), None);
        assert_eq!(default_rank(256, 512), 26);
        assert_eq!(default_rank(4, 4), 1);
    }
}
