//! Naive-LoRA: adapters from the plain SVD of the compression error.
//!
//! Minimizes `‖W − (W^C + L·R)‖_F` — optimal in the unweighted norm by
//! Eckart–Young, but blind to which weights matter for the model's outputs
//! (the paper's motivation for SLiM-LoRA).

use super::Adapters;
use crate::linalg::randomized_svd;
use crate::rng::Pcg32;
use crate::tensor::Matrix;

/// Compute rank-`r` adapters for error `W − W^C`.
pub fn adapters(w: &Matrix, wc: &Matrix, rank: usize) -> Adapters {
    let err = w.sub(wc);
    let mut rng = Pcg32::seeded(0x4e41_49e5);
    let svd = randomized_svd(&err, rank, 8, 2, &mut rng);
    let (l, r) = svd.split_balanced();
    Adapters { l, r }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduces_error() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(64, 48, 0.1, &mut rng);
        let wc = w.map(|x| if x.abs() < 0.05 { 0.0 } else { x }); // fake compression
        let a = adapters(&w, &wc, 8);
        let before = wc.sub(&w).fro_norm_sq();
        let after = wc.add(&a.product()).sub(&w).fro_norm_sq();
        assert!(after < before, "after {after} before {before}");
    }

    #[test]
    fn exact_when_error_is_low_rank() {
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::randn(40, 30, 0.1, &mut rng);
        let u = Matrix::randn(40, 3, 0.1, &mut rng);
        let v = Matrix::randn(3, 30, 0.1, &mut rng);
        let wc = w.sub(&u.matmul(&v)); // error is exactly rank 3
        let a = adapters(&w, &wc, 3);
        let resid = wc.add(&a.product()).rel_err(&w);
        assert!(resid < 1e-3, "resid {resid}");
    }

    #[test]
    fn higher_rank_monotone() {
        let mut rng = Pcg32::seeded(3);
        let w = Matrix::randn(64, 64, 0.1, &mut rng);
        let wc = w.map(|x| (x * 8.0).round() / 8.0); // quantization-ish error
        let mut prev = f64::INFINITY;
        for rank in [2usize, 6, 16, 32] {
            let a = adapters(&w, &wc, rank);
            let e = wc.add(&a.product()).sub(&w).fro_norm_sq();
            assert!(e <= prev * 1.02, "rank {rank}: {e} vs {prev}");
            prev = e;
        }
    }
}
