//! SLiM-LoRA (paper §3.2, Algorithm 2): saliency-based one-shot adapters.
//!
//! The saliency function `F(W) = diag(x)·W` is **additive**
//! (`F(A+B) = F(A)+F(B)`) and **invertible** (x is shifted away from zero),
//! so the optimal adapters in the saliency norm have the closed form
//!
//! ```text
//!   S_C = diag(x)·(W − W^C)          // saliency of the compression error
//!   Ũ Σ Ṽᵀ = SVD_r(S_C)
//!   L = diag(1/x)·Ũ·√Σ ,  R = √Σ·Ṽᵀ
//! ```
//!
//! which minimizes `‖F(W − (W^C + L·R))‖_F` (Eq. 8–11). `x` is the mean
//! absolute calibration activation per input channel, shifted by its own
//! minimum to guarantee invertibility (Alg. 2 line 5).

use super::Adapters;
use crate::linalg::randomized_svd;
use crate::rng::Pcg32;
use crate::tensor::Matrix;

/// The shifted saliency vector of Algorithm 2: `x = x̃ + min(|x̃|) + ε`.
pub fn saliency_vector(x_abs_mean: &[f32]) -> Vec<f32> {
    let min_abs = x_abs_mean.iter().fold(f32::INFINITY, |m, &v| m.min(v.abs()));
    let min_abs = if min_abs.is_finite() { min_abs } else { 0.0 };
    // ε keeps F invertible even when the whole vector is zero.
    let eps = 1e-6f32;
    x_abs_mean.iter().map(|&v| v + min_abs + eps).collect()
}

/// Compute rank-`r` SLiM-LoRA adapters.
///
/// * `w` — original weights (d_in × d_out)
/// * `wc` — compressed weights (quantized + pruned)
/// * `x_abs_mean` — per-input-channel mean |activation| from calibration
pub fn adapters(w: &Matrix, wc: &Matrix, x_abs_mean: &[f32], rank: usize) -> Adapters {
    assert_eq!(x_abs_mean.len(), w.rows(), "saliency vector must match d_in");
    let x = saliency_vector(x_abs_mean);
    // S_C = diag(x)·(W − W^C): saliency of the (negated) compression error.
    let err = w.sub(wc);
    let s_c = err.scale_rows(&x);
    let mut rng = Pcg32::seeded(0x511f_11a0);
    let svd = randomized_svd(&s_c, rank, 8, 2, &mut rng);
    let (l_tilde, r) = svd.split_balanced();
    // Invert the saliency transform on the left factor (Alg. 2 line 8).
    let inv: Vec<f32> = x.iter().map(|&v| 1.0 / v).collect();
    let l = l_tilde.scale_rows(&inv);
    Adapters { l, r }
}

/// Saliency-weighted squared error `‖diag(x)·(W − Ŵ)‖²` — the objective
/// SLiM-LoRA minimizes; exposed for tests and the experiment drivers.
pub fn saliency_error(w: &Matrix, w_hat: &Matrix, x_abs_mean: &[f32]) -> f64 {
    let x = saliency_vector(x_abs_mean);
    w.sub(w_hat).scale_rows(&x).fro_norm_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::naive;

    fn setup(seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let d_in = 96;
        let d_out = 64;
        let w = Matrix::randn(d_in, d_out, 0.1, &mut rng);
        // Compression: coarse quantization + 2:4-ish masking.
        let wc = w.map(|v| {
            let q = (v * 6.0).round() / 6.0;
            if q.abs() < 0.05 {
                0.0
            } else {
                q
            }
        });
        // Hot channels at the front, like real activation profiles.
        let x: Vec<f32> = (0..d_in)
            .map(|i| if i < 8 { 5.0 + rng.f32() } else { 0.1 + 0.05 * rng.f32() })
            .collect();
        (w, wc, x)
    }

    #[test]
    fn saliency_function_is_additive() {
        // F(A+B) = F(A) + F(B) — the property Eq. 9 relies on.
        let mut rng = Pcg32::seeded(1);
        let a = Matrix::randn(16, 8, 1.0, &mut rng);
        let b = Matrix::randn(16, 8, 1.0, &mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.f32()).collect();
        let xs = saliency_vector(&x);
        let lhs = a.add(&b).scale_rows(&xs);
        let rhs = a.scale_rows(&xs).add(&b.scale_rows(&xs));
        assert!(lhs.rel_err(&rhs) < 1e-6);
    }

    #[test]
    fn saliency_function_is_invertible() {
        // diag(1/x)·diag(x)·W = W even with zero entries in raw x.
        let mut rng = Pcg32::seeded(2);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let mut x = vec![0.0f32; 10]; // adversarial: all-zero activations
        x[3] = 0.5;
        let xs = saliency_vector(&x);
        let inv: Vec<f32> = xs.iter().map(|&v| 1.0 / v).collect();
        let round = a.scale_rows(&xs).scale_rows(&inv);
        assert!(round.rel_err(&a) < 1e-5);
    }

    #[test]
    fn beats_naive_on_saliency_error() {
        // The defining property: SLiM-LoRA minimizes the saliency-weighted
        // error, so it must beat Naive-LoRA on that metric.
        let (w, wc, x) = setup(3);
        let rank = 10;
        let a_slim = adapters(&w, &wc, &x, rank);
        let a_naive = naive::adapters(&w, &wc, rank);
        let e_slim = saliency_error(&w, &wc.add(&a_slim.product()), &x);
        let e_naive = saliency_error(&w, &wc.add(&a_naive.product()), &x);
        assert!(e_slim < e_naive, "slim {e_slim} vs naive {e_naive}");
    }

    #[test]
    fn beats_naive_on_output_error() {
        // And on the actual layer output error with matching activations.
        let (w, wc, x) = setup(4);
        let mut rng = Pcg32::seeded(5);
        // Sample activations consistent with the x profile.
        let acts = Matrix::from_fn(128, 96, |_, j| rng.gauss() * x[j]);
        let rank = 10;
        let a_slim = adapters(&w, &wc, &x, rank);
        let a_naive = naive::adapters(&w, &wc, rank);
        let out_err = |adj: &Matrix| acts.matmul(&wc.add(adj).sub(&w)).fro_norm_sq();
        let e_slim = out_err(&a_slim.product());
        let e_naive = out_err(&a_naive.product());
        assert!(e_slim < e_naive, "slim {e_slim} vs naive {e_naive}");
    }

    #[test]
    fn reduces_error_vs_no_adapter() {
        let (w, wc, x) = setup(6);
        let a = adapters(&w, &wc, &x, 10);
        let before = saliency_error(&w, &wc, &x);
        let after = saliency_error(&w, &wc.add(&a.product()), &x);
        assert!(after < before);
        // also reduces the raw error (not guaranteed optimal but should help)
        let raw_after = wc.add(&a.product()).sub(&w).fro_norm_sq();
        let raw_before = wc.sub(&w).fro_norm_sq();
        assert!(raw_after < raw_before);
    }

    #[test]
    fn uniform_activations_match_naive() {
        // With flat saliency, SLiM-LoRA degenerates to Naive-LoRA.
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::randn(48, 32, 0.1, &mut rng);
        let wc = w.map(|v| (v * 5.0).round() / 5.0);
        let x = vec![1.0f32; 48];
        let a_slim = adapters(&w, &wc, &x, 6);
        let a_naive = naive::adapters(&w, &wc, 6);
        let e_slim = wc.add(&a_slim.product()).sub(&w).fro_norm_sq();
        let e_naive = wc.add(&a_naive.product()).sub(&w).fro_norm_sq();
        assert!((e_slim - e_naive).abs() / e_naive.max(1e-12) < 0.05);
    }

    #[test]
    fn adapter_shapes() {
        let (w, wc, x) = setup(8);
        let a = adapters(&w, &wc, &x, 12);
        assert_eq!(a.l.shape(), (96, 12));
        assert_eq!(a.r.shape(), (12, 64));
        assert_eq!(a.rank(), 12);
        assert_eq!(a.param_count(), 96 * 12 + 12 * 64);
    }
}
