//! L²QER (Zhang et al. 2024a) — one-shot low-rank *quantization*-error
//! reconstruction.
//!
//! Structurally like SLiM-LoRA's saliency SVD, but the adapters compensate
//! only the quantization error `W − W^Q`, not the joint error `W − W^C`.
//! Under quant-only settings this works well (Table 8); under joint
//! sparsity+quantization the un-modeled sparsity error dominates and the
//! method falls behind (Table 1) — which this module lets the experiment
//! drivers demonstrate.

use super::{slim_lora, Adapters};
use crate::tensor::Matrix;

/// Compute rank-`r` L²QER adapters from the quantization error only.
///
/// * `w` — original weights
/// * `wq` — quantized (NOT pruned) weights
pub fn adapters(w: &Matrix, wq: &Matrix, x_abs_mean: &[f32], rank: usize) -> Adapters {
    // Same saliency-SVD machinery, but on the quant error alone.
    slim_lora::adapters(w, wq, x_abs_mean, rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::sparse::{mask::SparsityPattern, wanda};

    #[test]
    fn good_for_quant_only() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(64, 48, 0.1, &mut rng);
        let wq = w.map(|v| (v * 6.0).round() / 6.0);
        let x = vec![1.0f32; 64];
        let a = adapters(&w, &wq, &x, 8);
        let before = wq.sub(&w).fro_norm_sq();
        let after = wq.add(&a.product()).sub(&w).fro_norm_sq();
        assert!(after < before * 0.8);
    }

    #[test]
    fn underperforms_slim_lora_with_sparsity() {
        // Reproduces the paper's Table 1 story in miniature: adapters that
        // ignore the sparsity error lose to adapters on the joint error.
        let mut rng = Pcg32::seeded(2);
        let d_in = 96;
        let w = Matrix::randn(d_in, 64, 0.1, &mut rng);
        let wq = w.map(|v| (v * 6.0).round() / 6.0);
        let x_l2: Vec<f32> = (0..d_in).map(|_| 1.0 + rng.f32()).collect();
        let (wc, _) = wanda::prune(&wq, &x_l2, SparsityPattern::TWO_FOUR);
        let x_mean: Vec<f32> = x_l2.iter().map(|v| v / 10.0).collect();
        let rank = 10;
        // L²QER: compensates W−Wq but is applied on top of the sparse Wc.
        let a_l2 = adapters(&w, &wq, &x_mean, rank);
        // SLiM-LoRA: compensates the full W−Wc.
        let a_slim = slim_lora::adapters(&w, &wc, &x_mean, rank);
        let err = |a: &Adapters| wc.add(&a.product()).sub(&w).fro_norm_sq();
        assert!(
            err(&a_slim) < err(&a_l2),
            "slim {} vs l2qer {}",
            err(&a_slim),
            err(&a_l2)
        );
    }
}
