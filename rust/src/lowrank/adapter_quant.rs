//! Low-rank adapter quantization (paper §3.3) — `SLiM-LoRA^Q`.
//!
//! Adapters have long-tailed element distributions, so the paper uses
//! AbsMax *group* quantization (group = 128) rather than SLiM-Quant for
//! them, cutting adapter memory 4× (4-bit) with negligible accuracy impact
//! (Table 1's `SLiM-LoRA^Q` rows).

use super::Adapters;
use crate::quant::group_absmax;

/// Paper's adapter quantization config: 4 bits, groups of 128.
pub const ADAPTER_BITS: u8 = 4;
pub const ADAPTER_GROUP: usize = 128;

/// Quantize both adapter factors with group AbsMax; returns the fake-quant
/// adapters (accuracy path) — the packed codes live inside the kernels.
pub fn quantize(adapters: &Adapters) -> Adapters {
    let lq = group_absmax::quantize(&adapters.l, ADAPTER_BITS, ADAPTER_GROUP);
    let rq = group_absmax::quantize(&adapters.r, ADAPTER_BITS, ADAPTER_GROUP);
    Adapters { l: lq.wq, r: rq.wq }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::Matrix;

    fn long_tailed_adapters(seed: u64) -> Adapters {
        let mut rng = Pcg32::seeded(seed);
        // Long-tailed entries (Laplace), like real compression-error SVD factors.
        let l = Matrix::from_fn(128, 12, |_, _| rng.laplace(0.05));
        let r = Matrix::from_fn(12, 96, |_, _| rng.laplace(0.05));
        Adapters { l, r }
    }

    #[test]
    fn small_relative_error() {
        // 4-bit group quant on long-tailed factors: expect ~10-20% per
        // factor — small next to the compression error it corrects.
        let a = long_tailed_adapters(1);
        let aq = quantize(&a);
        assert!(aq.l.rel_err(&a.l) < 0.2, "L err {}", aq.l.rel_err(&a.l));
        assert!(aq.r.rel_err(&a.r) < 0.2, "R err {}", aq.r.rel_err(&a.r));
    }

    #[test]
    fn product_stays_close() {
        let a = long_tailed_adapters(2);
        let aq = quantize(&a);
        let rel = aq.product().rel_err(&a.product());
        assert!(rel < 0.3, "product err {rel}");
    }

    #[test]
    fn shapes_preserved() {
        let a = long_tailed_adapters(3);
        let aq = quantize(&a);
        assert_eq!(aq.l.shape(), a.l.shape());
        assert_eq!(aq.r.shape(), a.r.shape());
    }
}
