//! Singular value decompositions.
//!
//! Two routes:
//! * [`jacobi_svd`] — exact one-sided Jacobi SVD for small/square matrices
//!   (the k×n projected matrix inside the randomized route, and tests).
//! * [`randomized_svd`] — Halko–Martinsson–Tropp randomized truncated SVD
//!   with subspace (power) iteration; this is what the adapter computations
//!   use, since they only need the top `r = 0.1·d` singular triplets.

use super::qr::qr_thin;
use crate::rng::Pcg32;
use crate::tensor::{matmul_at_b, Matrix};

/// Truncated SVD result: `A ≈ U · diag(S) · Vt` with `U` m×k, `S` len k
/// (descending), `Vt` k×n.
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub vt: Matrix,
}

impl Svd {
    /// Reconstruct `U · diag(S) · Vt`.
    pub fn reconstruct(&self) -> Matrix {
        self.u.scale_cols(&self.s).matmul(&self.vt)
    }

    /// Truncate to the top-`k` triplets.
    pub fn truncate(mut self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        let u = self.u.block(0, self.u.rows(), 0, k);
        let vt = self.vt.block(0, k, 0, self.vt.cols());
        self.s.truncate(k);
        Svd { u, s: self.s, vt }
    }

    /// Split into `(L, R)` with `L = U·diag(√S)`, `R = diag(√S)·Vt` so that
    /// `L·R = U·diag(S)·Vt` — the balanced adapter factorization.
    pub fn split_balanced(&self) -> (Matrix, Matrix) {
        let sqrt_s: Vec<f32> = self.s.iter().map(|&x| x.max(0.0).sqrt()).collect();
        let l = self.u.scale_cols(&sqrt_s);
        let r = self.vt.scale_rows(&sqrt_s);
        (l, r)
    }
}

/// One-sided Jacobi SVD of `a` (m×n, any aspect). Exact up to convergence
/// tolerance; O(n²·m) per sweep so intended for small matrices.
pub fn jacobi_svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // Work on the transpose and swap U/V at the end.
        let svd_t = jacobi_svd(&a.transpose());
        return Svd { u: svd_t.vt.transpose(), s: svd_t.s, vt: svd_t.u.transpose() };
    }
    // Work array G (m×n, f64): columns get rotated until mutually orthogonal.
    let mut g: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    // V accumulates the right rotations (n×n).
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 60;
    let eps = 1e-12f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // Compute the 2x2 Gram entries for columns p,q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let gp = g[i * n + p];
                    let gq = g[i * n + q];
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let gp = g[i * n + p];
                    let gq = g[i * n + q];
                    g[i * n + p] = c * gp - s * gq;
                    g[i * n + q] = s * gp + c * gq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }
    // Singular values = column norms; U = normalized columns.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| g[i * n + j] * g[i * n + j]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (rank, &(norm, j)) in sv.iter().enumerate() {
        s.push(norm as f32);
        if norm > 0.0 {
            for i in 0..m {
                u.set(i, rank, (g[i * n + j] / norm) as f32);
            }
        }
        for i in 0..n {
            vt.set(rank, i, v[i * n + j] as f32);
        }
    }
    Svd { u, s, vt }
}

/// Randomized truncated SVD of rank `k` with `oversample` extra probes and
/// `power_iters` subspace iterations (2 is plenty for adapter use — the
/// compression-error spectra decay fast).
pub fn randomized_svd(
    a: &Matrix,
    k: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Pcg32,
) -> Svd {
    let (m, n) = a.shape();
    let k = k.min(m.min(n));
    let probes = (k + oversample).min(m.min(n)).max(1);
    // Range finder: Y = A·Ω, then power iterations with re-orthonormalization.
    let omega = Matrix::randn(n, probes, 1.0, rng);
    let mut y = a.matmul(&omega);
    let mut q = qr_thin(&y).q;
    for _ in 0..power_iters {
        let z = matmul_at_b(a, &q); // n×p = Aᵀ·Q
        let qz = qr_thin(&z).q;
        y = a.matmul(&qz);
        q = qr_thin(&y).q;
    }
    // Project: B = Qᵀ·A (p×n); exact SVD of the small B.
    let b = matmul_at_b(&q, a);
    let svd_b = jacobi_svd(&b);
    let u = q.matmul(&svd_b.u);
    Svd { u, s: svd_b.s, vt: svd_b.vt }.truncate(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_at_b;

    fn low_rank_matrix(m: usize, n: usize, r: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let u = Matrix::randn(m, r, 1.0, &mut rng);
        let v = Matrix::randn(r, n, 1.0, &mut rng);
        u.matmul(&v)
    }

    #[test]
    fn jacobi_reconstructs_exactly() {
        let mut rng = Pcg32::seeded(20);
        for &(m, n) in &[(12usize, 12usize), (20, 8), (8, 20)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = jacobi_svd(&a);
            assert!(svd.reconstruct().rel_err(&a) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn jacobi_singular_values_descending() {
        let mut rng = Pcg32::seeded(21);
        let a = Matrix::randn(15, 10, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn jacobi_u_orthonormal() {
        let mut rng = Pcg32::seeded(22);
        let a = Matrix::randn(18, 9, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let utu = matmul_at_b(&svd.u, &svd.u);
        assert!(utu.rel_err(&Matrix::eye(9)) < 1e-4);
    }

    #[test]
    fn randomized_recovers_low_rank() {
        let a = low_rank_matrix(80, 60, 5, 23);
        let mut rng = Pcg32::seeded(24);
        let svd = randomized_svd(&a, 5, 8, 2, &mut rng);
        assert!(svd.reconstruct().rel_err(&a) < 1e-3);
        assert_eq!(svd.s.len(), 5);
    }

    #[test]
    fn randomized_truncation_error_decreases_with_rank() {
        let mut rng = Pcg32::seeded(25);
        let a = Matrix::randn(64, 64, 1.0, &mut rng);
        let mut prev = f32::INFINITY;
        for k in [4usize, 16, 32, 64] {
            let mut r2 = Pcg32::seeded(26);
            let svd = randomized_svd(&a, k, 10, 3, &mut r2);
            let err = svd.reconstruct().rel_err(&a);
            assert!(err <= prev + 1e-3, "rank {k}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn split_balanced_multiplies_back() {
        let a = low_rank_matrix(30, 40, 6, 27);
        let mut rng = Pcg32::seeded(28);
        let svd = randomized_svd(&a, 6, 6, 2, &mut rng);
        let (l, r) = svd.split_balanced();
        assert!(l.matmul(&r).rel_err(&a) < 1e-3);
        assert_eq!(l.shape(), (30, 6));
        assert_eq!(r.shape(), (6, 40));
    }

    #[test]
    fn zero_matrix_svd() {
        let a = Matrix::zeros(10, 7);
        let svd = jacobi_svd(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.reconstruct().fro_norm() == 0.0);
    }
}
