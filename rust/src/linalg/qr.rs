//! Thin QR via Householder reflections.
//!
//! Used by the randomized SVD to orthonormalize the sampled range basis.

use crate::tensor::Matrix;

/// Thin QR factorization result: `A = Q · R` with `Q` m×k orthonormal and
/// `R` k×k upper triangular (k = min(m, n) = n for tall inputs).
pub struct QrThin {
    pub q: Matrix,
    pub r: Matrix,
}

/// Householder thin QR of a tall (m ≥ n) matrix.
pub fn qr_thin(a: &Matrix) -> QrThin {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin expects a tall matrix, got {m}x{n}");
    // Work in f64 for stability; these matrices are small (n ≤ rank+overs).
    let mut r: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    // Householder vectors stored column-by-column in `vs`.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut norm = 0.0f64;
        for i in k..m {
            norm += r[i * n + k] * r[i * n + k];
        }
        let norm = norm.sqrt();
        let mut v = vec![0.0f64; m - k];
        if norm == 0.0 {
            vs.push(v);
            continue;
        }
        let alpha = if r[k * n + k] >= 0.0 { -norm } else { norm };
        for i in k..m {
            v[i - k] = r[i * n + k];
        }
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq > 0.0 {
            // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..].
            for j in k..n {
                let mut dot = 0.0f64;
                for i in k..m {
                    dot += v[i - k] * r[i * n + j];
                }
                let s = 2.0 * dot / vnorm_sq;
                for i in k..m {
                    r[i * n + j] -= s * v[i - k];
                }
            }
        }
        vs.push(v);
    }
    // Extract R (n×n upper triangular).
    let mut rm = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rm.set(i, j, r[i * n + j] as f32);
        }
    }
    // Form Q by applying reflectors to the first n columns of I (backward).
    let mut q: Vec<f64> = vec![0.0; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let s = 2.0 * dot / vnorm_sq;
            for i in k..m {
                q[i * n + j] -= s * v[i - k];
            }
        }
    }
    let qm = Matrix::from_vec(m, n, q.iter().map(|&x| x as f32).collect());
    QrThin { q: qm, r: rm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::matmul_at_b;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg32::seeded(10);
        for &(m, n) in &[(20usize, 5usize), (50, 50), (100, 12)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let QrThin { q, r } = qr_thin(&a);
            assert!(q.matmul(&r).rel_err(&a) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg32::seeded(11);
        let a = Matrix::randn(60, 10, 1.0, &mut rng);
        let QrThin { q, .. } = qr_thin(&a);
        let qtq = matmul_at_b(&q, &q);
        assert!(qtq.rel_err(&Matrix::eye(10)) < 1e-4);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg32::seeded(12);
        let a = Matrix::randn(30, 8, 1.0, &mut rng);
        let QrThin { r, .. } = qr_thin(&a);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_does_not_panic() {
        // Two identical columns.
        let a = Matrix::from_fn(10, 3, |i, j| if j == 2 { i as f32 } else { i as f32 });
        let QrThin { q, r } = qr_thin(&a);
        assert!(q.matmul(&r).rel_err(&a) < 1e-4);
    }
}
