//! Numerical linear algebra on [`Matrix`]: Householder QR, one-sided Jacobi
//! SVD, randomized truncated SVD, and SPD solves.
//!
//! SLiM-LoRA (paper Alg. 2), Naive-LoRA and L²QER all reduce to a truncated
//! SVD of an error matrix; SparseGPT/OPTQ need Cholesky factorizations of a
//! damped Hessian. The vendored crate set has no LAPACK binding, so these are
//! implemented natively.

mod qr;
mod svd;

pub use qr::{qr_thin, QrThin};
pub use svd::{jacobi_svd, randomized_svd, Svd};

use crate::tensor::Matrix;

/// Cholesky factorization of an SPD matrix: returns lower-triangular `L`
/// with `A = L·Lᵀ`. Fails (None) if the matrix is not positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64;
            for k in 0..j {
                sum -= l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.get(j, j) as f64) as f32);
            }
        }
    }
    Some(l)
}

/// Invert an SPD matrix via Cholesky (A⁻¹ = L⁻ᵀ·L⁻¹). Used for the
/// SparseGPT inverse-Hessian. Returns None if not SPD.
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    let l = cholesky(a)?;
    // Solve L·Y = I column by column (forward), then Lᵀ·X = Y (backward).
    let mut inv = Matrix::zeros(n, n);
    let mut y = vec![0.0f64; n];
    let mut x = vec![0.0f64; n];
    for col in 0..n {
        for i in 0..n {
            let mut sum = if i == col { 1.0f64 } else { 0.0 };
            for k in 0..i {
                sum -= l.get(i, k) as f64 * y[k];
            }
            y[i] = sum / l.get(i, i) as f64;
        }
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l.get(k, i) as f64 * x[k];
            }
            x[i] = sum / l.get(i, i) as f64;
        }
        for i in 0..n {
            inv.set(i, col, x[i] as f32);
        }
    }
    Some(inv)
}

/// Solve the SPD system `A·x = b` via Cholesky.
pub fn spd_solve(a: &Matrix, b: &[f32]) -> Option<Vec<f32>> {
    let n = a.rows();
    assert_eq!(b.len(), n);
    let l = cholesky(a)?;
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.get(i, k) as f64 * y[k];
        }
        y[i] = sum / l.get(i, i) as f64;
    }
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.get(k, i) as f64 * x[k];
        }
        x[i] = sum / l.get(i, i) as f64;
    }
    Some(x.iter().map(|&v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::matmul_at_b;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let g = Matrix::randn(n + 5, n, 1.0, &mut rng);
        let mut a = matmul_at_b(&g, &g);
        for i in 0..n {
            a.set(i, i, a.get(i, i) + 0.1);
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).expect("spd");
        let rec = l.matmul(&l.transpose());
        assert!(rec.rel_err(&a) < 1e-4);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let a = random_spd(10, 2);
        let inv = spd_inverse(&a).expect("spd");
        let id = a.matmul(&inv);
        assert!(id.rel_err(&Matrix::eye(10)) < 1e-3);
    }

    #[test]
    fn spd_solve_solves() {
        let a = random_spd(8, 3);
        let mut rng = Pcg32::seeded(4);
        let x_true: Vec<f32> = (0..8).map(|_| rng.gauss()).collect();
        let b: Vec<f32> = (0..8)
            .map(|i| (0..8).map(|j| a.get(i, j) * x_true[j]).sum())
            .collect();
        let x = spd_solve(&a, &b).expect("spd");
        for (xs, xt) in x.iter().zip(x_true.iter()) {
            assert!((xs - xt).abs() < 1e-3, "{xs} vs {xt}");
        }
    }
}
