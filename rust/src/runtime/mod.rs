//! PJRT runtime: load AOT artifacts and execute them from the Rust hot path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`). Entries are discovered through
//! `artifacts/manifest.json` (written by `python -m compile.aot`); compiled
//! executables are cached per runtime instance. Python never runs here —
//! the HLO text is the only thing that crosses the language boundary.

mod manifest;
pub mod marshal;

pub use manifest::{Entry, Manifest, TensorSpec};

use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A loaded artifact directory + PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifact directory (repo-root/artifacts, overridable with
    /// SLIM_ARTIFACTS).
    pub fn default_dir() -> PathBuf {
        std::env::var("SLIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Entry metadata by name.
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no artifact entry named {name}"))
    }

    /// Compile (or fetch from cache) the executable for an entry.
    fn compiled(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Upload a literal to a device buffer.
    pub fn to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Execute an entry over device buffers (the zero-copy hot path —
    /// training keeps its state resident this way). Returns the output
    /// buffers of replica 0.
    ///
    /// NOTE: the literal-input `c_lib::execute` path leaks its internally
    /// created device buffers (observed ~50 MB/step on the train loop), so
    /// every execution in this crate goes through `execute_b` with
    /// self-managed buffers.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", entry.inputs.len(), inputs.len());
        }
        self.compiled(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let mut result = exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        Ok(result.swap_remove(0))
    }

    /// Execute an entry with positional literal inputs; returns the
    /// flattened tuple outputs. (Uploads to buffers internally so the
    /// inputs are freed deterministically — see `execute_buffers`.)
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self.entry(name)?.clone();
        let bufs: Vec<xla::PjRtBuffer> =
            inputs.iter().map(|l| self.to_buffer(l)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out_bufs = self.execute_buffers(name, &refs)?;
        // aot.py lowers with return_tuple=True → single tuple output buffer.
        let lit = out_bufs[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let outs = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if outs.len() != entry.outputs.len() {
            bail!("{name}: manifest lists {} outputs, got {}", entry.outputs.len(), outs.len());
        }
        Ok(outs)
    }

    /// Execute with Matrix/token inputs marshalled per the manifest specs.
    /// `f32_inputs` fill the f32 slots in order; the (single) i32 slot is
    /// filled from `tokens`.
    pub fn execute_matrices(
        &self,
        name: &str,
        f32_inputs: &[&Matrix],
        tokens: Option<(&[u32], usize, usize)>,
    ) -> Result<Vec<Matrix>> {
        let entry = self.entry(name)?.clone();
        let mut lits = Vec::with_capacity(entry.inputs.len());
        let mut fi = 0usize;
        for spec in &entry.inputs {
            if spec.dtype == "i32" {
                let (toks, b, s) =
                    tokens.ok_or_else(|| anyhow!("{name}: entry needs tokens"))?;
                lits.push(marshal::tokens_to_literal(toks, b, s)?);
            } else {
                let m = f32_inputs
                    .get(fi)
                    .ok_or_else(|| anyhow!("{name}: missing f32 input {}", spec.name))?;
                if !spec.matches_matrix(m) {
                    bail!(
                        "{name}: input {} expects shape {:?}, got {:?}",
                        spec.name,
                        spec.shape,
                        m.shape()
                    );
                }
                lits.push(marshal::matrix_to_literal(m, &spec.shape)?);
                fi += 1;
            }
        }
        if fi != f32_inputs.len() {
            bail!("{name}: {} f32 inputs supplied, {} consumed", f32_inputs.len(), fi);
        }
        let outs = self.execute(name, &lits)?;
        outs.iter()
            .zip(entry.outputs.iter())
            .map(|(lit, spec)| marshal::literal_to_matrix(lit, spec))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_and_run_quant_scan() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let entry = rt.entry("quant_scan").unwrap();
        let nbins = entry.inputs[0].shape[1];
        let k = entry.inputs[2].shape[1];
        // Bell histogram; verify the returned error curve has an interior
        // minimum — same invariant the python tests assert.
        let mut rng = crate::rng::Pcg32::seeded(5);
        let data: Vec<f32> = (0..100_000).map(|_| rng.gauss()).collect();
        let hist = crate::tensor::histogram_with_bins(&data, nbins);
        let centers = Matrix::from_vec(1, nbins, hist.centers.clone());
        let pdf = Matrix::from_vec(1, nbins, hist.pdf.clone());
        let alphas =
            Matrix::from_fn(1, k, |_, j| hist.max * (j as f32 + 1.0) / k as f32);
        let outs = rt
            .execute_matrices("quant_scan", &[&centers, &pdf, &alphas], None)
            .unwrap();
        let errs = &outs[0];
        assert_eq!(errs.shape(), (1, k));
        let best = (0..k)
            .min_by(|&a, &b| errs.get(0, a).partial_cmp(&errs.get(0, b)).unwrap())
            .unwrap();
        assert!(best > 0 && best < k - 1, "interior minimum expected, got {best}");
        // And it matches the native implementation's error estimates.
        for j in [best, 0, k - 1] {
            let native = crate::quant::slim_quant::estimate_error(&hist, alphas.get(0, j), 4);
            let aot = errs.get(0, j) as f64;
            assert!(
                (native - aot).abs() <= 1e-3 * native.max(1e-9) + 1e-6,
                "alpha {j}: native {native} vs aot {aot}"
            );
        }
    }

    #[test]
    fn layer_fwd_matches_native_kernel_math() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let name = "layer_fwd_64x256x256r26";
        let entry = rt.entry(name).unwrap().clone();
        let (m, din) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let dout = entry.inputs[1].shape[1];
        let rank = entry.inputs[4].shape[1];
        let mut rng = crate::rng::Pcg32::seeded(7);
        let x = Matrix::randn(m, din, 1.0, &mut rng);
        let wq = Matrix::from_fn(din, dout, |_, _| (rng.below(15) as f32) - 7.0);
        let scale = Matrix::from_vec(1, 1, vec![0.1]);
        let mask = Matrix::from_fn(din, dout, |_, _| (rng.below(2)) as f32);
        let l = Matrix::randn(din, rank, 0.1, &mut rng);
        let r = Matrix::randn(rank, dout, 0.1, &mut rng);
        let outs = rt
            .execute_matrices(name, &[&x, &wq, &scale, &mask, &l, &r], None)
            .unwrap();
        // Native reference: x @ (wq*alpha/7*mask) + x@l@r.
        let w = wq.scale(0.1 / 7.0).hadamard(&mask);
        let want = x.matmul(&w).add(&x.matmul(&l).matmul(&r));
        assert!(outs[0].rel_err(&want) < 1e-4, "err {}", outs[0].rel_err(&want));
    }

    #[test]
    fn missing_entry_is_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt.entry("nonexistent").is_err());
    }
}
