//! Literal ↔ Matrix marshalling.
//!
//! HLO artifacts take positional typed literals; our numeric substrate is
//! the 2-D [`Matrix`]. Manifest shapes may be 0-D (scalars), 1-D, 2-D, or
//! 3-D (logits [B, S, V]); everything maps onto a row-major Matrix whose
//! trailing dimension is the matrix column count.

use super::manifest::TensorSpec;
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};

/// Matrix → f32 literal with the manifest's target shape.
pub fn matrix_to_literal(m: &Matrix, shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(m.data());
    let dims: Vec<i64> = if shape.is_empty() {
        vec![] // scalar
    } else {
        shape.iter().map(|&d| d as i64).collect()
    };
    if shape.is_empty() {
        // reshape to rank-0
        return lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"));
    }
    lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

/// Token slice → i32 literal [b, s].
pub fn tokens_to_literal(tokens: &[u32], b: usize, s: usize) -> Result<xla::Literal> {
    if tokens.len() != b * s {
        return Err(anyhow!("tokens len {} != {}x{}", tokens.len(), b, s));
    }
    let as_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    xla::Literal::vec1(&as_i32)
        .reshape(&[b as i64, s as i64])
        .map_err(|e| anyhow!("reshape tokens: {e:?}"))
}

/// Literal → Matrix. Rank-0 → 1×1; rank-1 → 1×n; rank-2 → r×c; rank-3
/// [a, b, c] → (a·b)×c (row-major flattening).
pub fn literal_to_matrix(lit: &xla::Literal, spec: &TensorSpec) -> Result<Matrix> {
    let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal read: {e:?}"))?;
    let (rows, cols) = match spec.shape.len() {
        0 => (1, 1),
        1 => (1, spec.shape[0]),
        2 => (spec.shape[0], spec.shape[1]),
        n => {
            let cols = spec.shape[n - 1];
            (spec.numel() / cols, cols)
        }
    };
    if data.len() != rows * cols {
        return Err(anyhow!(
            "literal numel {} != spec {:?}",
            data.len(),
            spec.shape
        ));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_round_trip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let lit = matrix_to_literal(&m, &[3, 4]).unwrap();
        let spec = TensorSpec { name: "x".into(), shape: vec![3, 4], dtype: "f32".into() };
        let back = literal_to_matrix(&lit, &spec).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn scalar_round_trip() {
        let m = Matrix::from_vec(1, 1, vec![42.0]);
        let lit = matrix_to_literal(&m, &[]).unwrap();
        let spec = TensorSpec { name: "s".into(), shape: vec![], dtype: "f32".into() };
        let back = literal_to_matrix(&lit, &spec).unwrap();
        assert_eq!(back.get(0, 0), 42.0);
    }

    #[test]
    fn rank3_flattens() {
        let m = Matrix::from_fn(6, 5, |i, j| (i * 5 + j) as f32); // (2·3)×5
        let lit = matrix_to_literal(&m, &[2, 3, 5]).unwrap();
        let spec =
            TensorSpec { name: "l".into(), shape: vec![2, 3, 5], dtype: "f32".into() };
        let back = literal_to_matrix(&lit, &spec).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn token_literal_shape_checked() {
        assert!(tokens_to_literal(&[1, 2, 3], 2, 2).is_err());
        assert!(tokens_to_literal(&[1, 2, 3, 4], 2, 2).is_ok());
    }
}
