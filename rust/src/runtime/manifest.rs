//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Shape + dtype of one positional input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Does a Matrix (2-D) fit this spec? Scalars ([]) accept 1×1; 1-D
    /// accepts 1×n.
    pub fn matches_matrix(&self, m: &Matrix) -> bool {
        match self.shape.len() {
            0 => m.shape() == (1, 1),
            1 => m.rows() == 1 && m.cols() == self.shape[0],
            2 => m.shape() == (self.shape[0], self.shape[1]),
            _ => m.len() == self.numel(),
        }
    }
}

/// One AOT entry (an executable).
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Raw metadata object (config name, batch geometry, …).
    pub meta: Json,
}

impl Entry {
    /// Metadata field as usize.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key)?.as_usize()
    }

    /// Metadata field as str.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key)?.as_str()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<Entry>,
}

fn parse_spec(v: &Json) -> Result<TensorSpec> {
    let name = v.get("name").and_then(Json::as_str).unwrap_or("").to_string();
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("spec missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string();
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    /// Parse from a JSON document.
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string();
                let file = e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string();
                let inputs = e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_spec)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_spec)
                    .collect::<Result<Vec<_>>>()?;
                let meta = e.get("meta").cloned().unwrap_or(Json::Null);
                Ok(Entry { name, file, inputs, outputs, meta })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { entries })
    }

    /// Load + parse from a file.
    pub fn load(path: &Path) -> Result<Manifest> {
        Manifest::parse(&std::fs::read_to_string(path)?)
    }

    /// Names of all entries of a given `meta.kind`.
    pub fn entries_of_kind(&self, kind: &str) -> Vec<&Entry> {
        self.entries
            .iter()
            .filter(|e| e.meta_str("kind") == Some(kind))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "foo", "file": "foo.hlo.txt",
         "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"},
                    {"name": "t", "shape": [4, 8], "dtype": "i32"}],
         "outputs": [{"name": "out0", "shape": [], "dtype": "f32"}],
         "meta": {"kind": "lm_loss", "batch": 4, "config": "sim-125m"}}
      ]
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.name, "foo");
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[1].dtype, "i32");
        assert_eq!(e.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(e.meta_usize("batch"), Some(4));
        assert_eq!(e.meta_str("config"), Some("sim-125m"));
        assert_eq!(m.entries_of_kind("lm_loss").len(), 1);
        assert_eq!(m.entries_of_kind("train_step").len(), 0);
    }

    #[test]
    fn spec_matching() {
        let scalar = TensorSpec { name: "s".into(), shape: vec![], dtype: "f32".into() };
        assert!(scalar.matches_matrix(&Matrix::zeros(1, 1)));
        assert!(!scalar.matches_matrix(&Matrix::zeros(1, 2)));
        let mat = TensorSpec { name: "m".into(), shape: vec![3, 4], dtype: "f32".into() };
        assert!(mat.matches_matrix(&Matrix::zeros(3, 4)));
        assert!(!mat.matches_matrix(&Matrix::zeros(4, 3)));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("[]").is_err());
    }
}
