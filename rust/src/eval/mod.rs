//! Evaluation: perplexity (WikiText2 stand-in) and the 6-task zero-shot
//! suite, over dense or compressed models.
//!
//! Perplexity runs either natively (with weight overrides — the compressed
//! path) or through the AOT `lm_loss` artifact (dense validation that the
//! Rust and HLO forward agree). Zero-shot accuracy is likelihood ranking
//! via the native forward.

use crate::data::{accuracy, task_suite, Corpus};
use crate::model::{nll, Batch, ModelConfig, Overrides, Weights};
use crate::quant::fp8::InputQuant;
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};

/// Perplexity over the corpus eval split using the native forward.
pub fn perplexity(
    cfg: &ModelConfig,
    w: &Weights,
    overrides: Option<&Overrides>,
    corpus: &Corpus,
    max_windows: usize,
) -> f64 {
    perplexity_iq(cfg, w, overrides, corpus, max_windows, InputQuant::None)
}

/// [`perplexity`] with activation quantization (paper Apx B / Table 12).
pub fn perplexity_iq(
    cfg: &ModelConfig,
    w: &Weights,
    overrides: Option<&Overrides>,
    corpus: &Corpus,
    max_windows: usize,
    iq: InputQuant,
) -> f64 {
    let windows = corpus.eval_windows(cfg.max_seq, max_windows);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for win in &windows {
        let batch = Batch::new(win.clone(), 1, win.len());
        let logits = crate::model::transformer::forward_iq(cfg, w, &batch, None, overrides, iq);
        total += nll(cfg, &logits, &batch) * (win.len() - 1) as f64;
        count += win.len() - 1;
    }
    (total / count.max(1) as f64).exp()
}

/// Perplexity via the AOT `lm_loss` artifact (dense weights only).
pub fn perplexity_aot(
    rt: &Runtime,
    cfg: &ModelConfig,
    w: &Weights,
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let entry_name = format!("lm_loss_{}", cfg.name);
    let entry = rt.entry(&entry_name)?.clone();
    let b = entry.meta_usize("batch").ok_or_else(|| anyhow!("no batch"))?;
    let seq = entry.meta_usize("seq").ok_or_else(|| anyhow!("no seq"))?;
    let windows = corpus.eval_windows(seq, max_batches * b);
    if windows.len() < b {
        return Err(anyhow!("not enough eval windows"));
    }
    let order = crate::model::param_order(cfg);
    let params: Vec<&crate::tensor::Matrix> = order.iter().map(|n| w.expect(n)).collect();
    let mut total = 0.0f64;
    let mut batches = 0usize;
    for chunk in windows.chunks_exact(b).take(max_batches) {
        let toks: Vec<u32> = chunk.iter().flatten().copied().collect();
        let outs = rt.execute_matrices(&entry_name, &params, Some((&toks, b, seq)))?;
        total += outs[0].get(0, 0) as f64;
        batches += 1;
    }
    Ok((total / batches.max(1) as f64).exp())
}

/// Per-task and average zero-shot accuracy (percent).
pub struct ZeroShotReport {
    pub per_task: Vec<(String, f64)>,
    pub average: f64,
}

/// Run the 6-task suite with `items` items per task.
pub fn zero_shot(
    cfg: &ModelConfig,
    w: &Weights,
    overrides: Option<&Overrides>,
    corpus: &Corpus,
    items: usize,
) -> ZeroShotReport {
    zero_shot_iq(cfg, w, overrides, corpus, items, InputQuant::None)
}

/// [`zero_shot`] with activation quantization (paper Apx B / Table 5).
pub fn zero_shot_iq(
    cfg: &ModelConfig,
    w: &Weights,
    overrides: Option<&Overrides>,
    corpus: &Corpus,
    items: usize,
    iq: InputQuant,
) -> ZeroShotReport {
    let suite = task_suite(&corpus.lang, items, 0x5u64);
    let mut per_task = Vec::with_capacity(suite.len());
    let mut sum = 0.0;
    for task in &suite {
        let acc = accuracy(task, |prefix, cont| {
            continuation_logprob_iq(cfg, w, prefix, cont, overrides, iq)
        });
        sum += acc;
        per_task.push((task.name.to_string(), acc));
    }
    ZeroShotReport { average: sum / suite.len() as f64, per_task }
}

/// Continuation log-probability with input quantization.
fn continuation_logprob_iq(
    cfg: &ModelConfig,
    w: &Weights,
    prefix: &[u32],
    continuation: &[u32],
    overrides: Option<&Overrides>,
    iq: InputQuant,
) -> f64 {
    let mut toks = prefix.to_vec();
    toks.extend_from_slice(continuation);
    let seq = toks.len().min(cfg.max_seq);
    let toks = &toks[toks.len() - seq..];
    let batch = Batch::new(toks.to_vec(), 1, seq);
    let logits = crate::model::transformer::forward_iq(cfg, w, &batch, None, overrides, iq);
    let start = seq - continuation.len().min(seq);
    let mut lp = 0.0f64;
    for s in start..seq {
        if s == 0 {
            continue;
        }
        let row = logits.row(s - 1);
        let target = toks[s] as usize;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        lp += (row[target] - lse) as f64;
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusSpec;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;

    #[test]
    fn untrained_model_ppl_near_vocab() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let corpus = Corpus::generate(CorpusSpec::SynthWeb, 20_000);
        let ppl = perplexity(&cfg, &w, None, &corpus, 4);
        // Untrained ≈ uniform over V=512.
        assert!(ppl > 300.0 && ppl < 800.0, "ppl {ppl}");
    }

    #[test]
    fn untrained_zero_shot_near_chance() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(2);
        let w = init(&cfg, &mut rng);
        let corpus = Corpus::generate(CorpusSpec::SynthWeb, 5_000);
        let report = zero_shot(&cfg, &w, None, &corpus, 20);
        assert_eq!(report.per_task.len(), 6);
        assert!((report.average - 50.0).abs() < 25.0, "avg {}", report.average);
    }
}
