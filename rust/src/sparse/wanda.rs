//! Wanda pruning (Sun et al. 2023) — score(i,j) = |W_ij| · ‖x_i‖₂.
//!
//! The pruner SLiM uses by default (paper §3.1 end). No weight updates, only
//! activation-weighted magnitude scoring; the activation norms come from the
//! calibration pass ([`crate::calib`]).

use super::mask::{mask_from_scores, Mask, SparsityPattern};
use crate::tensor::Matrix;

/// Prune with Wanda scores. `x_l2[i]` is the L2 norm of input channel `i`
/// over the calibration set.
pub fn prune(w: &Matrix, x_l2: &[f32], pattern: SparsityPattern) -> (Matrix, Mask) {
    assert_eq!(x_l2.len(), w.rows(), "activation norms must match d_in");
    let scores = Matrix::from_fn(w.rows(), w.cols(), |i, j| w.get(i, j).abs() * x_l2[i]);
    let mask = mask_from_scores(&scores, pattern);
    (mask.apply(w), mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::sparse::magnitude;

    #[test]
    fn activation_weighting_changes_selection() {
        // Small weight on a hot channel should beat a bigger weight on a
        // cold channel.
        let w = Matrix::from_vec(4, 1, vec![0.5, 0.6, 0.55, 0.58]);
        let x = vec![10.0, 0.1, 0.1, 0.1];
        let (_, mask) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        assert!(mask.get(0, 0), "hot-channel weight must survive");
    }

    #[test]
    fn reduces_output_error_vs_magnitude() {
        // Wanda's claim: lower ‖X(W − W^C)‖ than magnitude pruning when
        // activations are non-uniform.
        let mut rng = Pcg32::seeded(1);
        let d_in = 96;
        let d_out = 64;
        let w = Matrix::randn(d_in, d_out, 0.1, &mut rng);
        let mut x = Matrix::randn(128, d_in, 1.0, &mut rng);
        // Make every 4th channel hot so hotness varies *within* each 2:4
        // group — the regime where activation-weighted scoring matters.
        for i in 0..128 {
            for j in (0..d_in).step_by(4) {
                let v = x.get(i, j) * 8.0;
                x.set(i, j, v);
            }
        }
        let x_l2 = x.col_l2_norm();
        let (wp_wanda, _) = prune(&w, &x_l2, SparsityPattern::TWO_FOUR);
        let (wp_mag, _) = magnitude::prune(&w, SparsityPattern::TWO_FOUR);
        let err = |wp: &Matrix| x.matmul(&wp.sub(&w)).fro_norm_sq();
        assert!(
            err(&wp_wanda) < err(&wp_mag),
            "wanda {} vs magnitude {}",
            err(&wp_wanda),
            err(&wp_mag)
        );
    }

    #[test]
    fn uniform_activations_reduce_to_magnitude() {
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::randn(32, 16, 1.0, &mut rng);
        let x = vec![1.0; 32];
        let (wp_w, _) = prune(&w, &x, SparsityPattern::Unstructured(0.5));
        let (wp_m, _) = magnitude::prune(&w, SparsityPattern::Unstructured(0.5));
        assert_eq!(wp_w, wp_m);
    }

    #[test]
    fn exact_two_four() {
        let mut rng = Pcg32::seeded(3);
        let w = Matrix::randn(64, 48, 1.0, &mut rng);
        let x: Vec<f32> = (0..64).map(|_| rng.f32() + 0.1).collect();
        let (_, mask) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        assert!(mask.satisfies_nofm(2, 4));
    }
}
