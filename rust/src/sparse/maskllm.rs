//! MaskLLM-style optimized 2:4 masks (Fang et al. 2024), by local search.
//!
//! MaskLLM learns 2:4 masks with Gumbel-softmax against the end-to-end loss
//! on GPUs. The reproduction substitutes a greedy local-search optimizer of
//! the *layer-wise* output error `‖X(W⊙M − W)‖²` over the discrete space of
//! valid n:m group choices: starting from the Wanda mask, it repeatedly
//! proposes swapping a kept/dropped pair inside one group and accepts
//! improvements. This captures the paper's point (Table 3): masks optimized
//! beyond one-shot ranking beat Wanda's, and SLiM-LoRA stacks on top.
//!
//! The weights are *not* updated (MaskLLM keeps original weights intact).

use super::mask::{Mask, SparsityPattern};
use super::wanda;
use crate::rng::Pcg32;
use crate::tensor::Matrix;

/// Number of proposal sweeps over all (column, group) cells.
pub const SWEEPS: usize = 4;

/// Optimize a 2:4 (or n:m) mask by local search on layer output error.
pub fn prune(w: &Matrix, x: &Matrix, pattern: SparsityPattern) -> (Matrix, Mask) {
    let (n, m) = match pattern {
        SparsityPattern::NofM(n, m) => (n, m),
        // Unstructured falls back to Wanda (MaskLLM targets semi-structured).
        SparsityPattern::Unstructured(_) => {
            return wanda::prune(w, &x.col_l2_norm(), pattern);
        }
    };
    let (d_in, d_out) = w.shape();
    assert_eq!(x.cols(), d_in);
    let b = x.rows();

    // Start from the Wanda mask.
    let (_, mut mask) = wanda::prune(w, &x.col_l2_norm(), pattern);

    // Per-column residual r_j = X · (w_j ⊙ m_j − w_j) = −X · (w_j ⊙ (1−m_j)).
    // Maintained incrementally: flipping entry (i, j) from drop→keep adds
    // X[:, i]·w_ij to r_j; keep→drop subtracts it.
    let xt = x.transpose(); // d_in × b, rows are channel activation vectors
    let mut resid = vec![vec![0.0f32; b]; d_out];
    for j in 0..d_out {
        let r = &mut resid[j];
        for i in 0..d_in {
            if !mask.get(i, j) {
                let wij = w.get(i, j);
                if wij != 0.0 {
                    for (rv, &xv) in r.iter_mut().zip(xt.row(i)) {
                        *rv -= wij * xv;
                    }
                }
            }
        }
    }
    let norm_sq = |v: &[f32]| v.iter().map(|&t| (t as f64) * (t as f64)).sum::<f64>();

    let mut rng = Pcg32::seeded(0x5eed_11f3);
    let n_groups = d_in / m;
    for _sweep in 0..SWEEPS {
        let mut improved = 0usize;
        for j in 0..d_out {
            for g in 0..n_groups {
                let base = g * m;
                // Collect kept / dropped rows in this group.
                let kept: Vec<usize> = (base..base + m).filter(|&i| mask.get(i, j)).collect();
                let dropped: Vec<usize> = (base..base + m).filter(|&i| !mask.get(i, j)).collect();
                if kept.len() != n || dropped.is_empty() {
                    continue;
                }
                // Propose swapping a random kept with a random dropped row.
                let ik = kept[rng.below_usize(kept.len())];
                let id = dropped[rng.below_usize(dropped.len())];
                let (wk, wd) = (w.get(ik, j), w.get(id, j));
                let cur = norm_sq(&resid[j]);
                // Candidate residual: drop ik (subtract X_ik·wk), keep id
                // (add X_id·wd).
                let r = &mut resid[j];
                let xk = xt.row(ik);
                let xd = xt.row(id);
                for idx in 0..b {
                    r[idx] += -wk * xk[idx] + wd * xd[idx];
                }
                let cand = norm_sq(r);
                if cand + 1e-12 < cur {
                    mask.set(ik, j, false);
                    mask.set(id, j, true);
                    improved += 1;
                } else {
                    // Revert.
                    for idx in 0..b {
                        r[idx] -= -wk * xk[idx] + wd * xd[idx];
                    }
                }
            }
        }
        if improved == 0 {
            break;
        }
    }

    (mask.apply(w), mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::mask::SparsityPattern;

    fn calib(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let mut x = Matrix::randn(b, d, 1.0, &mut rng);
        for i in 0..b {
            for j in 0..d / 8 {
                let v = x.get(i, j) * 4.0;
                x.set(i, j, v);
            }
        }
        x
    }

    #[test]
    fn preserves_two_four() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(64, 24, 0.1, &mut rng);
        let x = calib(48, 64, 2);
        let (_, mask) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        assert!(mask.satisfies_nofm(2, 4));
    }

    #[test]
    fn not_worse_than_wanda() {
        // The whole point: optimized masks should match or beat the Wanda
        // starting point on layer output error.
        let mut rng = Pcg32::seeded(3);
        let w = Matrix::randn(96, 48, 0.1, &mut rng);
        let x = calib(64, 96, 4);
        let err = |wp: &Matrix| x.matmul(&wp.sub(&w)).fro_norm_sq();
        let (wp_mask, _) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        let (wp_wanda, _) = wanda::prune(&w, &x.col_l2_norm(), SparsityPattern::TWO_FOUR);
        assert!(
            err(&wp_mask) <= err(&wp_wanda) + 1e-9,
            "maskllm {} vs wanda {}",
            err(&wp_mask),
            err(&wp_wanda)
        );
    }

    #[test]
    fn strictly_improves_on_adversarial_case() {
        // Construct correlated activations where Wanda's myopic ranking is
        // suboptimal; local search must find a better mask.
        let mut rng = Pcg32::seeded(5);
        let b = 40;
        let d = 32;
        let mut x = Matrix::randn(b, d, 1.0, &mut rng);
        // Strongly correlate adjacent channel pairs.
        for i in 0..b {
            for j in (0..d).step_by(2) {
                let v = x.get(i, j);
                x.set(i, j + 1, v * 0.95 + x.get(i, j + 1) * 0.05);
            }
        }
        let w = Matrix::randn(d, 16, 0.2, &mut rng);
        let err = |wp: &Matrix| x.matmul(&wp.sub(&w)).fro_norm_sq();
        let (wp_mask, _) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        let (wp_wanda, _) = wanda::prune(&w, &x.col_l2_norm(), SparsityPattern::TWO_FOUR);
        assert!(err(&wp_mask) < err(&wp_wanda), "should strictly improve");
    }

    #[test]
    fn unstructured_falls_back() {
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::randn(32, 16, 0.1, &mut rng);
        let x = calib(32, 32, 8);
        let (wp, mask) = prune(&w, &x, SparsityPattern::Unstructured(0.5));
        assert!((mask.density() - 0.5).abs() < 0.02);
        assert!(wp.sparsity() > 0.45);
    }
}
