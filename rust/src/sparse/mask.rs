//! Sparsity patterns and masks.
//!
//! A [`Mask`] is a dense 0/1 byte matrix; [`SparsityPattern`] describes the
//! constraint: unstructured at a target ratio, or n:m semi-structured
//! (keep n of every m consecutive input-dim elements in a column's row
//! group — 2:4 is NVIDIA's hardware-accelerated pattern, Mishra et al.
//! 2021). Masks are built from per-element *scores* (higher = keep), so all
//! pruners share the same selection code and only differ in scoring.

use crate::tensor::Matrix;

/// Sparsity constraint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsityPattern {
    /// Keep the top (1−ratio) fraction of entries per layer.
    Unstructured(f32),
    /// Keep `n` of every `m` consecutive elements along the input dim.
    NofM(usize, usize),
}

impl SparsityPattern {
    /// The canonical 2:4 pattern.
    pub const TWO_FOUR: SparsityPattern = SparsityPattern::NofM(2, 4);

    /// Nominal zero fraction.
    pub fn ratio(&self) -> f32 {
        match self {
            SparsityPattern::Unstructured(r) => *r,
            SparsityPattern::NofM(n, m) => 1.0 - *n as f32 / *m as f32,
        }
    }

    pub fn name(&self) -> String {
        match self {
            SparsityPattern::Unstructured(r) => format!("{:.0}% unstructured", r * 100.0),
            SparsityPattern::NofM(n, m) => format!("{n}:{m}"),
        }
    }

    pub fn parse(s: &str) -> Option<SparsityPattern> {
        if let Some((n, m)) = s.split_once(':') {
            let n = n.parse().ok()?;
            let m = m.parse().ok()?;
            if n > m || m == 0 {
                return None;
            }
            return Some(SparsityPattern::NofM(n, m));
        }
        let r: f32 = s.strip_suffix('%').unwrap_or(s).parse().ok()?;
        let r = if r > 1.0 { r / 100.0 } else { r };
        (0.0..1.0).contains(&r).then_some(SparsityPattern::Unstructured(r))
    }
}

/// Binary keep-mask over a weight matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    rows: usize,
    cols: usize,
    keep: Vec<u8>,
}

impl Mask {
    /// All-ones (keep everything).
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mask { rows, cols, keep: vec![1; rows * cols] }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.keep[i * self.cols + j] != 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.keep[i * self.cols + j] = v as u8;
    }

    /// Raw bytes (1 = keep).
    pub fn bytes(&self) -> &[u8] {
        &self.keep
    }

    /// Fraction of kept entries.
    pub fn density(&self) -> f32 {
        if self.keep.is_empty() {
            return 1.0;
        }
        self.keep.iter().map(|&b| b as usize).sum::<usize>() as f32 / self.keep.len() as f32
    }

    /// Apply to a matrix: zero out dropped entries.
    pub fn apply(&self, w: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), w.shape());
        let mut out = w.clone();
        for (x, &k) in out.data_mut().iter_mut().zip(self.keep.iter()) {
            if k == 0 {
                *x = 0.0;
            }
        }
        out
    }

    /// As an f32 matrix of 0/1 (for HLO inputs).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.keep.iter().map(|&b| b as f32).collect())
    }

    /// Check an exact n:m pattern along the input dimension (columns of a
    /// d_in × d_out layout means groups run down each column).
    pub fn satisfies_nofm(&self, n: usize, m: usize) -> bool {
        for j in 0..self.cols {
            let mut i = 0;
            while i < self.rows {
                let end = (i + m).min(self.rows);
                let kept: usize = (i..end).map(|r| self.get(r, j) as usize).sum();
                let expect =
                    if end - i == m { n } else { ((end - i) * n).div_ceil(m).min(end - i) };
                if end - i == m && kept != expect {
                    return false;
                }
                i = end;
            }
        }
        true
    }
}

/// Build a mask from per-element scores under a pattern (higher score =
/// more important = keep). The shared selection backend for all pruners.
pub fn mask_from_scores(scores: &Matrix, pattern: SparsityPattern) -> Mask {
    let (rows, cols) = scores.shape();
    let mut mask = Mask { rows, cols, keep: vec![0; rows * cols] };
    match pattern {
        SparsityPattern::Unstructured(ratio) => {
            let n_total = rows * cols;
            let n_drop = ((n_total as f64) * ratio as f64).round() as usize;
            // Partial selection: sort indices by score ascending, drop first.
            let mut idx: Vec<u32> = (0..n_total as u32).collect();
            let data = scores.data();
            idx.sort_unstable_by(|&a, &b| {
                data[a as usize].partial_cmp(&data[b as usize]).unwrap_or(std::cmp::Ordering::Equal)
            });
            for &i in &idx[n_drop.min(n_total)..] {
                mask.keep[i as usize] = 1;
            }
        }
        SparsityPattern::NofM(n, m) => {
            // Groups run down the input dimension (rows) of each column.
            for j in 0..cols {
                let mut i = 0;
                while i < rows {
                    let end = (i + m).min(rows);
                    let glen = end - i;
                    let keep_k = if glen == m { n } else { (glen * n).div_ceil(m) };
                    // Top-keep_k scores in the group.
                    let mut g: Vec<(f32, usize)> =
                        (i..end).map(|r| (scores.get(r, j), r)).collect();
                    g.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
                    for &(_, r) in g.iter().take(keep_k) {
                        mask.keep[r * cols + j] = 1;
                    }
                    i = end;
                }
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn pattern_parse() {
        assert_eq!(SparsityPattern::parse("2:4"), Some(SparsityPattern::NofM(2, 4)));
        assert_eq!(SparsityPattern::parse("50%"), Some(SparsityPattern::Unstructured(0.5)));
        assert_eq!(SparsityPattern::parse("0.6"), Some(SparsityPattern::Unstructured(0.6)));
        assert_eq!(SparsityPattern::parse("5:4"), None);
    }

    #[test]
    fn unstructured_hits_ratio() {
        let mut rng = Pcg32::seeded(1);
        let scores = Matrix::randn(64, 64, 1.0, &mut rng);
        for &r in &[0.3f32, 0.5, 0.7] {
            let mask = mask_from_scores(&scores, SparsityPattern::Unstructured(r));
            assert!((mask.density() - (1.0 - r)).abs() < 0.01, "ratio {r}");
        }
    }

    #[test]
    fn two_four_is_exact() {
        let mut rng = Pcg32::seeded(2);
        let scores = Matrix::randn(128, 32, 1.0, &mut rng);
        let mask = mask_from_scores(&scores, SparsityPattern::TWO_FOUR);
        assert!(mask.satisfies_nofm(2, 4));
        assert!((mask.density() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn nofm_keeps_top_scores() {
        // Group scores 0,1,2,3 → keep rows with 2,3.
        let scores = Matrix::from_vec(4, 1, vec![0.0, 1.0, 2.0, 3.0]);
        let mask = mask_from_scores(&scores, SparsityPattern::TWO_FOUR);
        assert!(!mask.get(0, 0));
        assert!(!mask.get(1, 0));
        assert!(mask.get(2, 0));
        assert!(mask.get(3, 0));
    }

    #[test]
    fn ragged_nofm_group() {
        let scores = Matrix::from_vec(6, 1, vec![5.0, 1.0, 2.0, 3.0, 9.0, 0.0]);
        let mask = mask_from_scores(&scores, SparsityPattern::TWO_FOUR);
        // First full group keeps 2; trailing group of 2 keeps 1.
        let kept: usize = (0..6).map(|i| mask.get(i, 0) as usize).sum();
        assert_eq!(kept, 3);
        assert!(mask.get(4, 0));
    }

    #[test]
    fn apply_zeroes_dropped() {
        let w = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut mask = Mask::ones(2, 2);
        mask.set(0, 1, false);
        let wp = mask.apply(&w);
        assert_eq!(wp.data(), &[1.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn one_three_pattern() {
        let mut rng = Pcg32::seeded(3);
        let scores = Matrix::randn(99, 7, 1.0, &mut rng);
        let mask = mask_from_scores(&scores, SparsityPattern::NofM(1, 3));
        assert!(mask.satisfies_nofm(1, 3));
        assert!((mask.density() - 1.0 / 3.0).abs() < 0.02);
    }
}
