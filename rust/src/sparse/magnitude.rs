//! Magnitude pruning (Han et al. 2015) — score = |W|.
//!
//! The weakest baseline in every table of the paper; kept faithful so the
//! reproduction shows the same large gap to Wanda/SparseGPT.

use super::mask::{mask_from_scores, Mask, SparsityPattern};
use crate::tensor::Matrix;

/// Prune by absolute weight magnitude.
pub fn prune(w: &Matrix, pattern: SparsityPattern) -> (Matrix, Mask) {
    let scores = w.map(f32::abs);
    let mask = mask_from_scores(&scores, pattern);
    (mask.apply(w), mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn drops_smallest() {
        let w = Matrix::from_vec(4, 1, vec![0.1, -5.0, 0.2, 3.0]);
        let (wp, mask) = prune(&w, SparsityPattern::TWO_FOUR);
        assert_eq!(wp.data(), &[0.0, -5.0, 0.0, 3.0]);
        assert!(mask.satisfies_nofm(2, 4));
    }

    #[test]
    fn unstructured_ratio() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(100, 100, 1.0, &mut rng);
        let (wp, mask) = prune(&w, SparsityPattern::Unstructured(0.5));
        assert!((wp.sparsity() - 0.5).abs() < 0.01);
        assert!((mask.density() - 0.5).abs() < 0.01);
        // Error should equal norm of dropped (smallest) entries: smaller
        // than half the total norm for a Gaussian.
        let err = wp.sub(&w).fro_norm_sq();
        assert!(err < w.fro_norm_sq() * 0.25);
    }
}
