//! One-shot pruning methods (paper §3.2 precondition, Apx D/R, Table 1).
//!
//! SLiM applies an off-the-shelf one-shot pruner *after* quantization; the
//! paper uses Wanda by default and compares against magnitude pruning,
//! SparseGPT, and (Table 3) MaskLLM. All of them are implemented here over
//! a common [`SparsityPattern`] abstraction covering unstructured, n:m
//! semi-structured (2:4 being the hardware-accelerated case), and arbitrary
//! ratios.

pub mod magnitude;
pub mod mask;
pub mod maskllm;
pub mod sparsegpt;
pub mod wanda;

pub use mask::{Mask, SparsityPattern};

use crate::tensor::Matrix;

/// Which pruner to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneMethod {
    /// No pruning (quant-only experiments).
    None,
    /// Global magnitude pruning (Han et al. 2015).
    Magnitude,
    /// Wanda: score = |W| · ‖x‖₂ per column (Sun et al. 2023).
    Wanda,
    /// SparseGPT: OBS-based with Hessian error feedback.
    SparseGpt,
    /// MaskLLM-like: local-search mask optimization of the layer-wise
    /// output error (stands in for MaskLLM's learned masks).
    MaskLlm,
}

impl PruneMethod {
    pub fn parse(s: &str) -> Option<PruneMethod> {
        Some(match s {
            "none" => PruneMethod::None,
            "magnitude" => PruneMethod::Magnitude,
            "wanda" => PruneMethod::Wanda,
            "sparsegpt" => PruneMethod::SparseGpt,
            "maskllm" => PruneMethod::MaskLlm,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PruneMethod::None => "none",
            PruneMethod::Magnitude => "Magnitude",
            PruneMethod::Wanda => "Wanda",
            PruneMethod::SparseGpt => "SparseGPT",
            PruneMethod::MaskLlm => "MaskLLM*",
        }
    }
}

/// Prune `w` with the given method and pattern.
///
/// * `x_l2` — per-input-channel activation L2 norms (Wanda's metric).
/// * `x_calib` — calibration activations (SparseGPT / MaskLLM need them).
///
/// Returns the pruned weights (zeros in masked positions) and the mask.
pub fn prune(
    w: &Matrix,
    method: PruneMethod,
    pattern: SparsityPattern,
    x_l2: Option<&[f32]>,
    x_calib: Option<&Matrix>,
) -> (Matrix, Mask) {
    match method {
        PruneMethod::None => {
            let mask = Mask::ones(w.rows(), w.cols());
            (w.clone(), mask)
        }
        PruneMethod::Magnitude => magnitude::prune(w, pattern),
        PruneMethod::Wanda => {
            let x = x_l2.expect("Wanda requires activation norms");
            wanda::prune(w, x, pattern)
        }
        PruneMethod::SparseGpt => {
            let x = x_calib.expect("SparseGPT requires calibration activations");
            sparsegpt::prune(w, x, pattern)
        }
        PruneMethod::MaskLlm => {
            let x = x_calib.expect("MaskLLM requires calibration activations");
            maskllm::prune(w, x, pattern)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn parse_and_names() {
        assert_eq!(PruneMethod::parse("wanda"), Some(PruneMethod::Wanda));
        assert_eq!(PruneMethod::parse("nope"), None);
        assert_eq!(PruneMethod::SparseGpt.name(), "SparseGPT");
    }

    #[test]
    fn none_keeps_everything() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let (wp, mask) =
            prune(&w, PruneMethod::None, SparsityPattern::Unstructured(0.5), None, None);
        assert_eq!(wp, w);
        assert_eq!(mask.density(), 1.0);
    }
}
