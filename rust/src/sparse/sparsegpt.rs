//! SparseGPT-style pruning (Frantar & Alistarh 2023).
//!
//! OBS-based one-shot pruning: saliency `s_ij = w_ij² / [H⁻¹]_ii` selects
//! what to drop, and dropping an entry redistributes its contribution into
//! the not-yet-processed rows through the inverse Hessian (the same error
//! feedback OPTQ uses for quantization):
//!
//! ```text
//!   err   = w_ij / [H⁻¹]_ii
//!   w_rj -= [H⁻¹]_ri · err    for r > i
//! ```
//!
//! We use the mask-then-reconstruct formulation: the mask is chosen from
//! OBS saliencies up front (per pattern group), then one sweep over the
//! input dims applies the feedback updates. This keeps the n:m constraint
//! exact while retaining SparseGPT's weight-update advantage over Wanda.

use super::mask::{mask_from_scores, Mask, SparsityPattern};
use crate::linalg::spd_inverse;
use crate::tensor::{matmul_at_b, Matrix};

/// Hessian damping fraction (matches the reference implementation).
pub const DAMP: f32 = 0.01;

/// Prune with SparseGPT given calibration activations `x` (b × d_in).
pub fn prune(w: &Matrix, x: &Matrix, pattern: SparsityPattern) -> (Matrix, Mask) {
    let (d_in, d_out) = w.shape();
    assert_eq!(x.cols(), d_in, "calibration activations must be b x d_in");
    // Damped inverse Hessian.
    let mut h = matmul_at_b(x, x);
    let mean_diag = (0..d_in).map(|i| h.get(i, i) as f64).sum::<f64>() as f32 / d_in as f32;
    let damp = (DAMP * mean_diag).max(1e-8);
    for i in 0..d_in {
        h.set(i, i, h.get(i, i) + damp);
    }
    let hinv = spd_inverse(&h).expect("damped Hessian must be SPD");

    // OBS saliency scores: w² / [H⁻¹]_ii  (higher = more important).
    let scores = Matrix::from_fn(d_in, d_out, |i, j| {
        let wij = w.get(i, j);
        wij * wij / hinv.get(i, i).max(1e-10)
    });
    let mask = mask_from_scores(&scores, pattern);

    // Sweep: zero masked entries, push their error into later rows.
    let mut work = w.clone();
    for i in 0..d_in {
        let hii = hinv.get(i, i).max(1e-10);
        for j in 0..d_out {
            if !mask.get(i, j) {
                let err = work.get(i, j) / hii;
                if err != 0.0 {
                    for r in i + 1..d_in {
                        let hri = hinv.get(r, i);
                        if hri != 0.0 {
                            work.set(r, j, work.get(r, j) - hri * err);
                        }
                    }
                }
                work.set(i, j, 0.0);
            }
        }
    }
    // Masked entries are exactly zero; kept entries carry the updates.
    (mask.apply(&work), mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::sparse::{magnitude, wanda};

    fn calib(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let mut x = Matrix::randn(b, d, 1.0, &mut rng);
        for i in 0..b {
            for j in 0..d / 12 {
                let v = x.get(i, j) * 5.0;
                x.set(i, j, v);
            }
        }
        x
    }

    #[test]
    fn exact_two_four_pattern() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(64, 32, 0.1, &mut rng);
        let x = calib(96, 64, 2);
        let (wp, mask) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        assert!(mask.satisfies_nofm(2, 4));
        assert!((wp.sparsity() - 0.5).abs() < 0.02);
    }

    #[test]
    fn beats_magnitude_on_output_error() {
        let mut rng = Pcg32::seeded(3);
        let w = Matrix::randn(80, 48, 0.1, &mut rng);
        let x = calib(128, 80, 4);
        let err = |wp: &Matrix| x.matmul(&wp.sub(&w)).fro_norm_sq();
        let (wp_sg, _) = prune(&w, &x, SparsityPattern::TWO_FOUR);
        let (wp_mag, _) = magnitude::prune(&w, SparsityPattern::TWO_FOUR);
        assert!(err(&wp_sg) < err(&wp_mag), "sgpt {} vs mag {}", err(&wp_sg), err(&wp_mag));
    }

    #[test]
    fn weight_update_helps_vs_wanda_masking() {
        // SparseGPT updates surviving weights; at equal masks quality it
        // should not be worse than Wanda's prune-only on output error.
        let mut rng = Pcg32::seeded(5);
        let w = Matrix::randn(96, 64, 0.1, &mut rng);
        let x = calib(160, 96, 6);
        let err = |wp: &Matrix| x.matmul(&wp.sub(&w)).fro_norm_sq();
        let (wp_sg, _) = prune(&w, &x, SparsityPattern::Unstructured(0.5));
        let (wp_wanda, _) = wanda::prune(&w, &x.col_l2_norm(), SparsityPattern::Unstructured(0.5));
        assert!(
            err(&wp_sg) < err(&wp_wanda) * 1.05,
            "sgpt {} vs wanda {}",
            err(&wp_sg),
            err(&wp_wanda)
        );
    }

    #[test]
    fn unstructured_ratio_respected() {
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::randn(60, 40, 0.1, &mut rng);
        let x = calib(90, 60, 8);
        let (wp, mask) = prune(&w, &x, SparsityPattern::Unstructured(0.6));
        assert!((mask.density() - 0.4).abs() < 0.02);
        assert!(wp.sparsity() >= 0.58);
    }
}
