//! The "synthlang" corpus generator.
//!
//! Token space (V = 512):
//! * `0` BOS, `1` SEP (sentence break), `2` REL1, `3` REL2 — special.
//! * `8..136` — 128 entity tokens `e`.
//! * `136..264` — attribute-1 tokens (`attr1(e)` is a seeded bijection).
//! * `264..392` — attribute-2 tokens (`attr2(e)` likewise).
//! * `392..512` — filler tokens with a Zipfian unigram prior and a sparse
//!   first-order Markov transition table.
//!
//! Sentences are drawn from four templates (facts about `attr1`/`attr2`,
//! Markov filler phrases, alternating patterns). Two named corpora —
//! `synth-web` and `synth-pajama` — share the fact mappings and the Markov
//! backbone (same "language") but differ in template mix and sampling seed,
//! mirroring C4 vs SlimPajama for the calibration-sensitivity study (T22).

use crate::rng::{Pcg32, Zipf};

pub const VOCAB: usize = 512;
pub const BOS: u32 = 0;
pub const SEP: u32 = 1;
pub const REL1: u32 = 2;
pub const REL2: u32 = 3;
pub const N_ENTITIES: usize = 128;
pub const ENTITY_BASE: u32 = 8;
pub const ATTR1_BASE: u32 = 136;
pub const ATTR2_BASE: u32 = 264;
pub const FILLER_BASE: u32 = 392;
pub const N_FILLER: usize = VOCAB - FILLER_BASE as usize;

/// Which named corpus to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusSpec {
    /// Primary corpus (C4 stand-in): balanced template mix.
    SynthWeb,
    /// Alternate corpus (SlimPajama stand-in): filler-heavy mix, different
    /// sampling stream.
    SynthPajama,
}

impl CorpusSpec {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusSpec::SynthWeb => "synth-web",
            CorpusSpec::SynthPajama => "synth-pajama",
        }
    }

    fn sample_seed(&self) -> u64 {
        match self {
            CorpusSpec::SynthWeb => 0xC0FFEE,
            CorpusSpec::SynthPajama => 0xBADCAB,
        }
    }

    /// Template probabilities: (fact1, fact2, filler, pattern).
    fn mix(&self) -> [f32; 4] {
        match self {
            CorpusSpec::SynthWeb => [0.22, 0.22, 0.41, 0.15],
            CorpusSpec::SynthPajama => [0.15, 0.15, 0.55, 0.15],
        }
    }
}

/// Shared language structure (same across corpora — seeded independently
/// of the sampling stream).
pub struct Language {
    /// attr1 bijection: entity index → attribute-1 token.
    pub attr1: Vec<u32>,
    /// attr2 bijection.
    pub attr2: Vec<u32>,
    /// Markov successor table: filler index → (succ tokens, probs).
    pub successors: Vec<(Vec<u32>, Vec<f32>)>,
    /// Zipf sampler over filler ranks.
    zipf: Zipf,
    /// Zipf rank → filler token (seeded permutation).
    rank_to_filler: Vec<u32>,
}

impl Language {
    /// Build the shared language (fixed seed — it IS the language).
    pub fn shared() -> Language {
        let mut rng = Pcg32::seeded(0x11a6_0a6e);
        let mut perm1: Vec<u32> = (0..N_ENTITIES as u32).collect();
        let mut perm2: Vec<u32> = (0..N_ENTITIES as u32).collect();
        rng.shuffle(&mut perm1);
        rng.shuffle(&mut perm2);
        let attr1 = perm1.iter().map(|&i| ATTR1_BASE + i).collect();
        let attr2 = perm2.iter().map(|&i| ATTR2_BASE + i).collect();
        // Sparse Markov chain: each filler has 3 successors with peaked
        // probabilities (0.6 / 0.3 / 0.1) — learnable bigram structure.
        let mut successors = Vec::with_capacity(N_FILLER);
        for _ in 0..N_FILLER {
            let mut succ = Vec::with_capacity(3);
            while succ.len() < 3 {
                let cand = FILLER_BASE + rng.below(N_FILLER as u32);
                if !succ.contains(&cand) {
                    succ.push(cand);
                }
            }
            successors.push((succ, vec![0.6, 0.3, 0.1]));
        }
        let mut rank_to_filler: Vec<u32> =
            (0..N_FILLER as u32).map(|i| FILLER_BASE + i).collect();
        rng.shuffle(&mut rank_to_filler);
        Language { attr1, attr2, successors, zipf: Zipf::new(N_FILLER, 1.05), rank_to_filler }
    }

    /// attr1 of entity index.
    pub fn attr1_of(&self, ent: usize) -> u32 {
        self.attr1[ent]
    }

    pub fn attr2_of(&self, ent: usize) -> u32 {
        self.attr2[ent]
    }

    /// The most likely successor of a filler token.
    pub fn top_successor(&self, filler: u32) -> u32 {
        self.successors[(filler - FILLER_BASE) as usize].0[0]
    }

    /// The least likely listed successor.
    pub fn weak_successor(&self, filler: u32) -> u32 {
        self.successors[(filler - FILLER_BASE) as usize].0[2]
    }

    fn sample_filler(&self, rng: &mut Pcg32) -> u32 {
        self.rank_to_filler[self.zipf.sample(rng)]
    }
}

/// A generated token stream with train/eval splits.
pub struct Corpus {
    pub spec: CorpusSpec,
    pub lang: Language,
    pub train: Vec<u32>,
    pub eval: Vec<u32>,
}

impl Corpus {
    /// Generate `n_tokens` of training text plus 1/8 of that for eval.
    pub fn generate(spec: CorpusSpec, n_tokens: usize) -> Corpus {
        let lang = Language::shared();
        let mut rng = Pcg32::seeded(spec.sample_seed());
        let train = gen_stream(&lang, spec, n_tokens, &mut rng);
        let eval = gen_stream(&lang, spec, n_tokens / 8 + 256, &mut rng);
        Corpus { spec, lang, train, eval }
    }

    /// Sample a training batch of `batch` windows of length `seq`.
    pub fn batch(&self, batch: usize, seq: usize, rng: &mut Pcg32) -> Vec<u32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below_usize(self.train.len() - seq);
            out.extend_from_slice(&self.train[start..start + seq]);
        }
        out
    }

    /// Deterministic eval windows (for perplexity).
    pub fn eval_windows(&self, seq: usize, max_windows: usize) -> Vec<Vec<u32>> {
        self.eval
            .chunks_exact(seq)
            .take(max_windows)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Calibration windows from the train stream (paper: 128 sequences).
    pub fn calibration(&self, n_seqs: usize, seq: usize, rng: &mut Pcg32) -> Vec<u32> {
        self.batch(n_seqs, seq, rng)
    }
}

fn gen_stream(lang: &Language, spec: CorpusSpec, n_tokens: usize, rng: &mut Pcg32) -> Vec<u32> {
    let mix = spec.mix();
    let mut out = Vec::with_capacity(n_tokens + 16);
    out.push(BOS);
    while out.len() < n_tokens {
        match rng.categorical(&mix) {
            0 => {
                // fact1: e REL1 attr1(e) SEP
                let e = rng.below_usize(N_ENTITIES);
                out.extend_from_slice(&[ENTITY_BASE + e as u32, REL1, lang.attr1_of(e), SEP]);
            }
            1 => {
                let e = rng.below_usize(N_ENTITIES);
                out.extend_from_slice(&[ENTITY_BASE + e as u32, REL2, lang.attr2_of(e), SEP]);
            }
            2 => {
                // Markov filler phrase of length 4..=10.
                let len = 4 + rng.below_usize(7);
                let mut t = lang.sample_filler(rng);
                out.push(t);
                for _ in 1..len {
                    let (succ, probs) = &lang.successors[(t - FILLER_BASE) as usize];
                    t = succ[rng.categorical(probs)];
                    out.push(t);
                }
                out.push(SEP);
            }
            _ => {
                // Alternating pattern a b a b a b SEP.
                let a = lang.sample_filler(rng);
                let mut b = lang.sample_filler(rng);
                if b == a {
                    b = lang.top_successor(a);
                }
                for k in 0..6 {
                    out.push(if k % 2 == 0 { a } else { b });
                }
                out.push(SEP);
            }
        }
    }
    out.truncate(n_tokens);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::generate(CorpusSpec::SynthWeb, 5_000);
        assert!(c.train.iter().all(|&t| (t as usize) < VOCAB));
        assert_eq!(c.train.len(), 5_000);
        assert!(c.eval.len() >= 256);
    }

    #[test]
    fn deterministic_per_spec() {
        let a = Corpus::generate(CorpusSpec::SynthWeb, 2_000);
        let b = Corpus::generate(CorpusSpec::SynthWeb, 2_000);
        assert_eq!(a.train, b.train);
    }

    #[test]
    fn corpora_differ_but_share_language() {
        let a = Corpus::generate(CorpusSpec::SynthWeb, 2_000);
        let b = Corpus::generate(CorpusSpec::SynthPajama, 2_000);
        assert_ne!(a.train, b.train);
        assert_eq!(a.lang.attr1, b.lang.attr1); // same facts
    }

    #[test]
    fn facts_are_consistent() {
        // Every (e, REL1, x) trigram in the stream must satisfy x=attr1(e).
        let c = Corpus::generate(CorpusSpec::SynthWeb, 20_000);
        let mut checked = 0;
        for w in c.train.windows(3) {
            if w[1] == REL1 && (ENTITY_BASE..ATTR1_BASE).contains(&w[0]) {
                let e = (w[0] - ENTITY_BASE) as usize;
                assert_eq!(w[2], c.lang.attr1_of(e));
                checked += 1;
            }
        }
        assert!(checked > 100, "checked only {checked} facts");
    }

    #[test]
    fn batches_have_right_shape() {
        let c = Corpus::generate(CorpusSpec::SynthWeb, 10_000);
        let mut rng = Pcg32::seeded(1);
        let b = c.batch(4, 32, &mut rng);
        assert_eq!(b.len(), 128);
        let windows = c.eval_windows(64, 10);
        assert_eq!(windows.len(), 10);
        assert!(windows.iter().all(|w| w.len() == 64));
    }

    #[test]
    fn zipf_profile_on_fillers() {
        let c = Corpus::generate(CorpusSpec::SynthWeb, 50_000);
        let mut counts = vec![0usize; VOCAB];
        for &t in &c.train {
            counts[t as usize] += 1;
        }
        let filler_counts: Vec<usize> =
            counts[FILLER_BASE as usize..].iter().copied().collect();
        let max = *filler_counts.iter().max().unwrap();
        let median = {
            let mut s = filler_counts.clone();
            s.sort();
            s[s.len() / 2]
        };
        assert!(max > median * 3, "long tail expected: max {max} median {median}");
    }
}
