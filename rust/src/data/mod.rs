//! Synthetic language data (the C4 / WikiText2 / zero-shot-suite stand-ins).
//!
//! See DESIGN.md §2: real corpora aren't available in this environment, so
//! [`corpus`] defines a seeded stochastic language ("synthlang") with
//! learnable structure — Zipfian unigrams, a bigram Markov backbone,
//! deterministic entity→attribute facts and repeating patterns — and
//! [`tasks`] derives a 6-task multiple-choice suite from it (likelihood
//! ranking, lm-eval style) mirroring the paper's 6-task zero-shot average.

pub mod corpus;
pub mod tasks;

pub use corpus::{Corpus, CorpusSpec};
pub use tasks::{accuracy, task_suite, TaskItem, ZeroShotTask};
