//! Six-task synthetic zero-shot suite (the MMLU/PiQA/ARC/WinoGrande/OBQA
//! stand-in; see DESIGN.md §2).
//!
//! Each task is a set of two-way multiple-choice items scored by likelihood
//! ranking, exactly as the Language Model Evaluation Harness scores
//! multiple-choice zero-shot tasks: the model is correct when it assigns
//! the true continuation a higher log-probability than the distractor.
//! Chance is 50%; a trained dense sim model scores well above it, and
//! compression degrades the score — giving the same dynamic range the
//! paper's accuracy tables rely on.

use super::corpus::{Language, BOS, ENTITY_BASE, N_ENTITIES, REL1, REL2, SEP};
use crate::rng::Pcg32;

/// One two-way multiple-choice item.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prefix: Vec<u32>,
    pub correct: Vec<u32>,
    pub distractor: Vec<u32>,
}

/// A named task with its items.
#[derive(Clone, Debug)]
pub struct ZeroShotTask {
    pub name: &'static str,
    pub items: Vec<TaskItem>,
}

/// Build the full 6-task suite with `n` items per task.
pub fn task_suite(lang: &Language, n: usize, seed: u64) -> Vec<ZeroShotTask> {
    let mut rng = Pcg32::seeded(seed);
    vec![
        fact_recall_1(lang, n, &mut rng),
        fact_recall_2(lang, n, &mut rng),
        bigram_choice(lang, n, &mut rng),
        pattern_completion(lang, n, &mut rng),
        contextual_recall(lang, n, &mut rng),
        phrase_plausibility(lang, n, &mut rng),
    ]
}

fn two_entities(rng: &mut Pcg32) -> (usize, usize) {
    let e = rng.below_usize(N_ENTITIES);
    let mut o = rng.below_usize(N_ENTITIES);
    while o == e {
        o = rng.below_usize(N_ENTITIES);
    }
    (e, o)
}

/// Task 1 — "fact-recall-1" (MMLU-ish): `e REL1 → attr1(e)` vs attr1(e').
fn fact_recall_1(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let (e, o) = two_entities(rng);
            TaskItem {
                prefix: vec![BOS, ENTITY_BASE + e as u32, REL1],
                correct: vec![lang.attr1_of(e)],
                distractor: vec![lang.attr1_of(o)],
            }
        })
        .collect();
    ZeroShotTask { name: "fact-recall-1", items }
}

/// Task 2 — "fact-recall-2": same over the REL2/attr2 mapping.
fn fact_recall_2(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let (e, o) = two_entities(rng);
            TaskItem {
                prefix: vec![BOS, ENTITY_BASE + e as u32, REL2],
                correct: vec![lang.attr2_of(e)],
                distractor: vec![lang.attr2_of(o)],
            }
        })
        .collect();
    ZeroShotTask { name: "fact-recall-2", items }
}

/// Task 3 — "bigram-choice" (PiQA-ish plausibility): strong successor vs
/// weak successor of a filler token.
fn bigram_choice(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let f = super::corpus::FILLER_BASE + rng.below(super::corpus::N_FILLER as u32);
            TaskItem {
                prefix: vec![BOS, f],
                correct: vec![lang.top_successor(f)],
                distractor: vec![lang.weak_successor(f)],
            }
        })
        .collect();
    ZeroShotTask { name: "bigram-choice", items }
}

/// Task 4 — "pattern-completion" (WinoGrande-ish): `a b a b a → b` vs a
/// random filler.
fn pattern_completion(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let base = super::corpus::FILLER_BASE;
            let a = base + rng.below(super::corpus::N_FILLER as u32);
            let mut b = base + rng.below(super::corpus::N_FILLER as u32);
            if b == a {
                b = lang.top_successor(a);
            }
            let mut d = base + rng.below(super::corpus::N_FILLER as u32);
            while d == b || d == a {
                d = base + rng.below(super::corpus::N_FILLER as u32);
            }
            TaskItem {
                prefix: vec![BOS, a, b, a, b, a],
                correct: vec![b],
                distractor: vec![d],
            }
        })
        .collect();
    ZeroShotTask { name: "pattern-completion", items }
}

/// Task 5 — "contextual-recall" (ARC-ish): the fact appears in context,
/// then is queried again: `e REL1 attr1(e) SEP e REL1 → attr1(e)`.
fn contextual_recall(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let (e, o) = two_entities(rng);
            let et = ENTITY_BASE + e as u32;
            TaskItem {
                prefix: vec![BOS, et, REL1, lang.attr1_of(e), SEP, et, REL1],
                correct: vec![lang.attr1_of(e)],
                distractor: vec![lang.attr1_of(o)],
            }
        })
        .collect();
    ZeroShotTask { name: "contextual-recall", items }
}

/// Task 6 — "phrase-plausibility" (OBQA-ish): a 3-step Markov phrase vs the
/// same phrase with the last step replaced by a non-successor.
fn phrase_plausibility(lang: &Language, n: usize, rng: &mut Pcg32) -> ZeroShotTask {
    let items = (0..n)
        .map(|_| {
            let base = super::corpus::FILLER_BASE;
            let a = base + rng.below(super::corpus::N_FILLER as u32);
            let b = lang.top_successor(a);
            let c = lang.top_successor(b);
            let mut d = base + rng.below(super::corpus::N_FILLER as u32);
            while d == c {
                d = base + rng.below(super::corpus::N_FILLER as u32);
            }
            TaskItem { prefix: vec![BOS, a, b], correct: vec![c], distractor: vec![d] }
        })
        .collect();
    ZeroShotTask { name: "phrase-plausibility", items }
}

/// Score one task given a log-probability oracle: returns accuracy in
/// percent. `logprob(prefix, continuation)` must return the summed
/// continuation log-probability.
pub fn accuracy(task: &ZeroShotTask, mut logprob: impl FnMut(&[u32], &[u32]) -> f64) -> f64 {
    if task.items.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for item in &task.items {
        let lp_c = logprob(&item.prefix, &item.correct);
        let lp_d = logprob(&item.prefix, &item.distractor);
        if lp_c > lp_d {
            correct += 1;
        }
    }
    100.0 * correct as f64 / task.items.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_six_tasks() {
        let lang = Language::shared();
        let suite = task_suite(&lang, 20, 7);
        assert_eq!(suite.len(), 6);
        for t in &suite {
            assert_eq!(t.items.len(), 20);
            for item in &t.items {
                assert_ne!(item.correct, item.distractor);
                assert!(!item.prefix.is_empty());
            }
        }
    }

    #[test]
    fn suite_is_deterministic() {
        let lang = Language::shared();
        let a = task_suite(&lang, 10, 3);
        let b = task_suite(&lang, 10, 3);
        assert_eq!(a[0].items[0].prefix, b[0].items[0].prefix);
    }

    #[test]
    fn accuracy_with_perfect_oracle_is_100() {
        let lang = Language::shared();
        let suite = task_suite(&lang, 25, 9);
        // Oracle: knows the language — score correct continuations higher.
        for t in &suite {
            let truth: std::collections::HashSet<(Vec<u32>, Vec<u32>)> = t
                .items
                .iter()
                .map(|i| (i.prefix.clone(), i.correct.clone()))
                .collect();
            let acc = accuracy(t, |p, c| {
                if truth.contains(&(p.to_vec(), c.to_vec())) {
                    -1.0
                } else {
                    -2.0
                }
            });
            assert_eq!(acc, 100.0, "{}", t.name);
        }
    }

    #[test]
    fn accuracy_with_random_oracle_near_50() {
        let lang = Language::shared();
        let suite = task_suite(&lang, 400, 11);
        let mut rng = Pcg32::seeded(1);
        let acc = accuracy(&suite[0], |_, _| rng.f64());
        assert!((acc - 50.0).abs() < 10.0, "acc {acc}");
    }
}
