//! Bit-packing of quantized codes for the runtime kernels.
//!
//! Signed codes are biased to unsigned and packed two-per-byte (int4) or
//! four-per-byte (int2). The packed layout is row-major over the logical
//! matrix; the 2:4-sparse kernel additionally compresses the zeroed lanes
//! (see [`crate::kernels::sparse24`]).

/// Packed 4-bit codes (two per byte, low nibble first).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedInt4 {
    pub bytes: Vec<u8>,
    pub len: usize,
}

/// Pack signed 4-bit codes in [-8, 7] (we only produce [-7, 7]).
pub fn pack_int4(codes: &[i8]) -> PackedInt4 {
    let mut bytes = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0] + 8) as u8 & 0x0F;
        let hi = if pair.len() > 1 { (pair[1] + 8) as u8 & 0x0F } else { 0 };
        bytes.push(lo | (hi << 4));
    }
    PackedInt4 { bytes, len: codes.len() }
}

/// Unpack back to signed codes.
pub fn unpack_int4(p: &PackedInt4) -> Vec<i8> {
    let mut out = Vec::with_capacity(p.len);
    for &b in &p.bytes {
        out.push((b & 0x0F) as i8 - 8);
        if out.len() < p.len {
            out.push((b >> 4) as i8 - 8);
        }
    }
    out.truncate(p.len);
    out
}

/// Packed 2-bit codes (four per byte).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedInt2 {
    pub bytes: Vec<u8>,
    pub len: usize,
}

/// Pack signed 2-bit codes in [-2, 1] (we produce [-1, 1]).
pub fn pack_int2(codes: &[i8]) -> PackedInt2 {
    let mut bytes = Vec::with_capacity(codes.len().div_ceil(4));
    for quad in codes.chunks(4) {
        let mut b = 0u8;
        for (k, &c) in quad.iter().enumerate() {
            b |= (((c + 2) as u8) & 0x03) << (2 * k);
        }
        bytes.push(b);
    }
    PackedInt2 { bytes, len: codes.len() }
}

/// Unpack 2-bit codes.
pub fn unpack_int2(p: &PackedInt2) -> Vec<i8> {
    let mut out = Vec::with_capacity(p.len);
    for &b in &p.bytes {
        for k in 0..4 {
            if out.len() < p.len {
                out.push(((b >> (2 * k)) & 0x03) as i8 - 2);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn int4_round_trip() {
        let mut rng = Pcg32::seeded(1);
        let codes: Vec<i8> = (0..1001).map(|_| rng.below(15) as i8 - 7).collect();
        let p = pack_int4(&codes);
        assert_eq!(p.bytes.len(), 501);
        assert_eq!(unpack_int4(&p), codes);
    }

    #[test]
    fn int4_even_length() {
        let codes: Vec<i8> = vec![-7, 7, 0, 3];
        assert_eq!(unpack_int4(&pack_int4(&codes)), codes);
    }

    #[test]
    fn int2_round_trip() {
        let mut rng = Pcg32::seeded(2);
        let codes: Vec<i8> = (0..1003).map(|_| rng.below(3) as i8 - 1).collect();
        let p = pack_int2(&codes);
        assert_eq!(p.bytes.len(), 251);
        assert_eq!(unpack_int2(&p), codes);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(unpack_int4(&pack_int4(&[])), Vec::<i8>::new());
        assert_eq!(unpack_int2(&pack_int2(&[])), Vec::<i8>::new());
    }

    #[test]
    fn int4_memory_is_half() {
        let codes = vec![0i8; 4096];
        assert_eq!(pack_int4(&codes).bytes.len(), 2048);
    }
}
