//! OPTQ (GPTQ)-style Hessian-aware quantization with error feedback.
//!
//! This is the quantizer paired with SparseGPT in the paper's Table 1
//! ("Group OPTQ"). For each input-dim row `i` (processed in order), the row
//! is quantized against per-group scales and the resulting error is
//! propagated into the not-yet-quantized rows using the inverse Hessian:
//!
//! ```text
//!   E_i   = (W_i − Q(W_i)) / [H⁻¹]_ii
//!   W_j  -= [H⁻¹]_ji · E_i      for j > i
//! ```
//!
//! The Hessian is the layer-wise `H = XᵀX + λI` from calibration
//! activations (λ = 1% mean diagonal damping, as in the GPTQ reference
//! implementation).

use super::{fake_quant_value, quant_code, Quantized};
use crate::linalg::spd_inverse;
use crate::tensor::Matrix;

/// Damping fraction applied to the Hessian diagonal.
pub const DAMP: f32 = 0.01;

/// OPTQ-quantize `w` (d_in × d_out) given `hessian = XᵀX` (d_in × d_in),
/// with AbsMax group scales of `group_size` along the input dimension
/// (`group_size == 0` → per-tensor scale).
pub fn quantize(w: &Matrix, bits: u8, hessian: &Matrix, group_size: usize) -> Quantized {
    let (d_in, d_out) = w.shape();
    assert_eq!(hessian.shape(), (d_in, d_in), "hessian must be d_in x d_in");

    // Damped Hessian inverse.
    let mut h = hessian.clone();
    let mean_diag =
        (0..d_in).map(|i| h.get(i, i) as f64).sum::<f64>() as f32 / d_in as f32;
    let damp = (DAMP * mean_diag).max(1e-8);
    for i in 0..d_in {
        h.set(i, i, h.get(i, i) + damp);
    }
    let hinv = spd_inverse(&h).expect("damped Hessian must be SPD");

    // Group scales computed on the *running* weights as each group starts,
    // matching GPTQ's act-order-free variant.
    let gsize = if group_size == 0 { d_in } else { group_size };
    let mut work = w.clone();
    let mut wq = Matrix::zeros(d_in, d_out);
    let mut codes = vec![0i8; d_in * d_out];
    let mut scales: Vec<f32> = Vec::new();
    let mut group_scale = vec![0.0f32; d_out];

    for i in 0..d_in {
        if i % gsize == 0 {
            // Recompute AbsMax scales for this group from the updated
            // weights (error feedback may have grown them).
            let end = (i + gsize).min(d_in);
            for j in 0..d_out {
                let mut m = 0.0f32;
                for r in i..end {
                    m = m.max(work.get(r, j).abs());
                }
                group_scale[j] = m;
            }
            scales.extend_from_slice(&group_scale);
        }
        let hii = hinv.get(i, i).max(1e-10);
        // Quantize row i and push the error into the remaining rows.
        for j in 0..d_out {
            let x = work.get(i, j);
            let alpha = group_scale[j];
            let q = fake_quant_value(x, alpha, bits);
            wq.set(i, j, q);
            codes[i * d_out + j] = quant_code(x, alpha, bits);
            let err = (x - q) / hii;
            if err != 0.0 {
                for r in i + 1..d_in {
                    let hri = hinv.get(r, i);
                    if hri != 0.0 {
                        work.set(r, j, work.get(r, j) - hri * err);
                    }
                }
            }
        }
    }

    Quantized { wq, codes, scales, group_size: gsize, bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group_absmax;
    use crate::rng::Pcg32;
    use crate::tensor::matmul_at_b;

    fn calib_activations(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        // Correlated activations with a few hot channels, like real LLMs.
        let mut x = Matrix::randn(b, d, 1.0, &mut rng);
        for i in 0..b {
            for j in 0..d / 16 {
                let v = x.get(i, j) * 6.0;
                x.set(i, j, v);
            }
        }
        x
    }

    #[test]
    fn output_error_beats_rtn() {
        // OPTQ's defining property: lower layer-output error ‖X(W−Wq)‖ than
        // round-to-nearest with the same scales.
        let mut rng = Pcg32::seeded(1);
        let d_in = 64;
        let d_out = 48;
        let w = Matrix::from_fn(d_in, d_out, |_, _| rng.laplace(0.05));
        let x = calib_activations(256, d_in, 2);
        let h = matmul_at_b(&x, &x);
        let q_optq = quantize(&w, 4, &h, 32);
        let q_rtn = group_absmax::quantize(&w, 4, 32);
        let out_err = |wq: &Matrix| x.matmul(&wq.sub(&w)).fro_norm_sq();
        let e_optq = out_err(&q_optq.wq);
        let e_rtn = out_err(&q_rtn.wq);
        assert!(e_optq < e_rtn, "optq {e_optq} vs rtn {e_rtn}");
    }

    #[test]
    fn shapes_and_code_range() {
        let mut rng = Pcg32::seeded(3);
        let w = Matrix::randn(32, 16, 0.1, &mut rng);
        let x = calib_activations(64, 32, 4);
        let h = matmul_at_b(&x, &x);
        let q = quantize(&w, 4, &h, 16);
        assert_eq!(q.wq.shape(), (32, 16));
        assert_eq!(q.scales.len(), 2 * 16);
        assert!(q.codes.iter().all(|&c| (-7..=7).contains(&c)));
    }

    #[test]
    fn per_tensor_mode() {
        let mut rng = Pcg32::seeded(5);
        let w = Matrix::randn(24, 8, 0.1, &mut rng);
        let x = calib_activations(64, 24, 6);
        let h = matmul_at_b(&x, &x);
        let q = quantize(&w, 4, &h, 0);
        assert_eq!(q.group_size, 24);
        assert_eq!(q.scales.len(), 8);
    }

    #[test]
    fn identity_hessian_close_to_rtn() {
        // With H = I there is no useful feedback signal; OPTQ should be in
        // the same error ballpark as plain group RTN (it reorders updates
        // but cannot be wildly worse).
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::randn(32, 32, 0.1, &mut rng);
        let h = Matrix::eye(32);
        let q = quantize(&w, 4, &h, 16);
        let rtn = group_absmax::quantize(&w, 4, 16);
        assert!(q.mse(&w) <= rtn.mse(&w) * 3.0);
    }
}
