//! Half-precision (f16 / bf16) storage codecs.
//!
//! Two 16-bit formats, both decoded exactly back to f32 (every half value
//! is representable in f32, so decode is lossless and encode∘decode is
//! idempotent):
//!
//! * **f16** — IEEE 754 binary16 (1 sign, 5 exponent, 10 mantissa bits):
//!   ~3 decimal digits of precision over ±65504, with gradual underflow
//!   through subnormals below 2⁻¹⁴. The near-f32-fidelity choice for KV
//!   cache rows and adapter weights, whose magnitudes are O(1).
//! * **bf16** — bfloat16 (1 sign, 8 exponent, 7 mantissa bits): f32's full
//!   exponent range at ~2 decimal digits. The drop-in-range choice when
//!   values may be large (it never saturates where f32 doesn't).
//!
//! Encoding rounds to nearest-even, like the hardware conversions. Out of
//! deliberate parallel with the FP8 codec ([`crate::quant::fp8`]), non-finite
//! and overflowing inputs **saturate to the largest finite value** instead
//! of producing ±∞/NaN — a cache row must never inject an infinity into an
//! attention score.
//!
//! These bit codecs back the half-width KV cache store
//! (`model::attention::KvDtype::{F16, Bf16}`) and the half-storage dense /
//! adapter kernels (`kernels::dense`, `kernels::lowrank`), whose GEMMs read
//! `u16` operands through [`f16_from_bits`] / [`bf16_from_bits`] and
//! accumulate in f32 (`tensor::ops::{gemm_half, gemm_abt_half}`).

/// Largest finite f16 value ((2 − 2⁻¹⁰) × 2¹⁵ = 65504).
pub const F16_MAX: f32 = 65504.0;
/// Largest finite bf16 value ((2 − 2⁻⁷) × 2¹²⁷ ≈ 3.39 × 10³⁸).
pub const BF16_MAX: f32 = f32::from_bits(0x7F7F_0000);

/// Encode an f32 into its IEEE binary16 bit pattern (round to nearest,
/// ties to even). Values that would round past ±[`F16_MAX`] — including
/// ±∞ and NaN — saturate to the largest finite half of the same sign.
pub fn f16_to_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let max = sign | 0x7BFF; // largest finite magnitude
    if !x.is_finite() {
        return max;
    }
    let exp = ((b >> 23) & 0xFF) as i32 - 127;
    let mant = b & 0x007F_FFFF;
    if exp >= 16 {
        return max; // ≥ 2¹⁶ > F16_MAX even before rounding
    }
    if exp >= -14 {
        // Normal half: keep 10 mantissa bits, round-to-nearest-even on the
        // 13 dropped bits.
        let keep = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = (((exp + 15) as u32) << 10) | keep;
        if rest > 0x1000 || (rest == 0x1000 && h & 1 == 1) {
            h += 1;
        }
        if h >= 0x7C00 {
            return max; // rounded up into the infinity encoding
        }
        return sign | h as u16;
    }
    if exp < -25 {
        return sign; // below half the smallest subnormal → ±0
    }
    // Subnormal half: value = m · 2⁻²⁴ with m in 0..1024. Shift the f32
    // significand (with its implicit bit restored) into place and round
    // ties-to-even on the dropped bits.
    let sig = mant | 0x0080_0000;
    let sh = (13 + (-14 - exp)) as u32; // 14..=24 for exp in -25..=-15
    let keep = sig >> sh;
    let rest = sig & ((1u32 << sh) - 1);
    let half = 1u32 << (sh - 1);
    let mut h = keep;
    if rest > half || (rest == half && h & 1 == 1) {
        h += 1;
    }
    sign | h as u16
}

/// Decode an IEEE binary16 bit pattern to f32 (exact). Exponent 31
/// patterns — never produced by [`f16_to_bits`] — decode to ±[`F16_MAX`]
/// for the same never-inject-∞ policy the encoder follows.
pub fn f16_from_bits(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1F) as i32;
    let m = (h & 0x3FF) as f32;
    match e {
        0 => sign * m * (-24.0f32).exp2(),
        31 => sign * F16_MAX,
        _ => sign * (1.0 + m / 1024.0) * ((e - 15) as f32).exp2(),
    }
}

/// Encode an f32 into its bfloat16 bit pattern (round to nearest, ties to
/// even on the 16 dropped mantissa bits). ±∞ / NaN and values that round
/// into the infinity encoding saturate to ±[`BF16_MAX`].
pub fn bf16_to_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = (b >> 16) & 0x8000;
    if !x.is_finite() {
        return (sign | 0x7F7F) as u16;
    }
    let round = ((b >> 16) & 1) + 0x7FFF;
    let r = (b.wrapping_add(round)) >> 16;
    if (r & 0x7FFF) >= 0x7F80 {
        return (sign | 0x7F7F) as u16; // rounded up into the infinity encoding
    }
    r as u16
}

/// Decode a bfloat16 bit pattern to f32 (exact: bf16 is f32's top half).
/// Non-finite patterns — never produced by [`bf16_to_bits`] — decode to
/// ±[`BF16_MAX`].
pub fn bf16_from_bits(h: u16) -> f32 {
    if (h & 0x7FFF) >= 0x7F80 {
        return if h & 0x8000 != 0 { -BF16_MAX } else { BF16_MAX };
    }
    f32::from_bits((h as u32) << 16)
}

/// Which half format a half-storage kernel or slab uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HalfKind {
    /// IEEE binary16 (1-5-10).
    F16,
    /// bfloat16 (1-8-7).
    Bf16,
}

impl HalfKind {
    /// Display / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            HalfKind::F16 => "f16",
            HalfKind::Bf16 => "bf16",
        }
    }

    /// Scalar encoder for this format.
    #[inline]
    pub fn encode(&self, x: f32) -> u16 {
        match self {
            HalfKind::F16 => f16_to_bits(x),
            HalfKind::Bf16 => bf16_to_bits(x),
        }
    }

    /// Scalar decoder for this format, as a plain `fn` pointer — the shape
    /// the generic half GEMMs (`tensor::ops::gemm_half`) take, so the
    /// format dispatch happens once per call, not once per element.
    #[inline]
    pub fn decoder(&self) -> fn(u16) -> f32 {
        match self {
            HalfKind::F16 => f16_from_bits,
            HalfKind::Bf16 => bf16_from_bits,
        }
    }
}

/// Encode a slice (`dst[i] = kind.encode(src[i])`; lengths must match).
pub fn encode_slice(kind: HalfKind, src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "half encode length mismatch");
    match kind {
        HalfKind::F16 => {
            for (d, &x) in dst.iter_mut().zip(src) {
                *d = f16_to_bits(x);
            }
        }
        HalfKind::Bf16 => {
            for (d, &x) in dst.iter_mut().zip(src) {
                *d = bf16_to_bits(x);
            }
        }
    }
}

/// Decode a slice (`dst[i] = kind.decode(src[i])`; lengths must match).
pub fn decode_slice(kind: HalfKind, src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "half decode length mismatch");
    let dec = kind.decoder();
    for (d, &h) in dst.iter_mut().zip(src) {
        *d = dec(h);
    }
}

/// Encode a whole f32 slice into a fresh bit vector.
pub fn encode_vec(kind: HalfKind, src: &[f32]) -> Vec<u16> {
    let mut out = vec![0u16; src.len()];
    encode_slice(kind, src, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn roundtrip_f16(x: f32) -> f32 {
        f16_from_bits(f16_to_bits(x))
    }

    fn roundtrip_bf16(x: f32) -> f32 {
        bf16_from_bits(bf16_to_bits(x))
    }

    #[test]
    fn f16_exact_values() {
        // Powers of two, small integers and 10-bit dyadics are exact.
        for &v in &[0.0f32, 1.0, -1.0, 2.0, 0.5, 1.5, 1.25, -4.0, 65504.0, 0.099975586] {
            assert_eq!(roundtrip_f16(v), v, "v={v}");
        }
        // Known bit patterns.
        assert_eq!(f16_to_bits(1.0), 0x3C00);
        assert_eq!(f16_to_bits(-2.0), 0xC000);
        assert_eq!(f16_to_bits(65504.0), 0x7BFF);
        assert_eq!(f16_to_bits(0.0), 0x0000);
    }

    #[test]
    fn f16_relative_error_half_ulp() {
        // Round-to-nearest ⇒ rel err ≤ 2⁻¹¹ for normal halfs.
        let mut rng = Pcg32::seeded(1);
        for _ in 0..4000 {
            let v = rng.range_f32(-1000.0, 1000.0);
            let r = roundtrip_f16(v);
            if v.abs() > 1e-3 {
                assert!(((r - v) / v).abs() <= 2.0f32.powi(-11) + 1e-7, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn f16_subnormals_and_underflow() {
        let min_sub = (-24.0f32).exp2(); // 2⁻²⁴, the smallest subnormal
        assert_eq!(roundtrip_f16(min_sub), min_sub);
        assert_eq!(roundtrip_f16(3.0 * min_sub), 3.0 * min_sub);
        let min_norm = (-14.0f32).exp2();
        assert_eq!(roundtrip_f16(min_norm), min_norm);
        // Below half the smallest subnormal → ±0; exactly half → even (0).
        assert_eq!(roundtrip_f16(min_sub / 4.0), 0.0);
        assert_eq!(roundtrip_f16(min_sub / 2.0), 0.0);
        assert_eq!(roundtrip_f16(-min_sub / 4.0), -0.0);
        // Just above half rounds up to the smallest subnormal.
        assert_eq!(roundtrip_f16(min_sub * 0.6), min_sub);
    }

    #[test]
    fn f16_saturates_never_inf() {
        assert_eq!(roundtrip_f16(1e9), F16_MAX);
        assert_eq!(roundtrip_f16(-1e9), -F16_MAX);
        assert_eq!(roundtrip_f16(f32::INFINITY), F16_MAX);
        assert_eq!(roundtrip_f16(f32::NEG_INFINITY), -F16_MAX);
        // 65520 would round to +∞ under IEEE; the codec clamps instead.
        assert_eq!(roundtrip_f16(65520.0), F16_MAX);
        assert!(roundtrip_f16(f32::NAN).is_finite());
    }

    #[test]
    fn bf16_exact_values_and_error() {
        for &v in &[0.0f32, 1.0, -1.0, 2.0, 0.5, 1.5, -4.0, 3.0e38] {
            let r = roundtrip_bf16(v);
            assert!(((r - v) / v.abs().max(1e-30)).abs() <= 2.0f32.powi(-8), "v={v} r={r}");
        }
        assert_eq!(bf16_to_bits(1.0), 0x3F80);
        assert_eq!(roundtrip_bf16(1.0), 1.0);
        // bf16 keeps f32's exponent range: huge values survive.
        assert_eq!(roundtrip_bf16(1e38), bf16_from_bits(bf16_to_bits(1e38)));
        let mut rng = Pcg32::seeded(2);
        for _ in 0..4000 {
            let v = rng.range_f32(-1e6, 1e6);
            let r = roundtrip_bf16(v);
            if v.abs() > 1e-3 {
                assert!(((r - v) / v).abs() <= 2.0f32.powi(-8) + 1e-7, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn bf16_saturates_never_inf() {
        assert_eq!(roundtrip_bf16(f32::INFINITY), BF16_MAX);
        assert_eq!(roundtrip_bf16(f32::NEG_INFINITY), -BF16_MAX);
        assert_eq!(roundtrip_bf16(f32::MAX), BF16_MAX); // rounds up → clamped
        assert!(roundtrip_bf16(f32::NAN).is_finite());
    }

    #[test]
    fn round_trip_is_idempotent_both_formats() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..2000 {
            let v = rng.range_f32(-500.0, 500.0);
            let f = roundtrip_f16(v);
            assert_eq!(roundtrip_f16(f), f, "f16 v={v}");
            let b = roundtrip_bf16(v);
            assert_eq!(roundtrip_bf16(b), b, "bf16 v={v}");
        }
    }

    #[test]
    fn rounding_is_monotone() {
        // x ≤ y ⇒ round(x) ≤ round(y): sort random draws and check the
        // decoded sequence never decreases (the property the KV store
        // relies on — quantization must not reorder score magnitudes).
        let mut rng = Pcg32::seeded(4);
        let mut xs: Vec<f32> = (0..3000).map(|_| rng.range_f32(-2000.0, 2000.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in xs.windows(2) {
            assert!(roundtrip_f16(w[0]) <= roundtrip_f16(w[1]), "f16 {} {}", w[0], w[1]);
            assert!(roundtrip_bf16(w[0]) <= roundtrip_bf16(w[1]), "bf16 {} {}", w[0], w[1]);
        }
    }

    #[test]
    fn slice_codecs_match_scalar() {
        let mut rng = Pcg32::seeded(5);
        let src: Vec<f32> = (0..257).map(|_| rng.gauss()).collect();
        for kind in [HalfKind::F16, HalfKind::Bf16] {
            let bits = encode_vec(kind, &src);
            for (b, &x) in bits.iter().zip(&src) {
                assert_eq!(*b, kind.encode(x));
            }
            let mut back = vec![0.0f32; src.len()];
            decode_slice(kind, &bits, &mut back);
            let dec = kind.decoder();
            for (got, b) in back.iter().zip(&bits) {
                assert_eq!(*got, dec(*b));
            }
        }
    }

    #[test]
    fn kind_names() {
        assert_eq!(HalfKind::F16.name(), "f16");
        assert_eq!(HalfKind::Bf16.name(), "bf16");
    }
}
