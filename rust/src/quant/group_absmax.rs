//! Group AbsMax quantization (paper Apx U; the strong uniform baseline).
//!
//! Each group of `group_size` consecutive input-dim elements within one
//! output column shares an AbsMax scale. Captures local magnitude variation
//! (beats per-tensor AbsMax) at the cost of storing one scale per group and
//! a slower dequant path (Table 23 measures that slow-down on our kernels).

use super::{fake_quant_value, quant_code, Quantized};
use crate::tensor::Matrix;

/// Group-AbsMax quantize `w` (d_in × d_out) with groups running down the
/// input dimension of each output column.
pub fn quantize(w: &Matrix, bits: u8, group_size: usize) -> Quantized {
    assert!(group_size > 0);
    let (d_in, d_out) = w.shape();
    let n_groups_per_col = d_in.div_ceil(group_size);
    let mut scales = vec![0.0f32; n_groups_per_col * d_out];
    // Pass 1: scales = max |w| per (group, col).
    for i in 0..d_in {
        let g = i / group_size;
        let row = w.row(i);
        for (j, &x) in row.iter().enumerate() {
            let s = &mut scales[g * d_out + j];
            *s = s.max(x.abs());
        }
    }
    // Pass 2: fake-quant + codes.
    let mut wq = Matrix::zeros(d_in, d_out);
    let mut codes = vec![0i8; d_in * d_out];
    for i in 0..d_in {
        let g = i / group_size;
        for j in 0..d_out {
            let alpha = scales[g * d_out + j];
            let x = w.get(i, j);
            wq.set(i, j, fake_quant_value(x, alpha, bits));
            codes[i * d_out + j] = quant_code(x, alpha, bits);
        }
    }
    Quantized { wq, codes, scales, group_size, bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::absmax;
    use crate::rng::Pcg32;

    #[test]
    fn shapes_and_scale_count() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(256, 64, 0.1, &mut rng);
        let q = quantize(&w, 4, 128);
        assert_eq!(q.scales.len(), 2 * 64);
        assert_eq!(q.group_size, 128);
    }

    #[test]
    fn ragged_group_handled() {
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::randn(100, 8, 0.1, &mut rng); // 100 = 128-group ragged
        let q = quantize(&w, 4, 128);
        assert_eq!(q.scales.len(), 8);
        assert_eq!(q.wq.shape(), (100, 8));
    }

    #[test]
    fn beats_per_tensor_on_outliers() {
        let mut rng = Pcg32::seeded(3);
        let mut w = Matrix::randn(256, 32, 0.02, &mut rng);
        w.set(0, 0, 4.0); // outlier poisons only its own group here
        let per_tensor = absmax::quantize(&w, 4).mse(&w);
        let grouped = quantize(&w, 4, 128).mse(&w);
        assert!(grouped < per_tensor / 4.0, "group {grouped} vs tensor {per_tensor}");
    }

    #[test]
    fn group_error_bounded_by_group_scale() {
        let mut rng = Pcg32::seeded(4);
        let w = Matrix::randn(64, 16, 1.0, &mut rng);
        let q = quantize(&w, 4, 16);
        let l = crate::quant::levels(4);
        for i in 0..64 {
            let g = i / 16;
            for j in 0..16 {
                let alpha = q.scales[g * 16 + j];
                let err = (w.get(i, j) - q.wq.get(i, j)).abs();
                assert!(err <= alpha / l / 2.0 + 1e-5);
            }
        }
    }

    #[test]
    fn smaller_groups_lower_error() {
        let mut rng = Pcg32::seeded(5);
        let w = Matrix::randn(256, 32, 0.5, &mut rng);
        let e128 = quantize(&w, 4, 128).mse(&w);
        let e32 = quantize(&w, 4, 32).mse(&w);
        assert!(e32 <= e128 + 1e-9);
    }

    #[test]
    fn bits_per_element_accounting() {
        let mut rng = Pcg32::seeded(6);
        let w = Matrix::randn(256, 16, 0.1, &mut rng);
        let q = quantize(&w, 4, 128);
        // 4 bits + 16-bit scale per 128 elements = 4.125
        assert!((q.bits_per_element() - 4.125).abs() < 1e-9);
    }
}
