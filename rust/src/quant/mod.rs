//! Weight and activation quantizers (paper §3.1, Apx B/E/U).
//!
//! All weight quantizers produce *fake-quant* matrices (quantize →
//! dequantize in f32) for the accuracy path — exactly how the paper
//! evaluates accuracy — plus integer codes + scales for the packed
//! inference kernels in [`crate::kernels`].
//!
//! Implemented methods:
//! * [`absmax`] — per-tensor AbsMax symmetric RTN (the weak baseline).
//! * [`group_absmax`] — AbsMax per group of 128 input-dim elements
//!   (the strong uniform baseline, also used for adapter quantization §3.3).
//! * [`slim_quant`] — SLiM-Quant (paper Alg. 1): per-tensor scale α found by
//!   minimizing `E_quant(α)+E_clip(α)` via numerical integration over the
//!   |W| histogram with multigrid refinement; `W` and activation-aware `O`
//!   variants.
//! * [`optq`] — OPTQ/GPTQ-style Hessian-aware quantization with error
//!   feedback (the SparseGPT companion in Table 1).
//! * [`fp8`] — FP8 (E4M3/E5M2) + int8 AbsMax input quantization (Apx B);
//!   the E4M3 byte codec (`e4m3_to_bits`/`e4m3_from_bits`) also backs the
//!   quantized KV cache store (`model::attention::KvDtype::Fp8E4M3`).
//! * [`pack`] — int4/int2 bit-packing for the runtime kernels.
//! * [`half`] — f16/bf16 bit codecs (round-to-nearest-even, saturating)
//!   backing the half-width KV cache store
//!   (`model::attention::KvDtype::{F16, Bf16}`) and the half-storage
//!   dense/adapter kernels.

pub mod absmax;
pub mod fp8;
pub mod group_absmax;
pub mod half;
pub mod optq;
pub mod pack;
pub mod slim_quant;

use crate::tensor::Matrix;

/// Number of symmetric levels on each side for q-bit quantization
/// (4-bit → 7, i.e. codes in [-7, 7]).
#[inline]
pub fn levels(bits: u8) -> f32 {
    ((1i32 << (bits - 1)) - 1) as f32
}

/// Fake-quantize a single value with scale `alpha` and `bits` (Eq. 2 of the
/// paper, with the conventional symmetric-level parameterization: codes in
/// `[-L, L]`, `L = 2^{q-1}-1`, dequant `= code·α/L`).
#[inline]
pub fn fake_quant_value(x: f32, alpha: f32, bits: u8) -> f32 {
    if alpha <= 0.0 {
        return 0.0;
    }
    let l = levels(bits);
    let t = (x / alpha).clamp(-1.0, 1.0);
    (t * l).round() * alpha / l
}

/// Integer code for a value (for packing).
#[inline]
pub fn quant_code(x: f32, alpha: f32, bits: u8) -> i8 {
    if alpha <= 0.0 {
        return 0;
    }
    let l = levels(bits);
    ((x / alpha).clamp(-1.0, 1.0) * l).round() as i8
}

/// Which weight quantizer to run — the pipeline and experiment drivers
/// select by this enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    /// No quantization (sparse-only experiments).
    None,
    /// Per-tensor AbsMax RTN.
    AbsMax,
    /// Group AbsMax, group size 128 over the input dimension.
    GroupAbsMax,
    /// SLiM-Quant weight-error minimization (paper's `SLiM-Quant^W`).
    SlimQuantW,
    /// SLiM-Quant with AWQ-style activation-aware channel scaling
    /// (paper's `SLiM-Quant^O`).
    SlimQuantO,
    /// OPTQ with per-group scales (the SparseGPT companion).
    GroupOptq,
}

impl QuantMethod {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<QuantMethod> {
        Some(match s {
            "none" => QuantMethod::None,
            "absmax" => QuantMethod::AbsMax,
            "group-absmax" => QuantMethod::GroupAbsMax,
            "slim-quant" | "slim-quant-w" => QuantMethod::SlimQuantW,
            "slim-quant-o" => QuantMethod::SlimQuantO,
            "group-optq" | "optq" => QuantMethod::GroupOptq,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantMethod::None => "none",
            QuantMethod::AbsMax => "AbsMax",
            QuantMethod::GroupAbsMax => "Group AbsMax",
            QuantMethod::SlimQuantW => "SLiM-Quant^W",
            QuantMethod::SlimQuantO => "SLiM-Quant^O",
            QuantMethod::GroupOptq => "Group OPTQ",
        }
    }
}

/// A quantized weight matrix: fake-quant values for the accuracy path and
/// codes/scales for the packed kernels.
#[derive(Clone, Debug)]
pub struct Quantized {
    /// Dequantized (fake-quant) weights, same shape as the input.
    pub wq: Matrix,
    /// Integer codes, row-major, same shape.
    pub codes: Vec<i8>,
    /// Scales: one per tensor (`group_size == 0`) or one per group.
    pub scales: Vec<f32>,
    /// 0 for per-tensor, otherwise the group length over the input dim.
    pub group_size: usize,
    /// Bit width.
    pub bits: u8,
}

impl Quantized {
    /// Reconstruct a `Quantized` from a fake-quant matrix plus its scales —
    /// the bridge from the compression pipeline's dense output
    /// ([`crate::compress::CompressedLayer`] keeps `wc` + `scales`, not the
    /// codes) back to the packed serving kernels. Exact when fake-quant
    /// values are `code·α/L` grid points (all plain quantizers; pruned
    /// entries are 0.0 → code 0): `round(x/α·L)` recovers the original
    /// code. Returns `None` at the first off-grid value (beyond a
    /// thousandth of a quantization step) — the case for activation-aware
    /// variants (SLiM-Quant^O), which fold per-channel scaling into the
    /// fake-quant values; packing those would corrupt salient channels.
    pub fn try_from_fake_quant(
        wq: &Matrix,
        scales: Vec<f32>,
        group_size: usize,
        bits: u8,
    ) -> Option<Quantized> {
        let (d_in, d_out) = wq.shape();
        let lv = levels(bits);
        let mut codes = vec![0i8; d_in * d_out];
        for i in 0..d_in {
            let row = wq.row(i);
            let g = if group_size == 0 { 0 } else { i / group_size };
            for (j, &x) in row.iter().enumerate() {
                let alpha = if group_size == 0 { scales[0] } else { scales[g * d_out + j] };
                let c = quant_code(x, alpha, bits);
                if (c as f32 * alpha / lv - x).abs() > alpha / lv * 1e-3 + 1e-12 {
                    return None;
                }
                codes[i * d_out + j] = c;
            }
        }
        Some(Quantized { wq: wq.clone(), codes, scales, group_size, bits })
    }

    /// Mean squared reconstruction error vs the original weights.
    pub fn mse(&self, w: &Matrix) -> f64 {
        self.wq.sub(w).fro_norm_sq() / w.len() as f64
    }

    /// Bits per stored element including scale overhead (f16 scales assumed,
    /// matching the paper's memory accounting).
    pub fn bits_per_element(&self) -> f64 {
        let scale_bits = self.scales.len() as f64 * 16.0;
        (self.codes.len() as f64 * self.bits as f64 + scale_bits) / self.codes.len() as f64
    }
}

/// Quantize with the given method. `x_abs_mean` (per input-channel mean |x|
/// from calibration) is required by `SlimQuantO`; `hessian` (XᵀX) by
/// `GroupOptq`.
pub fn quantize(
    w: &Matrix,
    method: QuantMethod,
    bits: u8,
    x_abs_mean: Option<&[f32]>,
    hessian: Option<&Matrix>,
) -> Quantized {
    match method {
        QuantMethod::None => Quantized {
            wq: w.clone(),
            codes: vec![0; w.len()],
            scales: vec![0.0],
            group_size: 0,
            bits: 32,
        },
        QuantMethod::AbsMax => absmax::quantize(w, bits),
        QuantMethod::GroupAbsMax => group_absmax::quantize(w, bits, 128),
        QuantMethod::SlimQuantW => slim_quant::quantize(w, bits),
        QuantMethod::SlimQuantO => {
            let x = x_abs_mean.expect("SlimQuantO requires calibration activation stats");
            slim_quant::quantize_activation_aware(w, bits, x)
        }
        QuantMethod::GroupOptq => {
            let h = hessian.expect("GroupOptq requires the layer Hessian XᵀX");
            optq::quantize(w, bits, h, 128)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_match_bitwidth() {
        assert_eq!(levels(4), 7.0);
        assert_eq!(levels(8), 127.0);
        assert_eq!(levels(2), 1.0);
    }

    #[test]
    fn fake_quant_is_idempotent() {
        let alpha = 2.0;
        for &x in &[-3.0f32, -1.9, -0.3, 0.0, 0.7, 1.4, 2.5] {
            let q1 = fake_quant_value(x, alpha, 4);
            let q2 = fake_quant_value(q1, alpha, 4);
            assert!((q1 - q2).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn fake_quant_clips() {
        assert_eq!(fake_quant_value(100.0, 1.0, 4), 1.0);
        assert_eq!(fake_quant_value(-100.0, 1.0, 4), -1.0);
    }

    #[test]
    fn codes_round_trip_dequant() {
        let alpha = 1.5;
        for &x in &[-1.2f32, 0.0, 0.4, 1.49] {
            let c = quant_code(x, alpha, 4);
            let deq = c as f32 * alpha / levels(4);
            assert!((deq - fake_quant_value(x, alpha, 4)).abs() < 1e-6);
        }
    }

    #[test]
    fn try_from_fake_quant_recovers_codes() {
        use crate::rng::Pcg32;
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::from_fn(64, 48, |_, _| rng.laplace(0.05));
        // Per-tensor (SLiM-Quant) round trip.
        let q = slim_quant::quantize(&w, 4);
        let r = Quantized::try_from_fake_quant(&q.wq, q.scales.clone(), 0, 4).unwrap();
        assert_eq!(r.codes, q.codes);
        // Group round trip.
        let qg = group_absmax::quantize(&w, 4, 16);
        let rg = Quantized::try_from_fake_quant(&qg.wq, qg.scales.clone(), 16, 4).unwrap();
        assert_eq!(rg.codes, qg.codes);
        // Off-grid values (folded channel scaling) are rejected.
        let mut off = q.wq.clone();
        for v in off.row_mut(0) {
            *v *= 0.5;
        }
        assert!(Quantized::try_from_fake_quant(&off, q.scales.clone(), 0, 4).is_none());
    }

    #[test]
    fn method_parsing() {
        assert_eq!(QuantMethod::parse("slim-quant"), Some(QuantMethod::SlimQuantW));
        assert_eq!(QuantMethod::parse("group-absmax"), Some(QuantMethod::GroupAbsMax));
        assert_eq!(QuantMethod::parse("bogus"), None);
    }
}
