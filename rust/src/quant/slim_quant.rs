//! SLiM-Quant (paper §3.1, Algorithm 1).
//!
//! Symmetric per-tensor quantization whose scale α minimizes the expected
//! reconstruction error, written probabilistically (paper Eq. 4–7) as
//!
//! ```text
//!   E_Q(α) = E_quant(α) + E_clip(α)
//!   E_quant(α) = ∫₀^α f_abs(x) · (fq(x; α) − x)² dx     (in-range error)
//!   E_clip(α)  = ∫_α^∞ f_abs(x) · (α − x)² dx            (clipping error)
//! ```
//!
//! The weight PDF `f_abs` has no closed form, so the integrals are evaluated
//! numerically over the |W| histogram (bin count per Apx T) and the argmin
//! is found by the multigrid search of Algorithm 1: a coarse scan over
//! `(0, max|W|]` followed by iterative refinement around the incumbent.
//!
//! The activation-aware variant (`SLiM-Quant^O`) additionally protects the
//! most salient input channels — saliency `|x̄_i|·mean_j|W_ij|` as in §3.1 —
//! by scaling them up in the weights (and down in the activations) before
//! quantizing, AWQ-style.

use super::absmax::quantize_with_alpha;
use super::{levels, Quantized};
use crate::tensor::{histogram, Histogram, Matrix};

/// Expected error `E_quant(α) + E_clip(α)` for one candidate α, integrated
/// over the histogram (this is `EstimateError` in Algorithm 1).
pub fn estimate_error(hist: &Histogram, alpha: f32, bits: u8) -> f64 {
    if alpha <= 0.0 {
        // Everything clips to 0 → error = E[x²].
        return hist
            .centers
            .iter()
            .zip(hist.pdf.iter())
            .map(|(&c, &p)| (c as f64) * (c as f64) * p as f64)
            .sum();
    }
    let l = levels(bits) as f64;
    let step = alpha as f64 / l;
    let mut err = 0.0f64;
    for (&c, &p) in hist.centers.iter().zip(hist.pdf.iter()) {
        if p == 0.0 {
            continue;
        }
        let x = c as f64;
        let e = if x <= alpha as f64 {
            // In-range: distance to the nearest level (E_quant term).
            let q = (x / step).round() * step;
            x - q
        } else {
            // Clipped to ±α (E_clip term).
            x - alpha as f64
        };
        err += p as f64 * e * e;
    }
    err
}

/// Multigrid α search of Algorithm 1: `coarse` samples over `(0, max]`,
/// then `refine_iters` rounds of 10-point refinement around the incumbent.
pub fn find_alpha(hist: &Histogram, bits: u8) -> f32 {
    if hist.max <= 0.0 {
        return 0.0;
    }
    let coarse = 10usize;
    let mut lo = 0.0f32;
    let mut hi = hist.max;
    let mut best_alpha = hist.max;
    let mut best_err = f64::INFINITY;
    for _level in 0..6 {
        let step = (hi - lo) / coarse as f32;
        if step <= f32::EPSILON * hist.max {
            break;
        }
        let mut level_best = best_alpha;
        for k in 1..=coarse {
            let alpha = lo + step * k as f32;
            let e = estimate_error(hist, alpha, bits);
            if e < best_err {
                best_err = e;
                level_best = alpha;
            }
        }
        best_alpha = level_best;
        // Refine around the incumbent (Algorithm 1 lines 13–15).
        lo = (best_alpha - step).max(0.0);
        hi = (best_alpha + step).min(hist.max);
    }
    best_alpha
}

/// SLiM-Quant^W: weight-error-minimizing per-tensor quantization.
pub fn quantize(w: &Matrix, bits: u8) -> Quantized {
    let hist = histogram(w);
    let alpha = find_alpha(&hist, bits);
    quantize_with_alpha(w, bits, alpha)
}

/// Fraction of channels protected by the activation-aware variant (the
/// paper scales "approximately 1% of the channels").
pub const SALIENT_FRACTION: f64 = 0.01;
/// Up-scaling factor for salient channels (weights ×s, activations ×1/s).
pub const SALIENT_SCALE: f32 = 2.0;

/// SLiM-Quant^O: activation-aware output-error minimization.
///
/// Channels with the top `SALIENT_FRACTION` saliency `|x̄_i|·mean_j|W_ij|`
/// are scaled by `s` in the weights before quantization; the returned
/// `channel_scale` must be applied as `x_i / s_i` to activations at
/// inference. For the fake-quant accuracy path we fold the inverse back into
/// `wq`, which is numerically identical to scaling the activations.
pub fn quantize_activation_aware(w: &Matrix, bits: u8, x_abs_mean: &[f32]) -> Quantized {
    let (d_in, _d_out) = w.shape();
    assert_eq!(x_abs_mean.len(), d_in, "activation stats must match d_in");
    // Per-input-channel saliency = |x̄_i| · mean_j |W_ij|.
    let mut saliency: Vec<(f32, usize)> = (0..d_in)
        .map(|i| {
            let wmean = w.row(i).iter().map(|x| x.abs()).sum::<f32>() / w.cols() as f32;
            (x_abs_mean[i].abs() * wmean, i)
        })
        .collect();
    saliency.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let n_salient = ((d_in as f64 * SALIENT_FRACTION).ceil() as usize).clamp(1, d_in);
    // Clip-aware scaling: pick s ≤ SALIENT_SCALE such that the scaled row
    // stays inside the (unscaled) optimal α — otherwise the salient
    // channel's weights clip and the protection backfires. (With per-tensor
    // scales this is the analogue of AWQ's grid-searched s.)
    let alpha0 = find_alpha(&histogram(w), bits).max(1e-12);
    let mut channel_scale = vec![1.0f32; d_in];
    for &(_, i) in saliency.iter().take(n_salient) {
        let row_max = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s_max = if row_max > 0.0 { alpha0 / row_max } else { SALIENT_SCALE };
        channel_scale[i] = SALIENT_SCALE.min(s_max).max(1.0);
    }
    // Quantize the scaled weights, then fold the activation-side 1/s into wq
    // so downstream consumers see an ordinary weight matrix.
    let w_scaled = w.scale_rows(&channel_scale);
    let mut q = quantize(&w_scaled, bits);
    let inv: Vec<f32> = channel_scale.iter().map(|&s| 1.0 / s).collect();
    q.wq = q.wq.scale_rows(&inv);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::absmax;
    use crate::rng::Pcg32;
    use crate::tensor::histogram_with_bins;

    #[test]
    fn estimate_error_zero_alpha_is_energy() {
        let data = vec![1.0f32; 100];
        let h = histogram_with_bins(&data, 64);
        let e = estimate_error(&h, 0.0, 4);
        // E[x²] with all mass at the top bin center (≈ 0.9921875²).
        assert!((e - (h.centers[63] as f64).powi(2)).abs() < 1e-4);
    }

    #[test]
    fn estimate_error_decreases_then_increases() {
        // For a bell-shaped distribution the error has an interior optimum:
        // α too small → clipping dominates; α too large → step dominates.
        let mut rng = Pcg32::seeded(1);
        let data: Vec<f32> = (0..100_000).map(|_| rng.gauss()).collect();
        let h = histogram_with_bins(&data, 2000);
        let e_tiny = estimate_error(&h, 0.1, 4);
        let e_best = estimate_error(&h, find_alpha(&h, 4), 4);
        let e_max = estimate_error(&h, h.max, 4);
        assert!(e_best < e_tiny);
        assert!(e_best < e_max);
    }

    #[test]
    fn find_alpha_beats_absmax_scale() {
        let mut rng = Pcg32::seeded(2);
        // Heavy-tailed weights: Laplace — α* should clip the tail.
        let data: Vec<f32> = (0..50_000).map(|_| rng.laplace(0.05)).collect();
        let h = histogram_with_bins(&data, 1000);
        let alpha = find_alpha(&h, 4);
        assert!(alpha < h.max, "optimal alpha should clip the tail");
        assert!(estimate_error(&h, alpha, 4) <= estimate_error(&h, h.max, 4));
    }

    #[test]
    fn slim_quant_mse_not_worse_than_absmax() {
        // The whole point of SLiM-Quant (paper Table 8's premise): for
        // realistic bell-curved weights its per-tensor MSE ≤ AbsMax's.
        let mut rng = Pcg32::seeded(3);
        for trial in 0..5 {
            let w = Matrix::from_fn(128, 128, |_, _| rng.laplace(0.03));
            let slim = quantize(&w, 4).mse(&w);
            let amax = absmax::quantize(&w, 4).mse(&w);
            assert!(slim <= amax * 1.01, "trial {trial}: slim {slim} vs absmax {amax}");
        }
    }

    #[test]
    fn slim_quant_on_gaussian_big_gain() {
        let mut rng = Pcg32::seeded(4);
        let mut w = Matrix::randn(256, 256, 0.02, &mut rng);
        w.set(0, 0, 2.0); // single outlier
        let slim = quantize(&w, 4).mse(&w);
        let amax = absmax::quantize(&w, 4).mse(&w);
        assert!(slim < amax / 4.0, "slim {slim} absmax {amax}");
    }

    #[test]
    fn multigrid_close_to_dense_grid() {
        let mut rng = Pcg32::seeded(5);
        let data: Vec<f32> = (0..40_000).map(|_| rng.gauss() * 0.1).collect();
        let h = histogram_with_bins(&data, 1000);
        let fast = find_alpha(&h, 4);
        // Dense reference scan.
        let mut best = (f64::INFINITY, 0.0f32);
        for k in 1..=4000 {
            let a = h.max * k as f32 / 4000.0;
            let e = estimate_error(&h, a, 4);
            if e < best.0 {
                best = (e, a);
            }
        }
        let e_fast = estimate_error(&h, fast, 4);
        assert!(
            e_fast <= best.0 * 1.05,
            "multigrid {e_fast} vs dense {} (alpha {} vs {})",
            best.0,
            fast,
            best.1
        );
    }

    #[test]
    fn activation_aware_protects_salient_channels() {
        let mut rng = Pcg32::seeded(6);
        let mut w = Matrix::from_fn(200, 64, |_, _| rng.laplace(0.03));
        // Channel 7 has huge activations → its weights are salient. Its
        // weights are small (headroom below α), the regime where AWQ-style
        // up-scaling pays off.
        for j in 0..64 {
            w.set(7, j, w.get(7, j) * 0.3);
        }
        let mut x_mean = vec![0.1f32; 200];
        x_mean[7] = 50.0;
        let qo = quantize_activation_aware(&w, 4, &x_mean);
        let qw = quantize(&w, 4);
        // Output-error proxy: saliency-weighted reconstruction error.
        let werr = |q: &Quantized| -> f64 {
            let diff = q.wq.sub(&w);
            (0..200)
                .map(|i| {
                    let rowerr: f64 =
                        diff.row(i).iter().map(|&e| (e as f64) * (e as f64)).sum();
                    rowerr * (x_mean[i] as f64) * (x_mean[i] as f64)
                })
                .sum()
        };
        assert!(werr(&qo) < werr(&qw), "O-variant should cut salient-channel error");
    }

    #[test]
    fn zero_weights() {
        let w = Matrix::zeros(8, 8);
        let q = quantize(&w, 4);
        assert_eq!(q.wq.fro_norm(), 0.0);
    }

    #[test]
    fn two_bit_mode_works() {
        // Table 16/17 need 2-bit quantization.
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::from_fn(128, 128, |_, _| rng.laplace(0.05));
        let q2 = quantize(&w, 2);
        let q4 = quantize(&w, 4);
        assert!(q2.mse(&w) > q4.mse(&w));
        assert!(q2.codes.iter().all(|&c| (-1..=1).contains(&c)));
    }
}
