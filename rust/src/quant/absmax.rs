//! Per-tensor AbsMax symmetric quantization (round-to-nearest).
//!
//! The simplest baseline: α = max|W|. Highly outlier-sensitive — a single
//! large weight inflates α and collapses the bulk of the distribution onto
//! few levels, which is exactly the failure mode SLiM-Quant fixes.

use super::{fake_quant_value, quant_code, Quantized};
use crate::tensor::Matrix;

/// AbsMax-quantize the whole tensor with one scale.
pub fn quantize(w: &Matrix, bits: u8) -> Quantized {
    let alpha = w.max_abs();
    quantize_with_alpha(w, bits, alpha)
}

/// Symmetric per-tensor quantization at a caller-chosen scale (shared by
/// SLiM-Quant, which only differs in how α is picked).
pub fn quantize_with_alpha(w: &Matrix, bits: u8, alpha: f32) -> Quantized {
    let wq = w.map(|x| fake_quant_value(x, alpha, bits));
    let codes = w.data().iter().map(|&x| quant_code(x, alpha, bits)).collect();
    Quantized { wq, codes, scales: vec![alpha], group_size: 0, bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn preserves_shape_and_range() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(32, 48, 0.1, &mut rng);
        let q = quantize(&w, 4);
        assert_eq!(q.wq.shape(), w.shape());
        let alpha = q.scales[0];
        assert!(q.wq.max_abs() <= alpha + 1e-6);
        assert!(q.codes.iter().all(|&c| (-7..=7).contains(&c)));
    }

    #[test]
    fn error_is_bounded_by_half_step() {
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::randn(16, 16, 1.0, &mut rng);
        let q = quantize(&w, 8);
        let step = q.scales[0] / super::super::levels(8);
        for (orig, deq) in w.data().iter().zip(q.wq.data().iter()) {
            assert!((orig - deq).abs() <= step / 2.0 + 1e-6);
        }
    }

    #[test]
    fn outlier_sensitivity() {
        // The documented failure mode: one huge outlier destroys precision
        // for the bulk at 4 bits.
        let mut rng = Pcg32::seeded(3);
        let mut w = Matrix::randn(64, 64, 0.02, &mut rng);
        let clean_mse = quantize(&w, 4).mse(&w);
        w.set(0, 0, 5.0); // inject outlier
        let dirty = quantize(&w, 4);
        // Most small weights now quantize to zero.
        let zeros = dirty.wq.data().iter().filter(|&&x| x == 0.0).count();
        assert!(zeros as f32 / w.len() as f32 > 0.5, "zeros {zeros}");
        assert!(dirty.mse(&w) > clean_mse * 5.0);
    }

    #[test]
    fn zero_matrix() {
        let w = Matrix::zeros(4, 4);
        let q = quantize(&w, 4);
        assert_eq!(q.wq.fro_norm(), 0.0);
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Pcg32::seeded(4);
        let w = Matrix::randn(64, 64, 0.5, &mut rng);
        let e4 = quantize(&w, 4).mse(&w);
        let e8 = quantize(&w, 8).mse(&w);
        assert!(e8 < e4 / 10.0);
    }
}
