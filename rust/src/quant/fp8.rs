//! Input (activation) quantization — paper Apx B.
//!
//! The paper evaluates SLiM with 8-bit inputs: AbsMax uniform int8 with one
//! scale per tensor, and FP8 (E4M3 / E5M2 per Micikevicius et al. 2022),
//! choosing E5M2 when the tensor's max exceeds E4M3's range. Both are
//! implemented as fake-quant transforms applied to activations on the eval
//! path.

use crate::tensor::Matrix;

/// E4M3 max finite value (per the FP8 spec: 1.75 × 2^8 = 448).
pub const E4M3_MAX: f32 = 448.0;
/// E5M2 max finite value (1.75 × 2^15 = 57344).
pub const E5M2_MAX: f32 = 57344.0;

/// Round a value to the nearest representable FP8 number with `mant_bits`
/// mantissa bits and exponent bias chosen per format.
fn fp8_round(x: f32, mant_bits: u32, min_exp: i32, max_val: f32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return if x.is_finite() { 0.0 } else { max_val.copysign(x) };
    }
    let sign = x.signum();
    let a = x.abs().min(max_val);
    // Decompose into mantissa × 2^exp with mantissa in [1, 2).
    let exp = a.log2().floor() as i32;
    let exp = exp.max(min_exp);
    let scale = (exp as f32).exp2();
    let mant = a / scale; // in [1,2) for normals, [0,1) for subnormals
    let steps = (1u32 << mant_bits) as f32;
    let q_mant = (mant * steps).round() / steps;
    sign * (q_mant * scale).min(max_val)
}

/// Fake-quantize to FP8 E4M3 (4 exponent bits, 3 mantissa bits).
pub fn e4m3(x: f32) -> f32 {
    fp8_round(x, 3, -6, E4M3_MAX)
}

/// Encode an f32 into its 8-bit E4M3 pattern (1 sign, 4 exponent bits with
/// bias 7, 3 mantissa bits; the OCP "FN" variant, where exponent field 15
/// still carries normal values up to ±448 and only mantissa 111 there is
/// reserved for NaN — never produced here). The value is rounded with
/// [`e4m3`] first, so `e4m3_from_bits(e4m3_to_bits(x)) == e4m3(x)`.
/// This is the byte layout the quantized KV cache stores
/// (`model::attention::KvDtype::Fp8E4M3`).
pub fn e4m3_to_bits(x: f32) -> u8 {
    let r = e4m3(x);
    if r == 0.0 {
        return 0; // canonical +0 (−0.0 folds in too)
    }
    let sign = if r < 0.0 { 0x80u8 } else { 0 };
    let a = r.abs();
    let exp = a.log2().floor() as i32;
    if exp < -6 {
        // Subnormal: value = mant/8 · 2^-6 with mant in 1..=7.
        return sign | (a * 512.0).round() as u8;
    }
    let mant = ((a / (exp as f32).exp2() - 1.0) * 8.0).round() as u8;
    sign | (((exp + 7) as u8) << 3) | mant
}

/// Decode an E4M3 bit pattern produced by [`e4m3_to_bits`].
pub fn e4m3_from_bits(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let ef = (b >> 3) & 0x0F;
    let mant = (b & 0x07) as f32;
    if ef == 0 {
        return sign * mant / 8.0 * (-6.0f32).exp2();
    }
    sign * (1.0 + mant / 8.0) * ((ef as i32 - 7) as f32).exp2()
}

/// Fake-quantize to FP8 E5M2 (5 exponent bits, 2 mantissa bits).
pub fn e5m2(x: f32) -> f32 {
    fp8_round(x, 2, -14, E5M2_MAX)
}

/// Activation-quantization mode for the eval path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputQuant {
    /// Full precision (default).
    None,
    /// int8 AbsMax, one scale per tensor (paper Apx B main setting).
    Int8AbsMax,
    /// FP8 with automatic E4M3→E5M2 fallback on range (paper Apx B).
    Fp8Auto,
}

impl InputQuant {
    pub fn parse(s: &str) -> Option<InputQuant> {
        Some(match s {
            "none" => InputQuant::None,
            "int8" => InputQuant::Int8AbsMax,
            "fp8" => InputQuant::Fp8Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            InputQuant::None => "fp32",
            InputQuant::Int8AbsMax => "int8-absmax",
            InputQuant::Fp8Auto => "fp8",
        }
    }
}

/// Apply input quantization to an activation tensor.
pub fn quantize_input(x: &Matrix, mode: InputQuant) -> Matrix {
    match mode {
        InputQuant::None => x.clone(),
        InputQuant::Int8AbsMax => {
            let alpha = x.max_abs();
            if alpha == 0.0 {
                return x.clone();
            }
            x.map(|v| {
                let c = ((v / alpha) * 127.0).round().clamp(-127.0, 127.0);
                c * alpha / 127.0
            })
        }
        InputQuant::Fp8Auto => {
            let max = x.max_abs();
            if max > E4M3_MAX {
                x.map(e5m2)
            } else {
                x.map(e4m3)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn e4m3_exact_values() {
        // Powers of two and small integers are exactly representable.
        for &v in &[0.0f32, 1.0, 2.0, 0.5, -4.0, 448.0, 1.5, 1.25] {
            assert_eq!(e4m3(v), v, "v={v}");
        }
    }

    #[test]
    fn e4m3_rounds_to_3_mantissa_bits() {
        // 1.0625 = 1 + 1/16 needs 4 mantissa bits → rounds to 1.0 or 1.125.
        let r = e4m3(1.0625);
        assert!(r == 1.0 || r == 1.125);
        // relative error bounded by half ULP = 2^-4.
        let mut rng = Pcg32::seeded(1);
        for _ in 0..1000 {
            let v = rng.range_f32(-400.0, 400.0);
            let r = e4m3(v);
            if v.abs() > 0.02 {
                assert!(((r - v) / v).abs() <= 0.0625 + 1e-6, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn e4m3_bits_round_trip() {
        // Exhaustive over interesting values: decode(encode(x)) must equal
        // the e4m3 rounding of x, including subnormals and saturation.
        let mut rng = Pcg32::seeded(9);
        for _ in 0..4000 {
            let v = rng.range_f32(-500.0, 500.0);
            let want = e4m3(v);
            let got = e4m3_from_bits(e4m3_to_bits(v));
            assert_eq!(got, want, "v={v}");
        }
        let specials = [
            0.0f32, -0.0, 1.0, -1.0, 448.0, -448.0, 0.015625, 0.001953125, 1e-4, -1e-4, 1e6,
        ];
        for &v in &specials {
            assert_eq!(e4m3_from_bits(e4m3_to_bits(v)), e4m3(v), "v={v}");
        }
        // Subnormal grid point: 3/8 · 2^-6.
        let sub = 3.0 / 8.0 * (-6.0f32).exp2();
        assert_eq!(e4m3_from_bits(e4m3_to_bits(sub)), sub);
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3(1e6), E4M3_MAX);
        assert_eq!(e4m3(-1e6), -E4M3_MAX);
    }

    #[test]
    fn e5m2_wider_range_coarser_precision() {
        assert_eq!(e5m2(1024.0), 1024.0);
        assert_eq!(e5m2(57344.0), E5M2_MAX);
        let mut rng = Pcg32::seeded(2);
        for _ in 0..1000 {
            let v = rng.range_f32(-5e4, 5e4);
            let r = e5m2(v);
            if v.abs() > 1.0 {
                assert!(((r - v) / v).abs() <= 0.125 + 1e-6, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn auto_fallback_selects_format() {
        let small = Matrix::from_vec(1, 2, vec![1.3, -2.7]);
        let q = quantize_input(&small, InputQuant::Fp8Auto);
        // In e4m3 range → e4m3 rounding (1/16 rel err max)
        assert!((q.get(0, 0) - 1.3).abs() < 1.3 * 0.07);
        let big = Matrix::from_vec(1, 2, vec![1000.0, -2.7]);
        let qb = quantize_input(&big, InputQuant::Fp8Auto);
        assert_eq!(qb.get(0, 0), 1024.0); // e5m2 rounding of 1000
    }

    #[test]
    fn int8_absmax_small_relative_error() {
        let mut rng = Pcg32::seeded(3);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let q = quantize_input(&x, InputQuant::Int8AbsMax);
        assert!(q.rel_err(&x) < 0.02, "err {}", q.rel_err(&x));
    }

    #[test]
    fn none_is_identity() {
        let mut rng = Pcg32::seeded(4);
        let x = Matrix::randn(8, 8, 1.0, &mut rng);
        assert_eq!(quantize_input(&x, InputQuant::None), x);
    }
}
