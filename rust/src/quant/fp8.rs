//! Input (activation) quantization — paper Apx B.
//!
//! The paper evaluates SLiM with 8-bit inputs: AbsMax uniform int8 with one
//! scale per tensor, and FP8 (E4M3 / E5M2 per Micikevicius et al. 2022),
//! choosing E5M2 when the tensor's max exceeds E4M3's range. Both are
//! implemented as fake-quant transforms applied to activations on the eval
//! path.

use crate::tensor::Matrix;

/// E4M3 max finite value (per the FP8 spec: 1.75 × 2^8 = 448).
pub const E4M3_MAX: f32 = 448.0;
/// E5M2 max finite value (1.75 × 2^15 = 57344).
pub const E5M2_MAX: f32 = 57344.0;

/// Round a value to the nearest representable FP8 number with `mant_bits`
/// mantissa bits and exponent bias chosen per format.
fn fp8_round(x: f32, mant_bits: u32, min_exp: i32, max_val: f32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return if x.is_finite() { 0.0 } else { max_val.copysign(x) };
    }
    let sign = x.signum();
    let a = x.abs().min(max_val);
    // Decompose into mantissa × 2^exp with mantissa in [1, 2).
    let exp = a.log2().floor() as i32;
    let exp = exp.max(min_exp);
    let scale = (exp as f32).exp2();
    let mant = a / scale; // in [1,2) for normals, [0,1) for subnormals
    let steps = (1u32 << mant_bits) as f32;
    let q_mant = (mant * steps).round() / steps;
    sign * (q_mant * scale).min(max_val)
}

/// Fake-quantize to FP8 E4M3 (4 exponent bits, 3 mantissa bits).
pub fn e4m3(x: f32) -> f32 {
    fp8_round(x, 3, -6, E4M3_MAX)
}

/// Fake-quantize to FP8 E5M2 (5 exponent bits, 2 mantissa bits).
pub fn e5m2(x: f32) -> f32 {
    fp8_round(x, 2, -14, E5M2_MAX)
}

/// Activation-quantization mode for the eval path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputQuant {
    /// Full precision (default).
    None,
    /// int8 AbsMax, one scale per tensor (paper Apx B main setting).
    Int8AbsMax,
    /// FP8 with automatic E4M3→E5M2 fallback on range (paper Apx B).
    Fp8Auto,
}

impl InputQuant {
    pub fn parse(s: &str) -> Option<InputQuant> {
        Some(match s {
            "none" => InputQuant::None,
            "int8" => InputQuant::Int8AbsMax,
            "fp8" => InputQuant::Fp8Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            InputQuant::None => "fp32",
            InputQuant::Int8AbsMax => "int8-absmax",
            InputQuant::Fp8Auto => "fp8",
        }
    }
}

/// Apply input quantization to an activation tensor.
pub fn quantize_input(x: &Matrix, mode: InputQuant) -> Matrix {
    match mode {
        InputQuant::None => x.clone(),
        InputQuant::Int8AbsMax => {
            let alpha = x.max_abs();
            if alpha == 0.0 {
                return x.clone();
            }
            x.map(|v| {
                let c = ((v / alpha) * 127.0).round().clamp(-127.0, 127.0);
                c * alpha / 127.0
            })
        }
        InputQuant::Fp8Auto => {
            let max = x.max_abs();
            if max > E4M3_MAX {
                x.map(e5m2)
            } else {
                x.map(e4m3)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn e4m3_exact_values() {
        // Powers of two and small integers are exactly representable.
        for &v in &[0.0f32, 1.0, 2.0, 0.5, -4.0, 448.0, 1.5, 1.25] {
            assert_eq!(e4m3(v), v, "v={v}");
        }
    }

    #[test]
    fn e4m3_rounds_to_3_mantissa_bits() {
        // 1.0625 = 1 + 1/16 needs 4 mantissa bits → rounds to 1.0 or 1.125.
        let r = e4m3(1.0625);
        assert!(r == 1.0 || r == 1.125);
        // relative error bounded by half ULP = 2^-4.
        let mut rng = Pcg32::seeded(1);
        for _ in 0..1000 {
            let v = rng.range_f32(-400.0, 400.0);
            let r = e4m3(v);
            if v.abs() > 0.02 {
                assert!(((r - v) / v).abs() <= 0.0625 + 1e-6, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3(1e6), E4M3_MAX);
        assert_eq!(e4m3(-1e6), -E4M3_MAX);
    }

    #[test]
    fn e5m2_wider_range_coarser_precision() {
        assert_eq!(e5m2(1024.0), 1024.0);
        assert_eq!(e5m2(57344.0), E5M2_MAX);
        let mut rng = Pcg32::seeded(2);
        for _ in 0..1000 {
            let v = rng.range_f32(-5e4, 5e4);
            let r = e5m2(v);
            if v.abs() > 1.0 {
                assert!(((r - v) / v).abs() <= 0.125 + 1e-6, "v={v} r={r}");
            }
        }
    }

    #[test]
    fn auto_fallback_selects_format() {
        let small = Matrix::from_vec(1, 2, vec![1.3, -2.7]);
        let q = quantize_input(&small, InputQuant::Fp8Auto);
        // In e4m3 range → e4m3 rounding (1/16 rel err max)
        assert!((q.get(0, 0) - 1.3).abs() < 1.3 * 0.07);
        let big = Matrix::from_vec(1, 2, vec![1000.0, -2.7]);
        let qb = quantize_input(&big, InputQuant::Fp8Auto);
        assert_eq!(qb.get(0, 0), 1024.0); // e5m2 rounding of 1000
    }

    #[test]
    fn int8_absmax_small_relative_error() {
        let mut rng = Pcg32::seeded(3);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let q = quantize_input(&x, InputQuant::Int8AbsMax);
        assert!(q.rel_err(&x) < 0.02, "err {}", q.rel_err(&x));
    }

    #[test]
    fn none_is_identity() {
        let mut rng = Pcg32::seeded(4);
        let x = Matrix::randn(8, 8, 1.0, &mut rng);
        assert_eq!(quantize_input(&x, InputQuant::None), x);
    }
}
