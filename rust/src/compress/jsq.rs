//! JSQ-like joint sparsification + quantization baseline (Guo et al. 2024).
//!
//! JSQ interleaves pruning and quantization so each stage sees the other's
//! error, with an activation-aware clipping search. We reproduce its
//! skeleton: alternating rounds of (a) Wanda-style pruning on the current
//! fake-quant weights and (b) per-tensor quantization with a clip-ratio
//! search against the *joint* output-error proxy. The paper (and our
//! Table 1) shows this recovers LLaMA-style models reasonably but is
//! brittle at 4 bits — no low-rank compensation exists to absorb the joint
//! error.

use crate::quant::absmax::quantize_with_alpha;
use crate::sparse::mask::{mask_from_scores, Mask, SparsityPattern};
use crate::tensor::Matrix;

/// Number of alternation rounds.
pub const ROUNDS: usize = 3;
/// Clip-ratio grid searched each quantization step.
pub const CLIP_GRID: [f32; 6] = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5];

/// Jointly sparsify + quantize. Returns (W^C, mask).
pub fn compress(
    w: &Matrix,
    x_l2: &[f32],
    bits: u8,
    pattern: SparsityPattern,
) -> (Matrix, Mask) {
    let (d_in, d_out) = w.shape();
    assert_eq!(x_l2.len(), d_in);
    let mut current = w.clone();
    let mut mask = Mask::ones(d_in, d_out);

    for _round in 0..ROUNDS {
        // (a) prune on the current (possibly quantized) weights with
        // activation-weighted scores.
        let scores = Matrix::from_fn(d_in, d_out, |i, j| current.get(i, j).abs() * x_l2[i]);
        mask = mask_from_scores(&scores, pattern);
        let masked = mask.apply(w); // always re-prune from the original values

        // (b) quantize the surviving weights with a clip search that
        // minimizes the saliency-weighted reconstruction error.
        let max_abs = masked.max_abs();
        let mut best = (f64::INFINITY, masked.clone());
        for &ratio in CLIP_GRID.iter() {
            let q = quantize_with_alpha(&masked, bits, max_abs * ratio);
            let wq = mask.apply(&q.wq);
            let err: f64 = (0..d_in)
                .map(|i| {
                    let s = (x_l2[i] as f64) * (x_l2[i] as f64);
                    let rowerr: f64 = wq
                        .row(i)
                        .iter()
                        .zip(masked.row(i))
                        .map(|(&a, &b)| ((a - b) as f64).powi(2))
                        .sum();
                    s * rowerr
                })
                .sum();
            if err < best.0 {
                best = (err, wq);
            }
        }
        current = best.1;
    }
    (current, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn respects_pattern_and_bits() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::from_fn(64, 48, |_, _| rng.laplace(0.05));
        let x: Vec<f32> = (0..64).map(|_| 1.0 + rng.f32()).collect();
        let (wc, mask) = compress(&w, &x, 4, SparsityPattern::TWO_FOUR);
        assert!(mask.satisfies_nofm(2, 4));
        assert!((wc.sparsity() - 0.5).abs() < 0.1);
        // Quantized: few distinct magnitudes among nonzeros.
        let mut vals: Vec<i32> = wc
            .data()
            .iter()
            .filter(|&&v| v != 0.0)
            .map(|&v| (v * 1e4).round() as i32)
            .collect();
        vals.sort();
        vals.dedup();
        assert!(vals.len() <= 15, "distinct values {}", vals.len());
    }

    #[test]
    fn clip_search_helps() {
        // With heavy tails, the searched clip must beat ratio=1.0 (AbsMax).
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::from_fn(96, 64, |_, _| rng.laplace(0.03));
        let x = vec![1.0f32; 96];
        let (wc, mask) = compress(&w, &x, 4, SparsityPattern::Unstructured(0.5));
        let masked = mask.apply(&w);
        let err_jsq = wc.sub(&masked).fro_norm_sq();
        let absmax = quantize_with_alpha(&masked, 4, masked.max_abs());
        let err_absmax = mask.apply(&absmax.wq).sub(&masked).fro_norm_sq();
        assert!(err_jsq <= err_absmax, "jsq {err_jsq} vs absmax {err_absmax}");
    }
}
