//! Per-layer compression pipeline: quantize → prune → low-rank compensate.
//!
//! Mirrors paper Fig. 1: `W → (SLiM-Quant) → W^Q → (pruner) → W^C →
//! (SLiM-LoRA) → W^C + L·R`, with the quantization error `E_Q = W − W^Q`
//! and sparsity error `E_S = W^Q − W^C` tracked explicitly so experiment
//! drivers can report the error budget per stage.

use crate::calib::LayerStats;
use crate::lowrank::{adapter_quant, l2qer, naive, slim_lora, Adapters, LoraMethod};
use crate::quant::{quantize, QuantMethod};
use crate::sparse::{prune, Mask, PruneMethod, SparsityPattern};
use crate::tensor::Matrix;

/// Calibration inputs for one layer. Usually produced by
/// [`crate::calib::collect`]; tests construct it directly.
#[derive(Clone, Debug)]
pub struct LayerCalib {
    /// Raw calibration activations (b × d_in) — needed by SparseGPT/OPTQ/
    /// MaskLLM; optional for the cheap pruners.
    pub x: Option<Matrix>,
    /// Per-channel mean |x| (SLiM saliency, AWQ scaling).
    pub x_abs_mean: Vec<f32>,
    /// Per-channel ‖x‖₂ (Wanda metric).
    pub x_l2: Vec<f32>,
}

impl LayerCalib {
    /// Build from raw activations.
    pub fn from_activations(x: Matrix) -> Self {
        let x_abs_mean = x.col_abs_mean();
        let x_l2 = x.col_l2_norm();
        LayerCalib { x: Some(x), x_abs_mean, x_l2 }
    }

    /// Build from a [`LayerStats`] summary (when raw activations weren't
    /// retained).
    pub fn from_stats(stats: &LayerStats) -> Self {
        LayerCalib {
            x: stats.x.clone(),
            x_abs_mean: stats.x_abs_mean.clone(),
            x_l2: stats.x_l2.clone(),
        }
    }

    /// Uniform statistics fallback (degrades saliency methods gracefully).
    pub fn uniform(d_in: usize) -> Self {
        LayerCalib { x: None, x_abs_mean: vec![1.0; d_in], x_l2: vec![1.0; d_in] }
    }

    fn hessian(&self) -> Option<Matrix> {
        self.x.as_ref().map(|x| crate::tensor::matmul_at_b(x, x))
    }
}

/// Full pipeline configuration — one of these per table row.
#[derive(Clone, Copy, Debug)]
pub struct CompressConfig {
    pub quant: QuantMethod,
    pub bits: u8,
    pub prune: PruneMethod,
    /// None → no sparsity (quant-only experiments).
    pub pattern: Option<SparsityPattern>,
    pub lora: LoraMethod,
    /// Adapter rank as a fraction of min(d_in, d_out); paper default 0.1.
    pub rank_ratio: f32,
    /// §3.3: group-quantize the adapters (`…^Q` variants).
    pub quantize_adapters: bool,
}

impl CompressConfig {
    /// Dense pass-through (for baselines rows).
    pub fn dense() -> Self {
        CompressConfig {
            quant: QuantMethod::None,
            bits: 32,
            prune: PruneMethod::None,
            pattern: None,
            lora: LoraMethod::None,
            rank_ratio: 0.1,
            quantize_adapters: false,
        }
    }

    /// The paper's flagship config: SLiM-Quant^W + Wanda 2:4 + SLiM-LoRA.
    pub fn slim(pattern: SparsityPattern) -> Self {
        CompressConfig {
            quant: QuantMethod::SlimQuantW,
            bits: 4,
            prune: PruneMethod::Wanda,
            pattern: Some(pattern),
            lora: LoraMethod::Slim,
            rank_ratio: 0.1,
            quantize_adapters: false,
        }
    }
}

/// Result of compressing one layer.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    /// Compressed base weights W^C (fake-quant values, zeros at mask).
    pub wc: Matrix,
    /// Sparsity mask.
    pub mask: Mask,
    /// Adapters, if configured.
    pub adapters: Option<Adapters>,
    /// ‖E_Q‖² = ‖W − W^Q‖² — quantization-stage error.
    pub e_quant: f64,
    /// ‖E_S‖² = ‖W^Q − W^C‖² — sparsity-stage error.
    pub e_sparse: f64,
    /// ‖W − (W^C + L·R)‖² — final reconstruction error.
    pub e_final: f64,
    /// Weight bits (4 for int4, 32 for none).
    pub bits: u8,
    /// Per-group quantization scales (for the packed kernels).
    pub scales: Vec<f32>,
    /// Quantization group size (0 = per-tensor).
    pub group_size: usize,
}

impl CompressedLayer {
    /// The effective dense weight the model sees: `W^C + L·R`.
    pub fn effective(&self) -> Matrix {
        match &self.adapters {
            Some(a) => self.wc.add(&a.product()),
            None => self.wc.clone(),
        }
    }

    /// Adapter rank (0 if none).
    pub fn rank(&self) -> usize {
        self.adapters.as_ref().map(|a| a.rank()).unwrap_or(0)
    }
}

/// Run the full pipeline on one layer.
pub fn compress_layer(w: &Matrix, calib: &LayerCalib, cfg: &CompressConfig) -> CompressedLayer {
    let (d_in, d_out) = w.shape();
    assert_eq!(calib.x_abs_mean.len(), d_in);

    // ── Stage 1: quantization (paper §3.1) ───────────────────────────────
    let hessian = if cfg.quant == QuantMethod::GroupOptq { calib.hessian() } else { None };
    let q = quantize(w, cfg.quant, cfg.bits, Some(&calib.x_abs_mean), hessian.as_ref());
    let wq = q.wq;
    let e_quant = wq.sub(w).fro_norm_sq();

    // ── Stage 2: pruning on the quantized weights (paper §3.2 intro) ─────
    let (wc, mask) = match cfg.pattern {
        Some(pattern) => prune(
            &wq,
            cfg.prune,
            pattern,
            Some(&calib.x_l2),
            calib.x.as_ref(),
        ),
        None => (wq.clone(), Mask::ones(d_in, d_out)),
    };
    let e_sparse = wc.sub(&wq).fro_norm_sq();

    // ── Stage 3: low-rank error compensation (paper §3.2) ────────────────
    let rank = ((d_in.min(d_out) as f32 * cfg.rank_ratio).round() as usize).max(1);
    let adapters = match cfg.lora {
        LoraMethod::None => None,
        LoraMethod::Naive => Some(naive::adapters(w, &wc, rank)),
        LoraMethod::Slim => Some(slim_lora::adapters(w, &wc, &calib.x_abs_mean, rank)),
        // L²QER compensates only the quantization error (pre-pruning).
        LoraMethod::L2qer => Some(l2qer::adapters(w, &wq, &calib.x_abs_mean, rank)),
    };
    let adapters = match (adapters, cfg.quantize_adapters) {
        (Some(a), true) => Some(adapter_quant::quantize(&a)),
        (a, _) => a,
    };

    let effective = match &adapters {
        Some(a) => wc.add(&a.product()),
        None => wc.clone(),
    };
    let e_final = effective.sub(w).fro_norm_sq();

    CompressedLayer {
        wc,
        mask,
        adapters,
        e_quant,
        e_sparse,
        e_final,
        bits: cfg.bits,
        scales: q.scales,
        group_size: q.group_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn layer(seed: u64) -> (Matrix, LayerCalib) {
        let mut rng = Pcg32::seeded(seed);
        let d_in = 128;
        let d_out = 96;
        let w = Matrix::from_fn(d_in, d_out, |_, _| rng.laplace(0.04));
        let mut x = Matrix::randn(96, d_in, 1.0, &mut rng);
        for i in 0..96 {
            for j in 0..10 {
                let v = x.get(i, j) * 6.0;
                x.set(i, j, v);
            }
        }
        (w, LayerCalib::from_activations(x))
    }

    #[test]
    fn slim_pipeline_error_budget() {
        let (w, calib) = layer(1);
        let out = compress_layer(&w, &calib, &CompressConfig::slim(SparsityPattern::TWO_FOUR));
        // Stage errors are positive and the adapters reduce the total error
        // below the raw compressed error.
        assert!(out.e_quant > 0.0);
        assert!(out.e_sparse > 0.0);
        let e_compressed = out.wc.sub(&w).fro_norm_sq();
        assert!(out.e_final < e_compressed, "{} !< {}", out.e_final, e_compressed);
        assert!(out.mask.satisfies_nofm(2, 4));
        assert!((out.wc.sparsity() - 0.5).abs() < 0.05);
    }

    #[test]
    fn dense_config_is_identity() {
        let (w, calib) = layer(2);
        let out = compress_layer(&w, &calib, &CompressConfig::dense());
        assert_eq!(out.effective(), w);
        assert_eq!(out.e_final, 0.0);
        assert_eq!(out.rank(), 0);
    }

    #[test]
    fn slim_beats_naive_on_saliency_error() {
        let (w, calib) = layer(3);
        let mut cfg = CompressConfig::slim(SparsityPattern::TWO_FOUR);
        let slim = compress_layer(&w, &calib, &cfg);
        cfg.lora = LoraMethod::Naive;
        let naive_out = compress_layer(&w, &calib, &cfg);
        let e_slim =
            crate::lowrank::slim_lora::saliency_error(&w, &slim.effective(), &calib.x_abs_mean);
        let e_naive = crate::lowrank::slim_lora::saliency_error(
            &w,
            &naive_out.effective(),
            &calib.x_abs_mean,
        );
        assert!(e_slim < e_naive, "slim {e_slim} vs naive {e_naive}");
    }

    #[test]
    fn adapter_quantization_small_penalty() {
        let (w, calib) = layer(4);
        let mut cfg = CompressConfig::slim(SparsityPattern::TWO_FOUR);
        let plain = compress_layer(&w, &calib, &cfg);
        cfg.quantize_adapters = true;
        let quanted = compress_layer(&w, &calib, &cfg);
        // ^Q variant should be within a few percent of the fp adapter error.
        assert!(quanted.e_final < plain.e_final * 1.25, "{} vs {}", quanted.e_final, plain.e_final);
    }

    #[test]
    fn quant_only_and_sparse_only_paths() {
        let (w, calib) = layer(5);
        // Quant-only.
        let mut cfg = CompressConfig::slim(SparsityPattern::TWO_FOUR);
        cfg.pattern = None;
        cfg.prune = PruneMethod::None;
        let q_only = compress_layer(&w, &calib, &cfg);
        assert_eq!(q_only.e_sparse, 0.0);
        assert_eq!(q_only.mask.density(), 1.0);
        // Sparse-only.
        let mut cfg2 = CompressConfig::slim(SparsityPattern::TWO_FOUR);
        cfg2.quant = QuantMethod::None;
        cfg2.bits = 32;
        let s_only = compress_layer(&w, &calib, &cfg2);
        assert_eq!(s_only.e_quant, 0.0);
        assert!(s_only.e_sparse > 0.0);
    }

    #[test]
    fn rank_ratio_scales_rank() {
        let (w, calib) = layer(6);
        let mut cfg = CompressConfig::slim(SparsityPattern::TWO_FOUR);
        cfg.rank_ratio = 0.25;
        let out = compress_layer(&w, &calib, &cfg);
        assert_eq!(out.rank(), 24); // 0.25 * min(128, 96)
    }
}
