//! Named method presets — one per row of the paper's tables.
//!
//! Each [`Preset`] maps to the exact (`quant`, `prune`, `lora`) combination
//! the paper evaluates, so experiment drivers iterate over presets and
//! render rows with the paper's own labels.

use super::pipeline::CompressConfig;
use crate::lowrank::LoraMethod;
use crate::quant::QuantMethod;
use crate::sparse::{PruneMethod, SparsityPattern};

/// A named table row from the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// Uncompressed reference.
    Dense,
    /// Magnitude pruning + Group AbsMax (Table 1 worst baseline).
    MagnitudeGroupAbsMax,
    /// SparseGPT + Group OPTQ (designed-together baseline).
    SparseGptGroupOptq,
    /// Wanda + Group AbsMax ("Best Method*" stand-in; strongest of the
    /// simple quantizer pairings we implement).
    WandaGroupAbsMax,
    /// JSQ joint baseline.
    Jsq,
    /// L²QER adapters over Group AbsMax quant + Wanda pruning.
    L2qer,
    /// Naive-LoRA over SLiM-Quant^W + Wanda.
    NaiveLora,
    /// SLiM-LoRA over SLiM-Quant^W + Wanda (the paper's method).
    SlimLora,
    /// SLiM-LoRA with quantized adapters (SLiM-LoRA^Q).
    SlimLoraQ,
    /// SLiM-LoRA over SLiM-Quant^O (activation-aware; Apx C).
    SlimLoraQuantO,
    /// MaskLLM-style masks, no adapters (Table 3).
    MaskLlm,
    /// MaskLLM masks + SLiM-LoRA (Table 3).
    MaskLlmSlimLora,
}

impl Preset {
    /// All Table 1 rows, in the paper's order.
    pub fn table1() -> Vec<Preset> {
        vec![
            Preset::MagnitudeGroupAbsMax,
            Preset::SparseGptGroupOptq,
            Preset::WandaGroupAbsMax,
            Preset::Jsq,
            Preset::L2qer,
            Preset::NaiveLora,
            Preset::SlimLora,
            Preset::SlimLoraQ,
        ]
    }

    /// Row label matching the paper (pruning/LoRA method, quantizer).
    pub fn label(&self) -> (&'static str, &'static str) {
        match self {
            Preset::Dense => ("Dense", "-"),
            Preset::MagnitudeGroupAbsMax => ("Magnitude", "Group AbsMax"),
            Preset::SparseGptGroupOptq => ("SparseGPT", "Group OPTQ"),
            Preset::WandaGroupAbsMax => ("Wanda", "Group AbsMax"),
            Preset::Jsq => ("JSQ", "JSQ"),
            Preset::L2qer => ("L2QER", "Group AbsMax"),
            Preset::NaiveLora => ("Naive-LoRA", "SLiM-Quant^W"),
            Preset::SlimLora => ("SLiM-LoRA", "SLiM-Quant^W"),
            Preset::SlimLoraQ => ("SLiM-LoRA^Q", "SLiM-Quant^W"),
            Preset::SlimLoraQuantO => ("SLiM-LoRA", "SLiM-Quant^O"),
            Preset::MaskLlm => ("MaskLLM*", "-"),
            Preset::MaskLlmSlimLora => ("MaskLLM* + SLiM-LoRA", "SLiM-Quant^W"),
        }
    }

    /// Whether the JSQ special path applies (joint loop instead of staged).
    pub fn is_jsq(&self) -> bool {
        matches!(self, Preset::Jsq)
    }

    /// Build the pipeline config for this preset at the given sparsity
    /// pattern (None → quant-only) and weight bit-width.
    pub fn config(&self, pattern: Option<SparsityPattern>, bits: u8) -> CompressConfig {
        let base = CompressConfig {
            quant: QuantMethod::None,
            bits,
            prune: PruneMethod::None,
            pattern,
            lora: LoraMethod::None,
            rank_ratio: 0.1,
            quantize_adapters: false,
        };
        match self {
            Preset::Dense => CompressConfig::dense(),
            Preset::MagnitudeGroupAbsMax => CompressConfig {
                quant: QuantMethod::GroupAbsMax,
                prune: PruneMethod::Magnitude,
                ..base
            },
            Preset::SparseGptGroupOptq => CompressConfig {
                quant: QuantMethod::GroupOptq,
                prune: PruneMethod::SparseGpt,
                ..base
            },
            Preset::WandaGroupAbsMax => CompressConfig {
                quant: QuantMethod::GroupAbsMax,
                prune: PruneMethod::Wanda,
                ..base
            },
            // JSQ is handled by the joint loop in `compress::jsq`; the
            // config here is only used for bookkeeping.
            Preset::Jsq => CompressConfig {
                quant: QuantMethod::AbsMax,
                prune: PruneMethod::Wanda,
                ..base
            },
            Preset::L2qer => CompressConfig {
                quant: QuantMethod::GroupAbsMax,
                prune: PruneMethod::Wanda,
                lora: LoraMethod::L2qer,
                ..base
            },
            Preset::NaiveLora => CompressConfig {
                quant: QuantMethod::SlimQuantW,
                prune: PruneMethod::Wanda,
                lora: LoraMethod::Naive,
                ..base
            },
            Preset::SlimLora => CompressConfig {
                quant: QuantMethod::SlimQuantW,
                prune: PruneMethod::Wanda,
                lora: LoraMethod::Slim,
                ..base
            },
            Preset::SlimLoraQ => CompressConfig {
                quant: QuantMethod::SlimQuantW,
                prune: PruneMethod::Wanda,
                lora: LoraMethod::Slim,
                quantize_adapters: true,
                ..base
            },
            Preset::SlimLoraQuantO => CompressConfig {
                quant: QuantMethod::SlimQuantO,
                prune: PruneMethod::Wanda,
                lora: LoraMethod::Slim,
                ..base
            },
            Preset::MaskLlm => CompressConfig {
                quant: QuantMethod::None,
                bits: 32,
                prune: PruneMethod::MaskLlm,
                ..base
            },
            Preset::MaskLlmSlimLora => CompressConfig {
                quant: QuantMethod::SlimQuantW,
                prune: PruneMethod::MaskLlm,
                lora: LoraMethod::Slim,
                ..base
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_paper_rows() {
        let rows = Preset::table1();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].label().0, "Magnitude");
        assert_eq!(rows[7].label().0, "SLiM-LoRA^Q");
    }

    #[test]
    fn configs_are_consistent() {
        let p = SparsityPattern::TWO_FOUR;
        let cfg = Preset::SlimLora.config(Some(p), 4);
        assert_eq!(cfg.quant, QuantMethod::SlimQuantW);
        assert_eq!(cfg.lora, LoraMethod::Slim);
        assert!(!cfg.quantize_adapters);
        let cfgq = Preset::SlimLoraQ.config(Some(p), 4);
        assert!(cfgq.quantize_adapters);
        let dense = Preset::Dense.config(None, 4);
        assert_eq!(dense.quant, QuantMethod::None);
    }
}
