//! The SLiM compression pipeline (paper Fig. 1) and method presets.
//!
//! [`pipeline`] wires the three stages — SLiM-Quant → pruning → SLiM-LoRA —
//! over a single layer, with per-stage error bookkeeping (`E_Q`, `E_S`,
//! final). [`jsq`] implements the Joint Sparsification-and-Quantization
//! baseline. [`presets`] names the exact method combinations that appear as
//! rows in the paper's tables.

pub mod jsq;
pub mod pipeline;
pub mod presets;

pub use pipeline::{compress_layer, CompressConfig, CompressedLayer, LayerCalib};
pub use presets::Preset;
