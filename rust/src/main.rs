//! `repro` — the SLiM reproduction CLI (L3 entrypoint).
//!
//! Commands:
//!   repro exp <id>|all [--full]      regenerate a paper table/figure
//!   repro train <model> [--steps N]  pretrain a sim model (cached)
//!   repro compress <model> [--preset P] [--pattern 2:4|50%] [--bits B]
//!   repro eval <model> [--preset P] [--pattern ...] [--ft]
//!   repro serve [--model M] [--addr A] [--compressed [--overrides]]
//!   repro models                     list the sim family
//!
//! Hand-rolled arg parsing (no clap in the vendored crate set).

use anyhow::{anyhow, bail, Result};
use slim::compress::Preset;
use slim::data::{Corpus, CorpusSpec};
use slim::experiments::{self, Ctx};
use slim::model;
use slim::runtime::Runtime;
use slim::server::{api, BatchPolicy, Engine, Router};
use slim::sparse::SparsityPattern;
use slim::train;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Flags {
    positional: Vec<String>,
    named: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_flags(args: &[String]) -> Flags {
    let mut f = Flags {
        positional: vec![],
        named: Default::default(),
        switches: Default::default(),
    };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                f.named.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                f.switches.insert(name.to_string());
                i += 1;
            }
        } else {
            f.positional.push(a.clone());
            i += 1;
        }
    }
    f
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "exp" => cmd_exp(&flags),
        "train" => cmd_train(&flags),
        "compress" => cmd_compress(&flags),
        "eval" => cmd_eval(&flags),
        "serve" => cmd_serve(&flags),
        "models" => {
            for c in model::family() {
                println!(
                    "{:<16} d={:<4} layers={} heads={} params={} (stands for {})",
                    c.name,
                    c.d_model,
                    c.n_layers,
                    c.n_heads,
                    c.param_count(),
                    c.stands_for
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other}; try `repro help`"),
    }
}

fn print_help() {
    println!(
        "repro — SLiM (ICML 2025) reproduction\n\
         \n\
           repro exp <id>|all [--full]     regenerate paper tables/figures\n\
                                           ids: {}\n\
           repro train <model> [--steps N]\n\
           repro compress <model> [--preset slim-lora] [--pattern 2:4] [--bits 4]\n\
           repro eval <model> [--preset P] [--pattern 2:4] [--ft]\n\
           repro serve [--model sim-125m] [--addr 127.0.0.1:7433] [--compressed [--overrides]]\n\
           repro models",
        experiments::ALL.join(",")
    );
}

fn parse_preset(s: &str) -> Result<Preset> {
    Ok(match s {
        "dense" => Preset::Dense,
        "magnitude" => Preset::MagnitudeGroupAbsMax,
        "sparsegpt" => Preset::SparseGptGroupOptq,
        "wanda" => Preset::WandaGroupAbsMax,
        "jsq" => Preset::Jsq,
        "l2qer" => Preset::L2qer,
        "naive-lora" => Preset::NaiveLora,
        "slim-lora" => Preset::SlimLora,
        "slim-lora-q" => Preset::SlimLoraQ,
        "slim-lora-o" => Preset::SlimLoraQuantO,
        "maskllm" => Preset::MaskLlm,
        other => bail!("unknown preset {other}"),
    })
}

fn cmd_exp(flags: &Flags) -> Result<()> {
    let id = flags
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: repro exp <id>|all"))?;
    let quick = !flags.switches.contains("full");
    let ctx = Ctx::new(quick)?;
    if id == "all" {
        for exp in experiments::ALL {
            println!("\n━━━ {exp} ━━━");
            experiments::run(&ctx, exp)?;
        }
    } else {
        experiments::run(&ctx, id)?;
    }
    Ok(())
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let name = flags
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: repro train <model>"))?;
    let steps: usize = flags
        .named
        .get("steps")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(400);
    let cfg = model::by_name(name).ok_or_else(|| anyhow!("unknown model {name}"))?;
    let rt = Runtime::load(Runtime::default_dir())?;
    let corpus = Corpus::generate(CorpusSpec::SynthWeb, 120_000);
    let report = train::pretrain(&rt, &cfg, &corpus, steps, 0x7a11)?;
    println!(
        "trained {name} for {steps} steps: loss {:.3} -> {:.3}",
        report.losses.first().unwrap_or(&0.0),
        report.losses.last().unwrap_or(&0.0)
    );
    let path = train::checkpoint_path(&cfg);
    report.weights.save(&path)?;
    println!("saved {}", path.display());
    Ok(())
}

fn setup_model(flags: &Flags) -> Result<(Ctx, Arc<experiments::harness::ModelBundle>)> {
    let name = flags
        .positional
        .first()
        .ok_or_else(|| anyhow!("missing <model>"))?;
    let ctx = Ctx::new(true)?;
    let bundle = ctx.bundle(name)?;
    Ok((ctx, bundle))
}

fn pattern_of(flags: &Flags) -> Result<Option<SparsityPattern>> {
    match flags.named.get("pattern") {
        None => Ok(Some(SparsityPattern::TWO_FOUR)),
        Some(s) if s == "none" => Ok(None),
        Some(s) => SparsityPattern::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("bad pattern {s}")),
    }
}

fn cmd_compress(flags: &Flags) -> Result<()> {
    let (ctx, b) = setup_model(flags)?;
    let preset =
        parse_preset(flags.named.get("preset").map(|s| s.as_str()).unwrap_or("slim-lora"))?;
    let pattern = pattern_of(flags)?;
    let bits: u8 = flags.named.get("bits").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let (cm, secs) = slim::util::timed(|| ctx.compress(&b, preset, pattern, bits));
    let mut e_q = 0.0;
    let mut e_s = 0.0;
    let mut e_f = 0.0;
    for layer in cm.layers.values() {
        e_q += layer.e_quant;
        e_s += layer.e_sparse;
        e_f += layer.e_final;
    }
    println!(
        "compressed {} with {:?} in {}: layers={} E_Q={:.4} E_S={:.4} E_final={:.4}",
        b.cfg.name,
        preset,
        slim::util::fmt_secs(secs),
        cm.layers.len(),
        e_q,
        e_s,
        e_f
    );
    Ok(())
}

fn cmd_eval(flags: &Flags) -> Result<()> {
    let (ctx, b) = setup_model(flags)?;
    let dense_acc = ctx.acc(&b, None);
    let dense_ppl = ctx.ppl(&b, None);
    println!("{} dense: acc {:.2}% ppl {:.2}", b.cfg.name, dense_acc, dense_ppl);
    if let Some(p) = flags.named.get("preset") {
        let preset = parse_preset(p)?;
        let pattern = pattern_of(flags)?;
        let mut cm = ctx.compress(&b, preset, pattern, 4);
        if flags.switches.contains("ft") {
            ctx.finetune(&b, &mut cm, preset == Preset::SlimLoraQ)?;
        }
        let acc = ctx.acc(&b, Some(&cm.overrides));
        let ppl = ctx.ppl(&b, Some(&cm.overrides));
        println!("{} {:?}: acc {:.2}% ppl {:.2}", b.cfg.name, preset, acc, ppl);
    }
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let name = flags
        .named
        .get("model")
        .map(|s| s.as_str())
        .unwrap_or("sim-125m");
    let addr = flags
        .named
        .get("addr")
        .map(|s| s.as_str())
        .unwrap_or("127.0.0.1:7433");
    let ctx = Ctx::new(true)?;
    let b = ctx.bundle(name)?;
    let weights = Arc::new(b.weights.clone());
    let engine = if flags.switches.contains("compressed") {
        let cm = ctx.compress(&b, Preset::SlimLora, Some(SparsityPattern::TWO_FOUR), 4);
        if flags.switches.contains("overrides") {
            // Legacy dense-override eval path (accuracy-identical, slower).
            println!("serving SLiM-compressed weights via dense overrides");
            Engine::new(name, b.cfg.clone(), weights, Some(Arc::new(cm.overrides)))
        } else {
            let cw = slim::model::CompressedWeights::from_model(&cm);
            let census: Vec<String> =
                cw.kernel_census().iter().map(|(k, n)| format!("{n}x {k}")).collect();
            println!(
                "serving SLiM-compressed weights on packed kernels ({}; {} weight bytes/step)",
                census.join(", "),
                cw.weight_bytes()
            );
            Engine::with_kernels(name, b.cfg.clone(), weights, Arc::new(cw))
        }
    } else {
        Engine::new(name, b.cfg.clone(), weights, None)
    };
    let mut router = Router::new();
    router.register(engine, BatchPolicy::default());
    let router = Arc::new(router);
    println!("listening on {addr} — protocol: one JSON per line");
    println!(
        r#"  try: echo '{{"model":"{name}","prompt":[8,2],"max_new":8}}' | nc 127.0.0.1 7433"#
    );
    api::serve(router, addr, |bound| println!("bound {bound}"))?;
    Ok(())
}

// Quick smoke of CLI plumbing (no artifacts needed).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse() {
        let args: Vec<String> =
            ["sim-125m", "--steps", "10", "--full"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args);
        assert_eq!(f.positional, vec!["sim-125m"]);
        assert_eq!(f.named.get("steps").unwrap(), "10");
        assert!(f.switches.contains("full"));
    }

    #[test]
    fn preset_names() {
        assert!(parse_preset("slim-lora").is_ok());
        assert!(parse_preset("nope").is_err());
    }
}
