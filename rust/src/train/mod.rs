//! Training drivers: Rust owns the loop, the AOT HLO owns the math.
//!
//! * [`pretrain`] — drives the fused `train_step_<cfg>` artifact (forward +
//!   backward + AdamW in one executable) over synthetic-corpus batches to
//!   produce the sim-family checkpoints. Parameters live as device literals
//!   across steps — no per-step marshalling.
//! * [`finetune_adapters`] — the paper's PEFT recipe (§3.4): drives
//!   `ft_step_<cfg>`, which updates only the low-rank adapters with frozen
//!   compressed base weights. For `…^Q` variants the adapters are
//!   re-quantized after fine-tuning (post-hoc STE approximation; see
//!   DESIGN.md).

use crate::data::Corpus;
use crate::model::{self, CompressedModel, ModelConfig, Weights};
use crate::rng::Pcg32;
use crate::runtime::{marshal, Runtime};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// Default pretraining hyperparameters.
pub const PRETRAIN_LR: f32 = 3e-3;
pub const FT_LR: f32 = 1e-3;

fn scalar_lit(v: f32) -> Result<xla::Literal> {
    marshal::matrix_to_literal(&Matrix::from_vec(1, 1, vec![v]), &[1, 1])
}

/// Result of a pretraining run.
pub struct TrainReport {
    pub weights: Weights,
    pub losses: Vec<f64>,
}

/// Pretrain a config from scratch on the corpus for `steps` steps.
pub fn pretrain(
    rt: &Runtime,
    cfg: &ModelConfig,
    corpus: &Corpus,
    steps: usize,
    seed: u64,
) -> Result<TrainReport> {
    let entry_name = format!("train_step_{}", cfg.name);
    let entry = rt.entry(&entry_name)?.clone();
    let batch = entry.meta_usize("batch").ok_or_else(|| anyhow!("no batch meta"))?;
    let seq = entry.meta_usize("seq").ok_or_else(|| anyhow!("no seq meta"))?;
    let n_params = entry.meta_usize("n_params").ok_or_else(|| anyhow!("no n_params"))?;

    let mut rng = Pcg32::seeded(seed);
    let init = model::init(cfg, &mut rng);
    let order = model::param_order(cfg);

    // Initial device literals: params + zeroed m/v.
    let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n_params);
    for name in &order {
        let m = init.expect(name);
        state.push(marshal::matrix_to_literal(m, &[m.rows(), m.cols()])?);
    }
    for name in &order {
        let m = init.expect(name);
        let z = Matrix::zeros(m.rows(), m.cols());
        state.push(marshal::matrix_to_literal(&z, &[m.rows(), m.cols()])?);
    }
    for name in &order {
        let m = init.expect(name);
        let z = Matrix::zeros(m.rows(), m.cols());
        state.push(marshal::matrix_to_literal(&z, &[m.rows(), m.cols()])?);
    }

    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let toks = corpus.batch(batch, seq, &mut rng);
        let mut inputs = std::mem::take(&mut state);
        inputs.push(scalar_lit((step + 1) as f32)?);
        inputs.push(scalar_lit(PRETRAIN_LR)?);
        inputs.push(marshal::tokens_to_literal(&toks, batch, seq)?);
        let mut outs = rt.execute(&entry_name, &inputs)?;
        let loss_lit = outs.pop().ok_or_else(|| anyhow!("missing loss output"))?;
        let loss: Vec<f32> = loss_lit.to_vec().map_err(|e| anyhow!("loss read: {e:?}"))?;
        losses.push(loss[0] as f64);
        state = outs; // params+m+v roll forward as literals
    }

    // Unpack final params.
    let mut weights = Weights::new();
    for (i, name) in order.iter().enumerate() {
        let spec = &entry.outputs[i];
        let m = marshal::literal_to_matrix(&state[i], spec)?;
        weights.set(name, m);
    }
    Ok(TrainReport { weights, losses })
}

/// Where cached checkpoints live.
pub fn checkpoint_path(cfg: &ModelConfig) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("runs/weights")
        .join(format!("{}.bin", cfg.name))
}

/// Pretrain unless a cached checkpoint exists (experiments share these).
pub fn pretrain_cached(
    rt: &Runtime,
    cfg: &ModelConfig,
    corpus: &Corpus,
    steps: usize,
) -> Result<Weights> {
    let path = checkpoint_path(cfg);
    if path.exists() {
        return Weights::load(&path);
    }
    crate::info!("pretraining {} for {} steps", cfg.name, steps);
    let report = pretrain(rt, cfg, corpus, steps, 0x7a11)?;
    crate::info!(
        "{}: loss {:.3} -> {:.3}",
        cfg.name,
        report.losses.first().copied().unwrap_or(0.0),
        report.losses.last().copied().unwrap_or(0.0)
    );
    report.weights.save(&path)?;
    Ok(report.weights)
}

/// Fine-tune the adapters of a compressed model (paper §3.4). Mutates the
/// compressed model's adapters and refreshed overrides in place; returns
/// the loss curve.
pub fn finetune_adapters(
    rt: &Runtime,
    cfg: &ModelConfig,
    weights: &Weights,
    cm: &mut CompressedModel,
    corpus: &Corpus,
    steps: usize,
    requantize_adapters: bool,
) -> Result<Vec<f64>> {
    let entry_name = format!("ft_step_{}", cfg.name);
    let entry = rt.entry(&entry_name)?.clone();
    let batch = entry.meta_usize("batch").ok_or_else(|| anyhow!("no batch meta"))?;
    let seq = entry.meta_usize("seq").ok_or_else(|| anyhow!("no seq meta"))?;
    let n_c = entry.meta_usize("n_cparams").ok_or_else(|| anyhow!("no n_cparams"))?;
    let n_t = entry.meta_usize("n_trainable").ok_or_else(|| anyhow!("no n_trainable"))?;

    // Build the compressed parameter list in manifest order.
    let cspecs = &entry.inputs[..n_c];
    let mut cparams: Vec<Matrix> = Vec::with_capacity(n_c);
    for spec in cspecs {
        let m = compressed_tensor(cfg, weights, cm, &spec.name, &spec.shape)?;
        cparams.push(m);
    }

    // Trainable slots (adapters), per manifest order within cspecs.
    let trainable_idx: Vec<usize> = (0..n_c)
        .filter(|&i| cspecs[i].name.ends_with(".l") || cspecs[i].name.ends_with(".r"))
        .collect();
    if trainable_idx.len() != n_t {
        return Err(anyhow!("trainable count mismatch: {} vs {n_t}", trainable_idx.len()));
    }

    // Optimizer state starts at zero; adapters update in `cparams` each
    // step (frozen tensors are re-marshalled — they are tiny at sim scale).
    let mut opt_m: Vec<Matrix> = trainable_idx
        .iter()
        .map(|&i| Matrix::zeros(cparams[i].rows(), cparams[i].cols()))
        .collect();
    let mut opt_v: Vec<Matrix> = opt_m.clone();

    let mut rng = Pcg32::seeded(0xf17e);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let toks = corpus.batch(batch, seq, &mut rng);
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n_c + 2 * n_t + 3);
        for (m, s) in cparams.iter().zip(cspecs.iter()) {
            inputs.push(marshal::matrix_to_literal(m, &s.shape)?);
        }
        for m in opt_m.iter().chain(opt_v.iter()) {
            inputs.push(marshal::matrix_to_literal(m, &[m.rows(), m.cols()])?);
        }
        inputs.push(scalar_lit((step + 1) as f32)?);
        inputs.push(scalar_lit(FT_LR)?);
        inputs.push(marshal::tokens_to_literal(&toks, batch, seq)?);
        let mut outs = rt.execute(&entry_name, &inputs)?;
        let loss_lit = outs.pop().ok_or_else(|| anyhow!("missing loss"))?;
        let loss: Vec<f32> = loss_lit.to_vec().map_err(|e| anyhow!("loss read: {e:?}"))?;
        losses.push(loss[0] as f64);
        // Outputs: new_t (n_t), new_m (n_t), new_v (n_t).
        let out_specs = &entry.outputs;
        for (k, lit) in outs.iter().enumerate() {
            let mat = marshal::literal_to_matrix(lit, &out_specs[k])?;
            if k < n_t {
                cparams[trainable_idx[k]] = mat;
            } else if k < 2 * n_t {
                opt_m[k - n_t] = mat;
            } else {
                opt_v[k - 2 * n_t] = mat;
            }
        }
    }

    // Write the tuned adapters back into the compressed model and refresh
    // the effective-weight overrides.
    for (i, spec) in cspecs.iter().enumerate() {
        let (is_l, base) = if let Some(b) = spec.name.strip_suffix(".l") {
            (true, b.to_string())
        } else if let Some(b) = spec.name.strip_suffix(".r") {
            (false, b.to_string())
        } else {
            continue;
        };
        if let Some(layer) = cm.layers.get_mut(&base) {
            if let Some(ad) = layer.adapters.as_mut() {
                if is_l {
                    ad.l = cparams[i].clone();
                } else {
                    ad.r = cparams[i].clone();
                }
            }
        }
    }
    if requantize_adapters {
        for layer in cm.layers.values_mut() {
            if let Some(ad) = layer.adapters.as_mut() {
                *ad = crate::lowrank::adapter_quant::quantize(ad);
            }
        }
    }
    for (name, layer) in cm.layers.iter() {
        cm.overrides.insert(name.clone(), layer.effective());
    }
    Ok(losses)
}

/// Resolve one compressed-parameter tensor by manifest name.
fn compressed_tensor(
    cfg: &ModelConfig,
    weights: &Weights,
    cm: &CompressedModel,
    name: &str,
    shape: &[usize],
) -> Result<Matrix> {
    let _ = cfg;
    // Linear-derived tensors end in .wq/.scale/.mask/.l/.r.
    for suffix in [".wq", ".scale", ".mask", ".l", ".r"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if let Some(layer) = cm.layers.get(base) {
                let (r, c) = (shape[0], shape.get(1).copied().unwrap_or(1));
                return Ok(match suffix {
                    ".wq" => codes_matrix(layer, r, c),
                    ".scale" => Matrix::from_vec(1, 1, vec![per_tensor_scale(layer)]),
                    ".mask" => layer.mask.to_matrix(),
                    ".l" => adapter_part(layer, true, r, c),
                    ".r" => adapter_part(layer, false, r, c),
                    _ => unreachable!(),
                });
            }
        }
    }
    // Everything else is a dense (frozen) tensor.
    weights
        .get(name)
        .cloned()
        .ok_or_else(|| anyhow!("no tensor for compressed param {name}"))
}

fn per_tensor_scale(layer: &crate::compress::CompressedLayer) -> f32 {
    if layer.scales.len() == 1 {
        layer.scales[0]
    } else {
        // Group-quantized bases can't be represented by one scale; the FT
        // path is only used with per-tensor SLiM-Quant (paper's FT rows).
        layer.scales.iter().fold(0.0f32, |m, &s| m.max(s))
    }
}

fn codes_matrix(layer: &crate::compress::CompressedLayer, r: usize, c: usize) -> Matrix {
    // Reconstruct integer codes from the fake-quant weights: codes =
    // wc / (alpha/levels). Exact for per-tensor quantization.
    let alpha = per_tensor_scale(layer);
    let levels = crate::quant::levels(layer.bits.min(8));
    if alpha <= 0.0 {
        return Matrix::zeros(r, c);
    }
    layer.wc.map(|v| (v * levels / alpha).round())
}

fn adapter_part(
    layer: &crate::compress::CompressedLayer,
    left: bool,
    r: usize,
    c: usize,
) -> Matrix {
    match &layer.adapters {
        Some(a) => {
            let m = if left { &a.l } else { &a.r };
            if m.shape() == (r, c) {
                return m.clone();
            }
            // Rank mismatch (config rank_ratio != AOT default): pad/trim.
            let mut out = Matrix::zeros(r, c);
            for i in 0..r.min(m.rows()) {
                let cols = c.min(m.cols());
                out.row_mut(i)[..cols].copy_from_slice(&m.row(i)[..cols]);
            }
            out
        }
        None => Matrix::zeros(r, c),
    }
}
