//! Token sampling: temperature / top-k / top-p over a logits row, driven
//! by a per-request seeded RNG.
//!
//! [`SampleParams`] travels with every `server::engine::GenRequest`; the
//! default (`temperature == 0`) is greedy argmax — exactly
//! [`greedy_pick`], with its documented lowest-index tie-break — and
//! consumes **zero** RNG draws, so greedy requests stay bit-compatible
//! with the pre-sampling serving stack. A non-greedy pick consumes
//! **exactly one** `f64` draw per emitted token, whatever the filter
//! settings. That fixed draw budget is a correctness contract, not a
//! detail: the speculative engine proposes draft tokens from a *clone* of
//! the sequence's RNG (one draw per proposal) and the target verifies by
//! sampling with the real RNG (one draw per emitted token), so clone draw
//! `i` and real draw `i` line up and speculative output is token-identical
//! to the non-speculative path for any seed — the sampling analogue of the
//! greedy "verify must match" argument.
//!
//! The sampled distribution is `softmax(logits / temperature)` restricted
//! to the top-k most probable tokens (0 = unrestricted) intersected with
//! the smallest nucleus whose mass reaches `top_p` (1.0 = unrestricted),
//! renormalized, then inverse-CDF sampled. Candidate order is probability
//! descending with index-ascending tie-break, so the pick is a pure
//! function of `(logits, params, draw)` on every platform.

use super::transformer::greedy_pick;
use crate::rng::Pcg32;

/// Sampling knobs carried per request. `Default` is greedy decoding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleParams {
    /// Softmax temperature; `<= 0` means greedy argmax (no RNG draws).
    pub temperature: f32,
    /// Keep only the `top_k` most probable tokens (0 = no limit).
    pub top_k: usize,
    /// Keep the smallest set of tokens whose probability mass reaches
    /// `top_p` (1.0 = no limit).
    pub top_p: f32,
    /// Seed for the per-request RNG stream; same seed ⇒ same tokens on
    /// every serving path (solo, batched, streamed, session-resumed,
    /// speculative).
    pub seed: u64,
}

impl Default for SampleParams {
    fn default() -> Self {
        SampleParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl SampleParams {
    /// Greedy argmax decoding (the default).
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Whether these params reduce to greedy argmax (no RNG use).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Validate ranges: temperature must be finite and ≥ 0, `top_p` in
    /// (0, 1]. Servers call this at the protocol boundary so a bad knob is
    /// a request error, not a NaN-shaped distribution later.
    pub fn validate(&self) -> Result<(), String> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!("temperature must be finite and >= 0, got {}", self.temperature));
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            return Err(format!("top_p must be in (0, 1], got {}", self.top_p));
        }
        Ok(())
    }
}

/// One sequence's sampling state: the knobs plus the seeded RNG stream.
/// Cloning yields an independent stream at the current position — how the
/// speculative draft proposes tokens without advancing the real stream.
#[derive(Clone, Debug)]
pub struct Sampler {
    params: SampleParams,
    rng: Pcg32,
}

impl Sampler {
    pub fn new(params: SampleParams) -> Self {
        Sampler { params, rng: Pcg32::seeded(params.seed) }
    }

    pub fn params(&self) -> SampleParams {
        self.params
    }

    /// Pick the next token from a logits row. Greedy params call
    /// [`greedy_pick`] and draw nothing; otherwise exactly one RNG draw is
    /// consumed, whatever the filters select.
    pub fn pick(&mut self, row: &[f32]) -> usize {
        if self.params.is_greedy() {
            return greedy_pick(row);
        }
        let u = self.rng.f64();
        sample_from(row, &self.params, u)
    }
}

/// The deterministic sampling core: given a logits row, non-greedy params
/// and a uniform draw `u ∈ [0, 1)`, return the sampled token index.
/// Factored out of [`Sampler::pick`] so tests can sweep `u` directly.
pub fn sample_from(row: &[f32], params: &SampleParams, u: f64) -> usize {
    debug_assert!(!params.is_greedy());
    debug_assert!(!row.is_empty());
    // Temperature-scaled softmax with max-subtraction for stability.
    let inv_t = 1.0 / params.temperature;
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut cand: Vec<(usize, f64)> = row
        .iter()
        .enumerate()
        .map(|(i, &v)| (i, (((v - max) * inv_t) as f64).exp()))
        .collect();
    // Probability descending, index ascending on ties — a total order, so
    // the candidate list (and therefore the pick) is deterministic.
    cand.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if params.top_k > 0 {
        cand.truncate(params.top_k.max(1));
    }
    if params.top_p < 1.0 {
        let total: f64 = cand.iter().map(|&(_, w)| w).sum();
        let mut cum = 0.0;
        let mut keep = cand.len();
        for (j, &(_, w)) in cand.iter().enumerate() {
            cum += w;
            if cum >= params.top_p as f64 * total {
                keep = j + 1;
                break;
            }
        }
        cand.truncate(keep);
    }
    // Inverse-CDF over the renormalized candidates.
    let total: f64 = cand.iter().map(|&(_, w)| w).sum();
    let target = u * total;
    let mut cum = 0.0;
    for &(i, w) in &cand {
        cum += w;
        if cum > target {
            return i;
        }
    }
    cand.last().unwrap().0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_greedy_and_draws_nothing() {
        let p = SampleParams::default();
        assert!(p.is_greedy());
        let mut a = Sampler::new(p);
        let mut b = Sampler::new(p);
        let row = [0.1f32, 2.0, -1.0, 2.0];
        // Greedy pick is the documented lowest-index argmax...
        assert_eq!(a.pick(&row), 1);
        // ...and consumes no RNG: both streams still agree after many picks
        // on a non-greedy re-parameterization of the same state.
        for _ in 0..10 {
            a.pick(&row);
        }
        a.params.temperature = 1.0;
        b.params.temperature = 1.0;
        for _ in 0..5 {
            assert_eq!(a.pick(&row), b.pick(&row));
        }
    }

    #[test]
    fn same_seed_same_stream() {
        let p = SampleParams { temperature: 0.8, top_k: 8, top_p: 0.9, seed: 42 };
        let mut a = Sampler::new(p);
        let mut b = Sampler::new(p);
        let row: Vec<f32> = (0..64).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let sa: Vec<usize> = (0..32).map(|_| a.pick(&row)).collect();
        let sb: Vec<usize> = (0..32).map(|_| b.pick(&row)).collect();
        assert_eq!(sa, sb);
        let mut c = Sampler::new(SampleParams { seed: 43, ..p });
        let sc: Vec<usize> = (0..32).map(|_| c.pick(&row)).collect();
        assert_ne!(sa, sc, "different seeds should diverge on a spread distribution");
    }

    #[test]
    fn clone_matches_original_stream() {
        // The speculative-draft contract: a cloned sampler's draw i equals
        // the original's draw i.
        let p = SampleParams { temperature: 1.3, top_k: 0, top_p: 1.0, seed: 7 };
        let mut real = Sampler::new(p);
        let row: Vec<f32> = (0..32).map(|i| (i % 7) as f32 * 0.5).collect();
        real.pick(&row); // advance past the first token
        let mut clone = real.clone();
        let proposed: Vec<usize> = (0..4).map(|_| clone.pick(&row)).collect();
        let actual: Vec<usize> = (0..4).map(|_| real.pick(&row)).collect();
        assert_eq!(proposed, actual);
    }

    #[test]
    fn top_k_restricts_support() {
        let p = SampleParams { temperature: 1.0, top_k: 2, top_p: 1.0, seed: 0 };
        let row = [5.0f32, 4.0, -50.0, -50.0, 3.9];
        for i in 0..100 {
            let u = i as f64 / 100.0;
            let pick = sample_from(&row, &p, u);
            assert!(pick == 0 || pick == 1, "top-2 must exclude index {pick}");
        }
    }

    #[test]
    fn top_p_keeps_smallest_nucleus() {
        // One dominant token (~99.99% mass): any top_p below that keeps
        // only it.
        let p = SampleParams { temperature: 1.0, top_k: 0, top_p: 0.5, seed: 0 };
        let row = [10.0f32, 0.0, 0.0, 0.0];
        for i in 0..50 {
            assert_eq!(sample_from(&row, &p, i as f64 / 50.0), 0);
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let p = SampleParams { temperature: 1e-4, top_k: 0, top_p: 1.0, seed: 0 };
        let row = [0.5f32, 1.5, 1.0, -0.2];
        for i in 0..20 {
            assert_eq!(sample_from(&row, &p, i as f64 / 20.0), greedy_pick(&row));
        }
    }

    #[test]
    fn inverse_cdf_tracks_probabilities() {
        // Two equally likely tokens: u below 0.5 takes the first (index
        // tie-break puts index 0 first), above takes the second.
        let p = SampleParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 };
        let row = [1.0f32, 1.0, -60.0];
        assert_eq!(sample_from(&row, &p, 0.25), 0);
        assert_eq!(sample_from(&row, &p, 0.75), 1);
        // u → 1 still lands inside the candidate set.
        assert_eq!(sample_from(&row, &p, 0.999_999), 1);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(SampleParams::default().validate().is_ok());
        assert!(SampleParams { temperature: -1.0, ..Default::default() }.validate().is_err());
        assert!(SampleParams { temperature: f32::NAN, ..Default::default() }
            .validate()
            .is_err());
        assert!(SampleParams { top_p: 0.0, ..Default::default() }.validate().is_err());
        assert!(SampleParams { top_p: 1.5, ..Default::default() }.validate().is_err());
    }
}
