//! Blocked multi-head causal attention over a pluggable K/V store — the
//! single attention implementation shared by every forward path.
//!
//! Before this module existed, `forward_iq` (full forward) and
//! `forward_slots` (continuous-batching serving) each carried their own
//! scalar per-(head, position) score/value loops. Both now route through
//! [`attend`], which restructures the computation into blocked matmuls:
//!
//! * per (sequence span, head), the query tile `Q` (span × dh) is multiplied
//!   against a contiguous K stripe via the `tensor::ops` A·Bᵀ kernel, rows
//!   beyond the causal frontier are masked to −∞, each row is softmaxed, and
//!   the probability tile is multiplied against the V stripe with the
//!   `tensor::ops` A·B kernel;
//! * the (span, head) work items are partitioned across `std::thread::scope`
//!   workers balanced by multiply-add cost — the same threading idiom as the
//!   packed kernels in `kernels::parallel_columns` — so decode batches
//!   parallelize over sequences×heads and long prefills over heads.
//!
//! The blocked f32 path is *bit-exact* against the scalar reference
//! ([`attend_reference`], kept only for parity tests and the
//! `benches/decode.rs` blocking on/off comparison): the slice kernels
//! accumulate in the same order the scalar loops did, and masked positions
//! contribute exact zeros that the A·B kernel skips.
//!
//! Behind the attention kernel sits [`KvSlab`], the pluggable cache storage:
//! K/V rows are laid out head-major (each (slot, head) owns a contiguous
//! `max_seq × dh` stripe, so score/value tiles read contiguous memory) and
//! are stored in one of three dtypes ([`KvDtype`]):
//!
//! * `F32` — full precision, zero-copy stripe borrows;
//! * `Int8` — symmetric AbsMax int8 with one scale per (row, head), built on
//!   the `quant` AbsMax machinery (`quant::quant_code`); ~4× fewer cache
//!   bytes than f32;
//! * `Fp8E4M3` — FP8 E4M3 bytes (`quant::fp8::e4m3_to_bits`), 4× fewer
//!   bytes, no scale storage.
//!
//! Quantized rows are encoded once on [`KvSlab::write`] and dequantized
//! stripe-block-wise inside the attention kernel — decode-time cache
//! traffic, the dominant cost of serving long contexts, drops ~4×
//! (SqueezeLLM, arxiv 2306.07629, shows generation is memory-bandwidth
//! bound; the paper's input-quantization appendix supplies the formats).

use crate::quant::fp8::{e4m3_from_bits, e4m3_to_bits};
use crate::quant::quant_code;
use crate::tensor::{gemm, gemm_abt, num_threads, Matrix, PAR_THRESHOLD};

/// Storage dtype for cached K/V rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// f32 rows (bit-exact with the uncached forward).
    #[default]
    F32,
    /// Symmetric AbsMax int8 codes + one f32 scale per (row, head).
    Int8,
    /// FP8 E4M3 bytes (no scales).
    Fp8E4M3,
}

impl KvDtype {
    /// Parse from a CLI / config string.
    pub fn parse(s: &str) -> Option<KvDtype> {
        Some(match s {
            "f32" | "fp32" => KvDtype::F32,
            "int8" => KvDtype::Int8,
            "fp8" | "fp8-e4m3" => KvDtype::Fp8E4M3,
            _ => return None,
        })
    }

    /// Display / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
            KvDtype::Fp8E4M3 => "fp8-e4m3",
        }
    }
}

/// One layer's K (or V) cache storage: `slots` sequence slots of `max_seq`
/// positions each, laid out head-major — `stripe(slot, head)` is a
/// contiguous `max_seq × dh` block, which is what lets the attention tiles
/// run as blocked matmuls over (and, for f32, borrow directly from) cache
/// memory. Rows are quantized on [`KvSlab::write`] per the slab's
/// [`KvDtype`] and dequantized block-wise by the attention kernel.
pub struct KvSlab {
    dtype: KvDtype,
    slots: usize,
    max_seq: usize,
    n_heads: usize,
    dh: usize,
    /// F32 storage (empty for quantized dtypes).
    f32s: Vec<f32>,
    /// Int8 codes (as raw bytes) or FP8 E4M3 bytes, same head-major layout.
    codes: Vec<u8>,
    /// Int8 AbsMax scales, one per (slot·position, head).
    scales: Vec<f32>,
}

impl KvSlab {
    /// Zeroed slab for `slots` sequences of up to `max_seq` positions of
    /// `n_heads × dh` values each.
    pub fn new(dtype: KvDtype, slots: usize, max_seq: usize, n_heads: usize, dh: usize) -> Self {
        let elems = slots * max_seq * n_heads * dh;
        let (f32s, codes, scales) = match dtype {
            KvDtype::F32 => (vec![0.0; elems], Vec::new(), Vec::new()),
            KvDtype::Int8 => (Vec::new(), vec![0u8; elems], vec![0.0; slots * max_seq * n_heads]),
            KvDtype::Fp8E4M3 => (Vec::new(), vec![0u8; elems], Vec::new()),
        };
        KvSlab { dtype, slots, max_seq, n_heads, dh, f32s, codes, scales }
    }

    /// Storage dtype.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Bytes of cache storage held (codes + scales) — the traffic model the
    /// decode bench reports.
    pub fn bytes(&self) -> usize {
        self.f32s.len() * 4 + self.codes.len() + self.scales.len() * 4
    }

    #[inline]
    fn stripe_base(&self, slot: usize, head: usize) -> usize {
        (slot * self.n_heads + head) * self.max_seq * self.dh
    }

    /// Encode one position's row (`n_heads·dh` f32 values, head-major like
    /// the model's hidden dim) into the slab at (`slot`, `pos`).
    pub fn write(&mut self, slot: usize, pos: usize, row: &[f32]) {
        assert_eq!(row.len(), self.n_heads * self.dh, "kv row width mismatch");
        assert!(slot < self.slots && pos < self.max_seq, "kv write out of range");
        let dh = self.dh;
        for h in 0..self.n_heads {
            let seg = &row[h * dh..(h + 1) * dh];
            let base = self.stripe_base(slot, h) + pos * dh;
            match self.dtype {
                KvDtype::F32 => self.f32s[base..base + dh].copy_from_slice(seg),
                KvDtype::Int8 => {
                    let alpha = seg.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    self.scales[(slot * self.max_seq + pos) * self.n_heads + h] = alpha;
                    for (dst, &x) in self.codes[base..base + dh].iter_mut().zip(seg.iter()) {
                        *dst = quant_code(x, alpha, 8) as u8;
                    }
                }
                KvDtype::Fp8E4M3 => {
                    for (dst, &x) in self.codes[base..base + dh].iter_mut().zip(seg.iter()) {
                        *dst = e4m3_to_bits(x);
                    }
                }
            }
        }
    }

    /// The first `len` rows of the (`slot`, `head`) stripe as a contiguous
    /// `len × dh` f32 tile: a zero-copy borrow for f32 slabs, a block
    /// dequantization into `scratch` otherwise.
    pub(crate) fn tile<'a>(
        &'a self,
        slot: usize,
        head: usize,
        len: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(len <= self.max_seq);
        let base = self.stripe_base(slot, head);
        let dh = self.dh;
        match self.dtype {
            KvDtype::F32 => &self.f32s[base..base + len * dh],
            KvDtype::Int8 => {
                scratch.resize(len * dh, 0.0);
                for (t, dst) in scratch.chunks_exact_mut(dh).enumerate() {
                    let alpha = self.scales[(slot * self.max_seq + t) * self.n_heads + head];
                    let dq = alpha / 127.0;
                    let src = &self.codes[base + t * dh..base + (t + 1) * dh];
                    for (o, &c) in dst.iter_mut().zip(src.iter()) {
                        *o = (c as i8) as f32 * dq;
                    }
                }
                &scratch[..len * dh]
            }
            KvDtype::Fp8E4M3 => {
                scratch.resize(len * dh, 0.0);
                for (o, &b) in scratch.iter_mut().zip(self.codes[base..base + len * dh].iter()) {
                    *o = e4m3_from_bits(b);
                }
                &scratch[..len * dh]
            }
        }
    }
}

/// One sequence's attention work in a packed batch: `span` new query rows
/// starting at row `q_base` of the packed q/ctx matrices, attending over
/// `p0` already-stored K/V positions plus its own `span` fresh ones
/// (query row `s` sees K/V positions `0..=p0+s`).
#[derive(Clone, Copy, Debug)]
pub struct AttnSpan {
    /// First row of this span in the packed q/ctx matrices.
    pub q_base: usize,
    /// Number of new (query) positions.
    pub span: usize,
    /// K/V positions already stored before this span's rows.
    pub p0: usize,
    /// K/V addressing: the slot index for [`KvSource::Pool`], the row base
    /// in the fresh K/V matrices for [`KvSource::Fresh`].
    pub kv: usize,
}

/// Where a span's K/V rows live.
pub enum KvSource<'a> {
    /// Freshly projected K/V matrices, `d_model` wide, the span's positions
    /// `0..p0+span` at rows `kv..kv+p0+span` (the full-forward path; `p0`
    /// is 0 there).
    Fresh { k: &'a Matrix, v: &'a Matrix },
    /// Slot-striped cache slabs (the serving path); the span's positions
    /// live in slot `kv`, already written for `0..p0+span`.
    Pool { k: &'a KvSlab, v: &'a KvSlab },
}

/// In-place numerically-stable softmax over a slice (−∞ entries come out
/// as exact zeros).
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Reusable per-worker tile scratch.
#[derive(Default)]
struct Scratch {
    qt: Vec<f32>,
    kt: Vec<f32>,
    vt: Vec<f32>,
    sc: Vec<f32>,
}

/// Copy a strided head-column block (`len` rows × `dh` cols at column `c0`)
/// of a d_model-wide matrix into a contiguous tile.
fn fill_cols(m: &Matrix, row0: usize, len: usize, c0: usize, dh: usize, out: &mut Vec<f32>) {
    out.clear();
    for t in 0..len {
        out.extend_from_slice(&m.row(row0 + t)[c0..c0 + dh]);
    }
}

/// Compute one (span, head) context tile (`span × dh`, zero-initialized)
/// via blocked Q·Kᵀ → mask → softmax → P·V.
#[allow(clippy::too_many_arguments)]
fn run_item(
    sp: &AttnSpan,
    head: usize,
    dh: usize,
    scale: f32,
    q: &Matrix,
    kv: &KvSource,
    s: &mut Scratch,
    out: &mut [f32],
) {
    let span = sp.span;
    let kvlen = sp.p0 + span;
    let c0 = head * dh;
    // Q tile: span × dh.
    s.qt.clear();
    for r in 0..span {
        s.qt.extend_from_slice(&q.row(sp.q_base + r)[c0..c0 + dh]);
    }
    let (kt, vt): (&[f32], &[f32]) = match kv {
        KvSource::Fresh { k, v } => {
            fill_cols(k, sp.kv, kvlen, c0, dh, &mut s.kt);
            fill_cols(v, sp.kv, kvlen, c0, dh, &mut s.vt);
            (&s.kt, &s.vt)
        }
        KvSource::Pool { k, v } => (
            k.tile(sp.kv, head, kvlen, &mut s.kt),
            v.tile(sp.kv, head, kvlen, &mut s.vt),
        ),
    };
    // Scores: span × kvlen blocked Q·Kᵀ, then causal mask + row softmax.
    s.sc.resize(span * kvlen, 0.0);
    gemm_abt(&s.qt, kt, span, dh, kvlen, &mut s.sc);
    for (r, row) in s.sc.chunks_exact_mut(kvlen).enumerate() {
        for v2 in row.iter_mut() {
            *v2 *= scale;
        }
        for v2 in row[sp.p0 + r + 1..].iter_mut() {
            *v2 = f32::NEG_INFINITY;
        }
        softmax_inplace(row);
    }
    // Context tile: span × dh blocked P·V (masked positions have exact-zero
    // probability and are skipped by the kernel).
    gemm(&s.sc, vt, span, kvlen, dh, out);
}

/// Blocked multi-head causal attention: for every [`AttnSpan`], compute its
/// context rows from `q` (packed `Σspan × n_heads·dh`) against `kv`, and
/// return them packed in the same layout as `q`.
///
/// Work is one item per (span, head); items are partitioned across
/// `std::thread::scope` workers balanced by multiply-add cost (serial below
/// the same threshold the dense matmul and packed kernels use). Results are
/// identical regardless of threading: each item is computed independently
/// into its own tile, and the f32 path reproduces the scalar reference
/// ([`attend_reference`]) bit-for-bit.
pub fn attend(
    n_heads: usize,
    dh: usize,
    scale: f32,
    spans: &[AttnSpan],
    q: &Matrix,
    kv: &KvSource,
) -> Matrix {
    let d = n_heads * dh;
    assert_eq!(q.cols(), d, "q width {} != n_heads·dh {}", q.cols(), d);
    let mut ctx = Matrix::zeros(q.rows(), d);
    if spans.is_empty() {
        return ctx;
    }
    // One work item per (span, head), costed in multiply-adds.
    let mut items: Vec<(usize, usize)> = Vec::with_capacity(spans.len() * n_heads);
    let mut total_cost = 0usize;
    for (si, sp) in spans.iter().enumerate() {
        for h in 0..n_heads {
            items.push((si, h));
        }
        total_cost += n_heads * 2 * sp.span * (sp.p0 + sp.span) * dh;
    }
    let item_cost = |&(si, _): &(usize, usize)| {
        let sp = &spans[si];
        2 * sp.span * (sp.p0 + sp.span) * dh
    };
    let nt = if total_cost < PAR_THRESHOLD { 1 } else { num_threads().min(items.len()) };

    // Contiguous item runs of ≈ equal cost. One shared buffer holds every
    // item's tile (item-major); each run fills its own buffer segment —
    // serially for one run, across `std::thread::scope` workers otherwise —
    // and the tiles are stitched into ctx afterwards (an O(n·d) copy,
    // negligible next to the O(n·kvlen·dh) attention math).
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nt + 1);
    let target = total_cost.div_ceil(nt);
    let mut start = 0usize;
    let mut acc = 0usize;
    for (i, it) in items.iter().enumerate() {
        acc += item_cost(it);
        if acc >= target || i + 1 == items.len() {
            ranges.push((start, i + 1));
            start = i + 1;
            acc = 0;
        }
    }
    let tile_elems = |its: &[(usize, usize)]| -> usize {
        its.iter().map(|&(si, _)| spans[si].span * dh).sum()
    };
    let run_range = |i0: usize, i1: usize, out: &mut [f32]| {
        let mut s = Scratch::default();
        let mut off = 0usize;
        for &(si, h) in &items[i0..i1] {
            let sp = &spans[si];
            let len = sp.span * dh;
            run_item(sp, h, dh, scale, q, kv, &mut s, &mut out[off..off + len]);
            off += len;
        }
    };
    let mut buf = vec![0.0f32; tile_elems(&items)];
    if nt <= 1 {
        run_range(0, items.len(), buf.as_mut_slice());
    } else {
        std::thread::scope(|scope| {
            let run_range = &run_range;
            let mut rest = buf.as_mut_slice();
            for &(i0, i1) in &ranges {
                let (head_buf, tail) = rest.split_at_mut(tile_elems(&items[i0..i1]));
                rest = tail;
                scope.spawn(move || run_range(i0, i1, head_buf));
            }
        });
    }
    let mut off = 0usize;
    for &(si, h) in &items {
        let sp = &spans[si];
        let c0 = h * dh;
        for (r, trow) in buf[off..off + sp.span * dh].chunks_exact(dh).enumerate() {
            ctx.row_mut(sp.q_base + r)[c0..c0 + dh].copy_from_slice(trow);
        }
        off += sp.span * dh;
    }
    ctx
}

/// Scalar reference attention: the per-(head, position) dot-product loops
/// the forwards used before the blocked kernel. Kept ONLY as the parity
/// baseline for tests and the `benches/decode.rs` blocking on/off
/// measurement — no forward path calls this.
pub fn attend_reference(
    n_heads: usize,
    dh: usize,
    scale: f32,
    spans: &[AttnSpan],
    q: &Matrix,
    kv: &KvSource,
) -> Matrix {
    let d = n_heads * dh;
    assert_eq!(q.cols(), d);
    let mut ctx = Matrix::zeros(q.rows(), d);
    let mut kt_s: Vec<f32> = Vec::new();
    let mut vt_s: Vec<f32> = Vec::new();
    for sp in spans {
        let kvlen = sp.p0 + sp.span;
        for h in 0..n_heads {
            let c0 = h * dh;
            let (kt, vt): (&[f32], &[f32]) = match kv {
                KvSource::Fresh { k, v } => {
                    fill_cols(k, sp.kv, kvlen, c0, dh, &mut kt_s);
                    fill_cols(v, sp.kv, kvlen, c0, dh, &mut vt_s);
                    (&kt_s, &vt_s)
                }
                KvSource::Pool { k, v } => (
                    k.tile(sp.kv, h, kvlen, &mut kt_s),
                    v.tile(sp.kv, h, kvlen, &mut vt_s),
                ),
            };
            for r in 0..sp.span {
                let gp = sp.p0 + r;
                let qrow = &q.row(sp.q_base + r)[c0..c0 + dh];
                let mut scores = vec![0.0f32; gp + 1];
                for (t, sc) in scores.iter_mut().enumerate() {
                    let krow = &kt[t * dh..(t + 1) * dh];
                    let mut dot = 0.0f32;
                    for (a, b2) in qrow.iter().zip(krow.iter()) {
                        dot += a * b2;
                    }
                    *sc = dot * scale;
                }
                softmax_inplace(&mut scores);
                let crow = ctx.row_mut(sp.q_base + r);
                for (t, &pr) in scores.iter().enumerate() {
                    let vrow = &vt[t * dh..(t + 1) * dh];
                    for (cv, &vv) in crow[c0..c0 + dh].iter_mut().zip(vrow.iter()) {
                        *cv += pr * vv;
                    }
                }
            }
        }
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Random slab pair + matching f32 rows for `slots` sequences at the
    /// given depths.
    fn filled_slabs(
        dtype: KvDtype,
        depths: &[usize],
        max_seq: usize,
        n_heads: usize,
        dh: usize,
        rng: &mut Pcg32,
    ) -> (KvSlab, KvSlab) {
        let d = n_heads * dh;
        let mut ks = KvSlab::new(dtype, depths.len(), max_seq, n_heads, dh);
        let mut vs = KvSlab::new(dtype, depths.len(), max_seq, n_heads, dh);
        for (slot, &depth) in depths.iter().enumerate() {
            for pos in 0..depth {
                let krow: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
                let vrow: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
                ks.write(slot, pos, &krow);
                vs.write(slot, pos, &vrow);
            }
        }
        (ks, vs)
    }

    #[test]
    fn blocked_matches_scalar_reference_exactly_fresh() {
        // Full-forward shape: mixed batch, span == kvlen, p0 == 0. The f32
        // blocked path must be bit-identical to the scalar loops.
        let mut rng = Pcg32::seeded(1);
        let (n_heads, dh, seq, batch) = (4usize, 8usize, 13usize, 3usize);
        let d = n_heads * dh;
        let n = batch * seq;
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let spans: Vec<AttnSpan> = (0..batch)
            .map(|b| AttnSpan { q_base: b * seq, span: seq, p0: 0, kv: b * seq })
            .collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Fresh { k: &k, v: &v };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn blocked_matches_scalar_reference_exactly_pool() {
        // Serving shape: mixed spans (a prefill batched with decode steps)
        // over cached prefixes of different depths.
        let mut rng = Pcg32::seeded(2);
        let (n_heads, dh, max_seq) = (2usize, 16usize, 32usize);
        let d = n_heads * dh;
        // slot depths INCLUDE the fresh span rows (already written).
        let depths = [9usize, 20, 1];
        let spans = [
            AttnSpan { q_base: 0, span: 4, p0: 5, kv: 0 }, // mid-decode burst
            AttnSpan { q_base: 4, span: 1, p0: 19, kv: 1 }, // one-token decode
            AttnSpan { q_base: 5, span: 1, p0: 0, kv: 2 },  // fresh prefill
        ];
        let (ks, vs) = filled_slabs(KvDtype::F32, &depths, max_seq, n_heads, dh, &mut rng);
        let q = Matrix::randn(6, d, 1.0, &mut rng);
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Pool { k: &ks, v: &vs };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn threaded_path_matches_serial_exactly() {
        // Big enough to cross PAR_THRESHOLD so attend() takes the
        // scope-spawn path; the reference is fully serial.
        let mut rng = Pcg32::seeded(3);
        let (n_heads, dh, depth, batch) = (4usize, 64usize, 128usize, 4usize);
        let d = n_heads * dh;
        let depths: Vec<usize> = (0..batch).map(|_| depth).collect();
        let (ks, vs) = filled_slabs(KvDtype::F32, &depths, depth, n_heads, dh, &mut rng);
        let q = Matrix::randn(batch, d, 1.0, &mut rng);
        let spans: Vec<AttnSpan> = (0..batch)
            .map(|b| AttnSpan { q_base: b, span: 1, p0: depth - 1, kv: b })
            .collect();
        let total_cost: usize = spans.iter().map(|sp| n_heads * 2 * (sp.p0 + 1) * dh).sum();
        assert!(total_cost >= crate::tensor::PAR_THRESHOLD, "test must cross the threshold");
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Pool { k: &ks, v: &vs };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn int8_slab_small_error_and_4x_fewer_bytes() {
        let mut rng = Pcg32::seeded(4);
        let (n_heads, dh, max_seq) = (4usize, 32usize, 16usize);
        let d = n_heads * dh;
        let mut f32s = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
        let mut int8 = KvSlab::new(KvDtype::Int8, 1, max_seq, n_heads, dh);
        let mut fp8 = KvSlab::new(KvDtype::Fp8E4M3, 1, max_seq, n_heads, dh);
        for pos in 0..max_seq {
            let row: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
            f32s.write(0, pos, &row);
            int8.write(0, pos, &row);
            fp8.write(0, pos, &row);
        }
        let mut sf = Vec::new();
        let mut s8 = Vec::new();
        let mut se = Vec::new();
        for h in 0..n_heads {
            let exact = f32s.tile(0, h, max_seq, &mut sf).to_vec();
            let i8t = int8.tile(0, h, max_seq, &mut s8);
            let f8t = fp8.tile(0, h, max_seq, &mut se);
            let norm: f32 = exact.iter().map(|x| x * x).sum::<f32>().sqrt();
            let err8: f32 =
                exact.iter().zip(i8t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            let errf: f32 =
                exact.iter().zip(f8t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            assert!(err8 / norm < 0.01, "int8 head {h}: rel err {}", err8 / norm);
            assert!(errf / norm < 0.05, "fp8 head {h}: rel err {}", errf / norm);
        }
        // ~4× fewer cache bytes (int8 pays a small per-(row, head) scale).
        assert!(f32s.bytes() as f64 / int8.bytes() as f64 > 3.5, "int8 ratio");
        assert_eq!(f32s.bytes(), 4 * fp8.bytes());
    }

    #[test]
    fn quantized_pool_attention_close_to_f32() {
        let mut rng = Pcg32::seeded(5);
        let (n_heads, dh, depth) = (2usize, 16usize, 24usize);
        let d = n_heads * dh;
        // Same rows into an f32 and an int8 slab (clone the rng stream).
        let mut rng2 = Pcg32::seeded(5);
        let (kf, vf) = filled_slabs(KvDtype::F32, &[depth], depth, n_heads, dh, &mut rng);
        let (k8, v8) = filled_slabs(KvDtype::Int8, &[depth], depth, n_heads, dh, &mut rng2);
        let q = Matrix::randn(2, d, 1.0, &mut rng);
        let spans = [AttnSpan { q_base: 0, span: 2, p0: depth - 2, kv: 0 }];
        let scale = 1.0 / (dh as f32).sqrt();
        let exact = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &kf, v: &vf });
        let approx = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &k8, v: &v8 });
        assert!(approx.rel_err(&exact) < 0.02, "int8 attn err {}", approx.rel_err(&exact));
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(KvDtype::parse("f32"), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("int8"), Some(KvDtype::Int8));
        assert_eq!(KvDtype::parse("fp8"), Some(KvDtype::Fp8E4M3));
        assert_eq!(KvDtype::parse("bf16"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 1e4];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[3] > 0.99);
        // Masked (−∞) entries come out as exact zeros.
        let mut ys = vec![0.5f32, f32::NEG_INFINITY, 1.0];
        softmax_inplace(&mut ys);
        assert_eq!(ys[1], 0.0);
    }
}
