//! Blocked multi-head causal attention over a pluggable K/V store — the
//! single attention implementation shared by every forward path.
//!
//! Before this module existed, `forward_iq` (full forward) and
//! `forward_slots` (continuous-batching serving) each carried their own
//! scalar per-(head, position) score/value loops. Both now route through
//! [`attend`], which restructures the computation into blocked matmuls:
//!
//! * per (sequence span, head), the query tile `Q` (span × dh) is multiplied
//!   against a contiguous K stripe via the `tensor::ops` A·Bᵀ kernel, rows
//!   beyond the causal frontier are masked to −∞, each row is softmaxed, and
//!   the probability tile is multiplied against the V stripe with the
//!   `tensor::ops` A·B kernel;
//! * the (span, head) work items are partitioned across `std::thread::scope`
//!   workers balanced by multiply-add cost — the same threading idiom as the
//!   packed kernels in `kernels::parallel_columns` — so decode batches
//!   parallelize over sequences×heads and long prefills over heads.
//!
//! The blocked f32 path is *bit-exact* against the scalar reference
//! ([`attend_reference`], kept only for parity tests and the
//! `benches/decode.rs` blocking on/off comparison): the slice kernels
//! accumulate in the same order the scalar loops did, and masked positions
//! contribute exact zeros that the A·B kernel skips.
//!
//! Behind the attention kernel sits [`KvSlab`], the pluggable cache storage:
//! K/V rows are laid out head-major (each (slot, head) owns a contiguous
//! `max_seq × dh` stripe, so score/value tiles read contiguous memory) and
//! are stored in one of five dtypes ([`KvDtype`]):
//!
//! * `F32` — full precision, zero-copy stripe borrows;
//! * `F16` / `Bf16` — half-precision 16-bit codes (`quant::half`), 2× fewer
//!   cache bytes at near-f32 fidelity. Unwrapped windows skip the f32
//!   scratch entirely: the score and value tiles run on the half-operand
//!   GEMMs (`tensor::ops::{gemm_abt_half, gemm_half}`), which decode inline
//!   and accumulate in f32 — bit-identical to dequantize-then-f32-GEMM,
//!   without the materialization traffic;
//! * `Int8` — symmetric AbsMax int8 with one scale per (row, head), built on
//!   the `quant` AbsMax machinery (`quant::quant_code`); ~4× fewer cache
//!   bytes than f32;
//! * `Fp8E4M3` — FP8 E4M3 bytes (`quant::fp8::e4m3_to_bits`), 4× fewer
//!   bytes, no scale storage.
//!
//! Quantized rows are encoded once on [`KvSlab::write`] and dequantized
//! stripe-block-wise inside the attention kernel — decode-time cache
//! traffic, the dominant cost of serving long contexts, drops 2–4×
//! (SqueezeLLM, arxiv 2306.07629, shows generation is memory-bandwidth
//! bound; the paper's input-quantization appendix supplies the formats).
//!
//! Long prefill spans are split into query tiles of at most
//! `kernels::TILES.attn_tile()` rows before work partitioning (query rows
//! are independent — per-row softmax, row-independent GEMMs — so the split
//! is bit-exact for every tile size); the tile size is picked by the
//! one-shot autotuner (`kernels::tune`), with the `usize::MAX` default
//! reproducing the unsplit behavior.
//!
//! ## Ring addressing (logical vs physical positions)
//!
//! Each (slot, head) stripe is treated as a **ring buffer** over `max_seq`
//! physical rows: the row for *logical* position `L` (the token's index in
//! the sequence, unbounded) lives at physical row `L % max_seq`
//! ([`KvSlab::write_logical`] with [`KvLayout::Ring`]), so a write past the
//! context length overwrites the oldest retained position in O(1) instead
//! of forcing the engine to re-prefill a sliding window. The attention
//! kernel reads the retained window back **in logical order** through
//! [`KvSlab::tile`]: the window starts at physical row [`AttnSpan::start`]
//! and is materialized as at most two contiguous arcs
//! (`[start..max_seq)` then `[0..start)`), so the slice GEMMs always see
//! one contiguous logically-ordered tile. Unwrapped f32 windows stay
//! zero-copy borrows; wrapped or quantized windows are copied/dequantized
//! into the per-worker scratch (int8 scales are indexed by physical row,
//! so they wrap with their rows automatically).
//!
//! [`KvLayout::Shift`] is the slow reference layout for the same
//! sliding-window semantics: instead of wrapping, an overflow write
//! memmoves every retained row (and its scales) down by one and appends at
//! the last physical row — O(window) per token, but the stored bytes equal
//! the ring's logical window exactly, which is what the ring/shift
//! greedy-equivalence tests assert.

use crate::quant::fp8::{e4m3_from_bits, e4m3_to_bits};
use crate::quant::half::{encode_slice, HalfKind};
use crate::quant::quant_code;
use crate::tensor::{gemm, gemm_abt, gemm_abt_half, gemm_half, num_threads, Matrix, PAR_THRESHOLD};

/// The dtype names [`KvDtype::parse`] accepts, for error messages and docs.
pub const KV_DTYPE_NAMES: &str = "f32, fp32, f16, fp16, bf16, int8, fp8, fp8-e4m3";

/// Storage dtype for cached K/V rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// f32 rows (bit-exact with the uncached forward).
    #[default]
    F32,
    /// IEEE binary16 codes — 2× fewer bytes, near-f32 fidelity.
    F16,
    /// bfloat16 codes — 2× fewer bytes, f32's exponent range.
    Bf16,
    /// Symmetric AbsMax int8 codes + one f32 scale per (row, head).
    Int8,
    /// FP8 E4M3 bytes (no scales).
    Fp8E4M3,
}

impl KvDtype {
    /// Parse from a CLI / config string. Unknown names are a hard error
    /// listing the accepted spellings ([`KV_DTYPE_NAMES`]) — a typo'd
    /// dtype must never silently fall back to another store.
    pub fn parse(s: &str) -> Result<KvDtype, String> {
        match s {
            "f32" | "fp32" => Ok(KvDtype::F32),
            "f16" | "fp16" => Ok(KvDtype::F16),
            "bf16" => Ok(KvDtype::Bf16),
            "int8" => Ok(KvDtype::Int8),
            "fp8" | "fp8-e4m3" => Ok(KvDtype::Fp8E4M3),
            _ => Err(format!("unknown kv dtype {s:?} (valid: {KV_DTYPE_NAMES})")),
        }
    }

    /// Display / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Bf16 => "bf16",
            KvDtype::Int8 => "int8",
            KvDtype::Fp8E4M3 => "fp8-e4m3",
        }
    }

    /// The half codec backing this dtype (None for f32 / byte-coded).
    pub fn half_kind(&self) -> Option<HalfKind> {
        match self {
            KvDtype::F16 => Some(HalfKind::F16),
            KvDtype::Bf16 => Some(HalfKind::Bf16),
            _ => None,
        }
    }
}

/// Eviction layout of a KV cache slot once a sequence outgrows `max_seq`.
///
/// Both layouts implement the same sliding-window semantics — the cache
/// retains the most recent `max_seq` positions, stored rows are never
/// recomputed — and produce bit-identical attention inputs; they differ
/// only in where the retained rows physically live.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvLayout {
    /// Ring buffer: logical position `L` lives at physical row
    /// `L % max_seq`; an overflow write is one O(1) overwrite of the
    /// oldest row and the window is read back as two contiguous arcs.
    /// The serving default.
    #[default]
    Ring,
    /// Shift buffer: an overflow write memmoves every retained row (and
    /// its scales) down by one, then appends at row `max_seq - 1` —
    /// O(window) per token. Kept as the obviously-correct legacy
    /// sliding-window reference for equivalence tests and benches.
    Shift,
}

impl KvLayout {
    /// Display / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            KvLayout::Ring => "ring",
            KvLayout::Shift => "shift",
        }
    }
}

/// Rows per KV cache page: the allocation granule of the paged cache.
/// Each (head, frame) pair is a contiguous `PAGE_ROWS × dh` block, so a
/// page is the unit of sharing (prefix cache), refcounting and
/// copy-on-write in `model::KvCachePool`.
pub const PAGE_ROWS: usize = 16;

/// Effective page size for a context of `max_seq` rows — a page never
/// exceeds the context, so tiny test configs get single-page slots.
pub fn page_rows_for(max_seq: usize) -> usize {
    PAGE_ROWS.min(max_seq).max(1)
}

/// Page-table sentinel: logical page backed by no physical frame yet.
pub(crate) const UNMAPPED: u32 = u32::MAX;

/// One layer's K (or V) cache storage, at **page** granularity: a pool of
/// `n_frames` physical page frames of [`page_rows_for`]`(max_seq)` rows
/// each, addressed per slot through a page table (`pps = ⌈max_seq/page⌉`
/// entries per slot). Storage is (head, frame, row)-major — for one head,
/// consecutive frames are contiguous `page × dh` blocks — so a window
/// whose frames were allocated consecutively reads back as ONE contiguous
/// stripe, preserving the zero-copy f32 borrow and the half fast path of
/// the old slot-striped layout; shared / fragmented windows degrade to a
/// per-page gather into scratch. Rows are quantized on [`KvSlab::write`]
/// per the slab's [`KvDtype`] and dequantized block-wise by the attention
/// kernel. Positions past `max_seq` are addressed through
/// [`KvSlab::write_logical`] per a [`KvLayout`] (ring wrap or reference
/// shift).
///
/// The slab's page table mirrors the authoritative one in
/// `model::KvCachePool` (which owns refcounts and copy-on-write); the
/// standalone constructor [`KvSlab::new`] installs an identity mapping
/// (frame `slot·pps + i` backs logical page `i` of `slot`), reproducing
/// the old slot-striped behavior exactly.
pub struct KvSlab {
    dtype: KvDtype,
    max_seq: usize,
    n_heads: usize,
    dh: usize,
    /// Rows per page frame.
    page: usize,
    /// Page-table entries per slot (`⌈max_seq/page⌉`).
    pps: usize,
    /// Physical page frames in storage.
    n_frames: usize,
    /// Per-slot page tables: entry `slot·pps + i` maps logical page `i`
    /// to a frame index, or [`UNMAPPED`].
    tables: Vec<u32>,
    /// F32 storage (empty for quantized dtypes).
    f32s: Vec<f32>,
    /// f16 / bf16 codes, same layout (empty otherwise).
    halfs: Vec<u16>,
    /// Int8 codes (as raw bytes) or FP8 E4M3 bytes, same layout.
    codes: Vec<u8>,
    /// Int8 AbsMax scales, one per (frame·row, head).
    scales: Vec<f32>,
}

impl KvSlab {
    /// Zeroed slab for `slots` sequences of up to `max_seq` positions of
    /// `n_heads × dh` values each, with an identity page mapping (one
    /// private frame run per slot — the unpaged reference behavior).
    pub fn new(dtype: KvDtype, slots: usize, max_seq: usize, n_heads: usize, dh: usize) -> Self {
        let pps = max_seq.div_ceil(page_rows_for(max_seq));
        let mut slab = Self::paged(dtype, slots, max_seq, n_heads, dh, slots * pps);
        for (e, t) in slab.tables.iter_mut().enumerate() {
            *t = e as u32;
        }
        slab
    }

    /// Zeroed slab with `n_frames` physical frames and every page table
    /// entry unmapped — the pool constructor; `model::KvCachePool` maps
    /// pages explicitly as sequences allocate, share and copy-on-write.
    pub fn paged(
        dtype: KvDtype,
        slots: usize,
        max_seq: usize,
        n_heads: usize,
        dh: usize,
        n_frames: usize,
    ) -> Self {
        let page = page_rows_for(max_seq);
        let pps = max_seq.div_ceil(page);
        let elems = n_frames * page * n_heads * dh;
        let (f32s, halfs, codes, scales) = match dtype {
            KvDtype::F32 => (vec![0.0; elems], Vec::new(), Vec::new(), Vec::new()),
            KvDtype::F16 | KvDtype::Bf16 => (Vec::new(), vec![0u16; elems], Vec::new(), Vec::new()),
            KvDtype::Int8 => (
                Vec::new(),
                Vec::new(),
                vec![0u8; elems],
                vec![0.0; n_frames * page * n_heads],
            ),
            KvDtype::Fp8E4M3 => (Vec::new(), Vec::new(), vec![0u8; elems], Vec::new()),
        };
        KvSlab {
            dtype,
            max_seq,
            n_heads,
            dh,
            page,
            pps,
            n_frames,
            tables: vec![UNMAPPED; slots * pps],
            f32s,
            halfs,
            codes,
            scales,
        }
    }

    /// Storage dtype.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// The half codec backing this slab (None unless dtype is F16/Bf16).
    pub fn half_kind(&self) -> Option<HalfKind> {
        self.dtype.half_kind()
    }

    /// Bytes of cache storage held (codes + scales) — the traffic model the
    /// decode bench reports.
    pub fn bytes(&self) -> usize {
        self.f32s.len() * 4 + self.halfs.len() * 2 + self.codes.len() + self.scales.len() * 4
    }

    /// Number of slots addressed by the page tables.
    #[inline]
    fn slots(&self) -> usize {
        self.tables.len() / self.pps
    }

    /// Rows per page frame.
    pub fn page_rows(&self) -> usize {
        self.page
    }

    /// Page-table entries per slot.
    pub fn pages_per_slot(&self) -> usize {
        self.pps
    }

    /// Map logical page `idx` of `slot` to physical frame `frame`. Called
    /// by `model::KvCachePool` (the refcount owner) to mirror its
    /// authoritative table into this slab.
    pub fn set_page(&mut self, slot: usize, idx: usize, frame: u32) {
        debug_assert!((frame as usize) < self.n_frames, "kv frame out of range");
        self.tables[slot * self.pps + idx] = frame;
    }

    /// Unmap logical page `idx` of `slot`.
    pub fn clear_page(&mut self, slot: usize, idx: usize) {
        self.tables[slot * self.pps + idx] = UNMAPPED;
    }

    /// Copy frame `src`'s rows (all heads, plus int8 scales) into frame
    /// `dst` — the storage half of a pool copy-on-write split.
    pub fn copy_frame(&mut self, src: usize, dst: usize) {
        let n = self.page * self.dh;
        for h in 0..self.n_heads {
            let (s, d) = (self.head_base(h) + src * n, self.head_base(h) + dst * n);
            match self.dtype {
                KvDtype::F32 => self.f32s.copy_within(s..s + n, d),
                KvDtype::F16 | KvDtype::Bf16 => self.halfs.copy_within(s..s + n, d),
                KvDtype::Int8 | KvDtype::Fp8E4M3 => self.codes.copy_within(s..s + n, d),
            }
        }
        if self.dtype == KvDtype::Int8 {
            let n = self.page * self.n_heads;
            self.scales.copy_within(src * n..(src + 1) * n, dst * n);
        }
    }

    /// Start of head `head`'s frame storage: frames are (head, frame,
    /// row)-major, so for one head, consecutive frames are contiguous
    /// `page × dh` blocks.
    #[inline]
    fn head_base(&self, head: usize) -> usize {
        head * self.n_frames * self.page * self.dh
    }

    /// Storage row backing physical row `prow` of `slot`, through the page
    /// table. The element offset for head `h` is
    /// `head_base(h) + srow·dh`; the int8 scale index is
    /// `srow·n_heads + h`.
    #[inline]
    fn srow(&self, slot: usize, prow: usize) -> usize {
        let f = self.tables[slot * self.pps + prow / self.page];
        debug_assert!(f != UNMAPPED, "kv access to unmapped page (slot {slot}, row {prow})");
        (f as usize) * self.page + prow % self.page
    }

    /// Storage row of the window's first row if the whole `len`-row window
    /// starting at physical row `start` is one contiguous storage run
    /// (frames backing it were allocated consecutively), else `None`.
    /// Wrapped windows always decline — the second arc is logically older
    /// than the first, so it must be re-ordered through the gather path.
    fn run_extent(&self, slot: usize, start: usize, len: usize) -> Option<usize> {
        if start + len > self.max_seq {
            return None;
        }
        let first = self.srow(slot, start);
        let head = (self.page - start % self.page).min(len);
        let mut expect = first + head;
        let mut done = head;
        while done < len {
            let r = self.srow(slot, start + done);
            if r != expect {
                return None;
            }
            let n = (len - done).min(self.page);
            expect = r + n;
            done += n;
        }
        Some(first)
    }

    /// Encode one position's row (`n_heads·dh` f32 values, head-major like
    /// the model's hidden dim) into the slab at physical row (`slot`, `pos`).
    /// The page backing `pos` must be mapped (identity mapping for
    /// standalone slabs; `KvCachePool::prepare_span` for pooled ones).
    pub fn write(&mut self, slot: usize, pos: usize, row: &[f32]) {
        assert_eq!(row.len(), self.n_heads * self.dh, "kv row width mismatch");
        assert!(slot < self.slots() && pos < self.max_seq, "kv write out of range");
        let f = self.tables[slot * self.pps + pos / self.page];
        assert!(f != UNMAPPED, "kv write to unmapped page (slot {slot}, row {pos})");
        let r = (f as usize) * self.page + pos % self.page;
        let dh = self.dh;
        for h in 0..self.n_heads {
            let seg = &row[h * dh..(h + 1) * dh];
            let base = self.head_base(h) + r * dh;
            match self.dtype {
                KvDtype::F32 => self.f32s[base..base + dh].copy_from_slice(seg),
                KvDtype::F16 | KvDtype::Bf16 => {
                    let kind = self.dtype.half_kind().unwrap();
                    encode_slice(kind, seg, &mut self.halfs[base..base + dh]);
                }
                KvDtype::Int8 => {
                    let alpha = seg.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    self.scales[r * self.n_heads + h] = alpha;
                    for (dst, &x) in self.codes[base..base + dh].iter_mut().zip(seg.iter()) {
                        *dst = quant_code(x, alpha, 8) as u8;
                    }
                }
                KvDtype::Fp8E4M3 => {
                    for (dst, &x) in self.codes[base..base + dh].iter_mut().zip(seg.iter()) {
                        *dst = e4m3_to_bits(x);
                    }
                }
            }
        }
    }

    /// Encode one *logical* position's row. Positions below `max_seq` write
    /// straight through; positions past it evict the oldest retained row
    /// per `layout` — an O(1) wrapped overwrite at `logical % max_seq` for
    /// [`KvLayout::Ring`], an O(window) shift-down + append for the
    /// [`KvLayout::Shift`] reference.
    pub fn write_logical(&mut self, slot: usize, logical: usize, row: &[f32], layout: KvLayout) {
        let pos = if logical < self.max_seq {
            logical
        } else {
            match layout {
                KvLayout::Ring => logical % self.max_seq,
                KvLayout::Shift => {
                    self.evict_front(slot);
                    self.max_seq - 1
                }
            }
        };
        self.write(slot, pos, row);
    }

    /// Drop physical row 0 of `slot` by moving rows `1..max_seq` (codes or
    /// f32 values, and int8 scales) down one row — the [`KvLayout::Shift`]
    /// eviction. Rows move *through the page table* one at a time (source
    /// and destination may live in different frames), O(window) — the slow
    /// reference layout only. Scales move with their rows, preserving the
    /// (row, head) pairing.
    fn evict_front(&mut self, slot: usize) {
        for prow in 1..self.max_seq {
            let (s, d) = (self.srow(slot, prow), self.srow(slot, prow - 1));
            self.copy_row(s, d);
        }
    }

    /// Copy one storage row (all heads + int8 scales) to another.
    fn copy_row(&mut self, src: usize, dst: usize) {
        if src == dst {
            return;
        }
        let dh = self.dh;
        for h in 0..self.n_heads {
            let (s, d) = (self.head_base(h) + src * dh, self.head_base(h) + dst * dh);
            match self.dtype {
                KvDtype::F32 => self.f32s.copy_within(s..s + dh, d),
                KvDtype::F16 | KvDtype::Bf16 => self.halfs.copy_within(s..s + dh, d),
                KvDtype::Int8 | KvDtype::Fp8E4M3 => self.codes.copy_within(s..s + dh, d),
            }
        }
        if self.dtype == KvDtype::Int8 {
            let n = self.n_heads;
            self.scales.copy_within(src * n..src * n + n, dst * n);
        }
    }

    /// The `len`-row window of the (`slot`, `head`) stripe beginning at
    /// physical row `start`, in logical order, as a contiguous `len × dh`
    /// f32 tile. A window that reaches `max_seq` wraps to row 0 (the ring's
    /// second arc). Unwrapped f32 windows whose frames form one contiguous
    /// storage run ([`KvSlab::run_extent`] — always true for identity
    /// mappings, and for pooled slots whose frames were allocated
    /// consecutively) are zero-copy borrows; wrapped, quantized, or
    /// fragmented windows are copied/dequantized into `scratch` page arc by
    /// page arc.
    pub(crate) fn tile<'a>(
        &'a self,
        slot: usize,
        head: usize,
        start: usize,
        len: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(len <= self.max_seq && start < self.max_seq);
        if self.dtype == KvDtype::F32 {
            if let Some(r0) = self.run_extent(slot, start, len) {
                let base = self.head_base(head) + r0 * self.dh;
                return &self.f32s[base..base + len * self.dh];
            }
        }
        scratch.clear();
        let mut done = 0;
        while done < len {
            let prow = (start + done) % self.max_seq;
            let n = (len - done).min(self.page - prow % self.page).min(self.max_seq - prow);
            self.fill_rows(head, self.srow(slot, prow), n, scratch);
            done += n;
        }
        &scratch[..]
    }

    /// Zero-copy borrow of an *unwrapped*, storage-contiguous window of a
    /// half-precision stripe, as raw 16-bit codes — the fast path
    /// [`run_item`] feeds straight into the half-operand GEMMs, skipping
    /// f32 materialization. Returns `None` for non-half dtypes, wrapped
    /// windows, and windows whose frames are not one contiguous run (those
    /// fall back to the per-page [`KvSlab::tile`] dequant path).
    pub(crate) fn tile_half(
        &self,
        slot: usize,
        head: usize,
        start: usize,
        len: usize,
    ) -> Option<&[u16]> {
        if self.half_kind().is_none() {
            return None;
        }
        let r0 = self.run_extent(slot, start, len)?;
        let base = self.head_base(head) + r0 * self.dh;
        Some(&self.halfs[base..base + len * self.dh])
    }

    /// Append `n` rows starting at *storage* row `r0` (contiguous within
    /// one frame by construction) of `head`'s storage to `out`, dequantized
    /// to f32.
    fn fill_rows(&self, head: usize, r0: usize, n: usize, out: &mut Vec<f32>) {
        if n == 0 {
            return;
        }
        let dh = self.dh;
        let base = self.head_base(head) + r0 * dh;
        match self.dtype {
            KvDtype::F32 => out.extend_from_slice(&self.f32s[base..base + n * dh]),
            KvDtype::F16 | KvDtype::Bf16 => {
                let dec = self.half_kind().unwrap().decoder();
                out.extend(self.halfs[base..base + n * dh].iter().map(|&h| dec(h)));
            }
            KvDtype::Int8 => {
                for t in 0..n {
                    let alpha = self.scales[(r0 + t) * self.n_heads + head];
                    let dq = alpha / 127.0;
                    let src = &self.codes[base + t * dh..base + (t + 1) * dh];
                    out.extend(src.iter().map(|&c| (c as i8) as f32 * dq));
                }
            }
            KvDtype::Fp8E4M3 => {
                out.extend(self.codes[base..base + n * dh].iter().map(|&b| e4m3_from_bits(b)));
            }
        }
    }
}

/// One sequence's attention work in a packed batch: `span` new query rows
/// starting at row `q_base` of the packed q/ctx matrices, attending over
/// `p0` retained K/V window positions plus its own `span` fresh ones
/// (query row `s` sees window entries `0..=p0+s`, in logical order).
#[derive(Clone, Copy, Debug)]
pub struct AttnSpan {
    /// First row of this span in the packed q/ctx matrices.
    pub q_base: usize,
    /// Number of new (query) positions.
    pub span: usize,
    /// Retained K/V window positions preceding this span's rows. For an
    /// unwrapped slot this is the cached length; once the ring has wrapped
    /// it is the window size minus `span` (older positions are evicted).
    pub p0: usize,
    /// K/V addressing: the slot index for [`KvSource::Pool`], the row base
    /// in the fresh K/V matrices for [`KvSource::Fresh`].
    pub kv: usize,
    /// Physical row of the window's first (oldest retained) position in
    /// the pool slabs — the ring read wraps from `max_seq` back to row 0.
    /// Always 0 for [`KvSource::Fresh`] and for unwrapped slots.
    pub start: usize,
}

/// Where a span's K/V rows live.
pub enum KvSource<'a> {
    /// Freshly projected K/V matrices, `d_model` wide, the span's positions
    /// `0..p0+span` at rows `kv..kv+p0+span` (the full-forward path; `p0`
    /// is 0 there).
    Fresh { k: &'a Matrix, v: &'a Matrix },
    /// Slot-striped cache slabs (the serving path); the span's positions
    /// live in slot `kv`, already written for `0..p0+span`.
    Pool { k: &'a KvSlab, v: &'a KvSlab },
}

/// In-place numerically-stable softmax over a slice (−∞ entries come out
/// as exact zeros).
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Reusable per-worker tile scratch.
#[derive(Default)]
struct Scratch {
    qt: Vec<f32>,
    kt: Vec<f32>,
    vt: Vec<f32>,
    sc: Vec<f32>,
}

/// Copy a strided head-column block (`len` rows × `dh` cols at column `c0`)
/// of a d_model-wide matrix into a contiguous tile.
fn fill_cols(m: &Matrix, row0: usize, len: usize, c0: usize, dh: usize, out: &mut Vec<f32>) {
    out.clear();
    for t in 0..len {
        out.extend_from_slice(&m.row(row0 + t)[c0..c0 + dh]);
    }
}

/// Scale, causally mask, and row-softmax a `span × kvlen` score tile in
/// place. The mask is expressed in logical window positions: entry
/// `p0 + r` is query row `r` itself, later entries are its span-mates'
/// rows.
fn mask_softmax(sc: &mut [f32], p0: usize, kvlen: usize, scale: f32) {
    for (r, row) in sc.chunks_exact_mut(kvlen).enumerate() {
        for v2 in row.iter_mut() {
            *v2 *= scale;
        }
        for v2 in row[p0 + r + 1..].iter_mut() {
            *v2 = f32::NEG_INFINITY;
        }
        softmax_inplace(row);
    }
}

/// Compute one (span, head) context tile (`span × dh`, zero-initialized)
/// via blocked Q·Kᵀ → mask → softmax → P·V.
#[allow(clippy::too_many_arguments)]
fn run_item(
    sp: &AttnSpan,
    head: usize,
    dh: usize,
    scale: f32,
    q: &Matrix,
    kv: &KvSource,
    s: &mut Scratch,
    out: &mut [f32],
) {
    let span = sp.span;
    let kvlen = sp.p0 + span;
    let c0 = head * dh;
    // Q tile: span × dh.
    s.qt.clear();
    for r in 0..span {
        s.qt.extend_from_slice(&q.row(sp.q_base + r)[c0..c0 + dh]);
    }
    // Half-width fast path: an unwrapped f16/bf16 pool window feeds its raw
    // 16-bit codes straight into the half-operand GEMMs (inline decode,
    // f32 accumulation in the same order) — bit-identical to the
    // dequantize-to-scratch fallback below, at half the tile traffic.
    if let KvSource::Pool { k, v } = kv {
        if let (Some(kind), Some(kht), Some(vht)) = (
            k.half_kind(),
            k.tile_half(sp.kv, head, sp.start, kvlen),
            v.tile_half(sp.kv, head, sp.start, kvlen),
        ) {
            let dec = kind.decoder();
            s.sc.resize(span * kvlen, 0.0);
            gemm_abt_half(&s.qt, kht, span, dh, kvlen, dec, &mut s.sc);
            mask_softmax(&mut s.sc, sp.p0, kvlen, scale);
            gemm_half(&s.sc, vht, span, kvlen, dh, dec, out);
            return;
        }
    }
    let (kt, vt): (&[f32], &[f32]) = match kv {
        KvSource::Fresh { k, v } => {
            fill_cols(k, sp.kv, kvlen, c0, dh, &mut s.kt);
            fill_cols(v, sp.kv, kvlen, c0, dh, &mut s.vt);
            (&s.kt, &s.vt)
        }
        KvSource::Pool { k, v } => (
            k.tile(sp.kv, head, sp.start, kvlen, &mut s.kt),
            v.tile(sp.kv, head, sp.start, kvlen, &mut s.vt),
        ),
    };
    // Scores: span × kvlen blocked Q·Kᵀ, then causal mask + row softmax.
    s.sc.resize(span * kvlen, 0.0);
    gemm_abt(&s.qt, kt, span, dh, kvlen, &mut s.sc);
    mask_softmax(&mut s.sc, sp.p0, kvlen, scale);
    // Context tile: span × dh blocked P·V (masked positions have exact-zero
    // probability and are skipped by the kernel).
    gemm(&s.sc, vt, span, kvlen, dh, out);
}

/// Blocked multi-head causal attention: for every [`AttnSpan`], compute its
/// context rows from `q` (packed `Σspan × n_heads·dh`) against `kv`, and
/// return them packed in the same layout as `q`.
///
/// Work is one item per (span, head); items are partitioned across
/// `std::thread::scope` workers balanced by multiply-add cost (serial below
/// the same threshold the dense matmul and packed kernels use). Results are
/// identical regardless of threading: each item is computed independently
/// into its own tile, and the f32 path reproduces the scalar reference
/// ([`attend_reference`]) bit-for-bit.
pub fn attend(
    n_heads: usize,
    dh: usize,
    scale: f32,
    spans: &[AttnSpan],
    q: &Matrix,
    kv: &KvSource,
) -> Matrix {
    let d = n_heads * dh;
    assert_eq!(q.cols(), d, "q width {} != n_heads·dh {}", q.cols(), d);
    let mut ctx = Matrix::zeros(q.rows(), d);
    if spans.is_empty() {
        return ctx;
    }
    // Split long prefill spans into query tiles of at most
    // `TILES.attn_tile()` rows (more, finer work items → better balance
    // across workers and a bounded score-tile footprint). Bit-exact:
    // query rows are independent — sub-span row `r'` at offset `t` keeps
    // causal prefix `p0 + t + r' = p0 + r`, per-row softmax and the
    // row-independent GEMMs are untouched. The `usize::MAX` default
    // never splits.
    let tile = crate::kernels::TILES.attn_tile();
    let split: Vec<AttnSpan>;
    let spans: &[AttnSpan] = if spans.iter().any(|sp| sp.span > tile) {
        split = spans
            .iter()
            .flat_map(|sp| {
                (0..sp.span).step_by(tile).map(move |t| AttnSpan {
                    q_base: sp.q_base + t,
                    span: tile.min(sp.span - t),
                    p0: sp.p0 + t,
                    kv: sp.kv,
                    start: sp.start,
                })
            })
            .collect();
        &split
    } else {
        spans
    };
    // One work item per (span, head), costed in multiply-adds.
    let mut items: Vec<(usize, usize)> = Vec::with_capacity(spans.len() * n_heads);
    let mut total_cost = 0usize;
    for (si, sp) in spans.iter().enumerate() {
        for h in 0..n_heads {
            items.push((si, h));
        }
        total_cost += n_heads * 2 * sp.span * (sp.p0 + sp.span) * dh;
    }
    let item_cost = |&(si, _): &(usize, usize)| {
        let sp = &spans[si];
        2 * sp.span * (sp.p0 + sp.span) * dh
    };
    let nt = if total_cost < PAR_THRESHOLD { 1 } else { num_threads().min(items.len()) };

    // Contiguous item runs of ≈ equal cost. One shared buffer holds every
    // item's tile (item-major); each run fills its own buffer segment —
    // serially for one run, across `std::thread::scope` workers otherwise —
    // and the tiles are stitched into ctx afterwards (an O(n·d) copy,
    // negligible next to the O(n·kvlen·dh) attention math).
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nt + 1);
    let target = total_cost.div_ceil(nt);
    let mut start = 0usize;
    let mut acc = 0usize;
    for (i, it) in items.iter().enumerate() {
        acc += item_cost(it);
        if acc >= target || i + 1 == items.len() {
            ranges.push((start, i + 1));
            start = i + 1;
            acc = 0;
        }
    }
    let tile_elems = |its: &[(usize, usize)]| -> usize {
        its.iter().map(|&(si, _)| spans[si].span * dh).sum()
    };
    let run_range = |i0: usize, i1: usize, out: &mut [f32]| {
        let mut s = Scratch::default();
        let mut off = 0usize;
        for &(si, h) in &items[i0..i1] {
            let sp = &spans[si];
            let len = sp.span * dh;
            run_item(sp, h, dh, scale, q, kv, &mut s, &mut out[off..off + len]);
            off += len;
        }
    };
    let mut buf = vec![0.0f32; tile_elems(&items)];
    if nt <= 1 {
        run_range(0, items.len(), buf.as_mut_slice());
    } else {
        std::thread::scope(|scope| {
            let run_range = &run_range;
            let mut rest = buf.as_mut_slice();
            for &(i0, i1) in &ranges {
                let (head_buf, tail) = rest.split_at_mut(tile_elems(&items[i0..i1]));
                rest = tail;
                scope.spawn(move || run_range(i0, i1, head_buf));
            }
        });
    }
    let mut off = 0usize;
    for &(si, h) in &items {
        let sp = &spans[si];
        let c0 = h * dh;
        for (r, trow) in buf[off..off + sp.span * dh].chunks_exact(dh).enumerate() {
            ctx.row_mut(sp.q_base + r)[c0..c0 + dh].copy_from_slice(trow);
        }
        off += sp.span * dh;
    }
    ctx
}

/// Scalar reference attention: the per-(head, position) dot-product loops
/// the forwards used before the blocked kernel. Kept ONLY as the parity
/// baseline for tests and the `benches/decode.rs` blocking on/off
/// measurement — no forward path calls this.
pub fn attend_reference(
    n_heads: usize,
    dh: usize,
    scale: f32,
    spans: &[AttnSpan],
    q: &Matrix,
    kv: &KvSource,
) -> Matrix {
    let d = n_heads * dh;
    assert_eq!(q.cols(), d);
    let mut ctx = Matrix::zeros(q.rows(), d);
    let mut kt_s: Vec<f32> = Vec::new();
    let mut vt_s: Vec<f32> = Vec::new();
    for sp in spans {
        let kvlen = sp.p0 + sp.span;
        for h in 0..n_heads {
            let c0 = h * dh;
            let (kt, vt): (&[f32], &[f32]) = match kv {
                KvSource::Fresh { k, v } => {
                    fill_cols(k, sp.kv, kvlen, c0, dh, &mut kt_s);
                    fill_cols(v, sp.kv, kvlen, c0, dh, &mut vt_s);
                    (&kt_s, &vt_s)
                }
                KvSource::Pool { k, v } => (
                    k.tile(sp.kv, h, sp.start, kvlen, &mut kt_s),
                    v.tile(sp.kv, h, sp.start, kvlen, &mut vt_s),
                ),
            };
            for r in 0..sp.span {
                let gp = sp.p0 + r;
                let qrow = &q.row(sp.q_base + r)[c0..c0 + dh];
                let mut scores = vec![0.0f32; gp + 1];
                for (t, sc) in scores.iter_mut().enumerate() {
                    let krow = &kt[t * dh..(t + 1) * dh];
                    let mut dot = 0.0f32;
                    for (a, b2) in qrow.iter().zip(krow.iter()) {
                        dot += a * b2;
                    }
                    *sc = dot * scale;
                }
                softmax_inplace(&mut scores);
                let crow = ctx.row_mut(sp.q_base + r);
                for (t, &pr) in scores.iter().enumerate() {
                    let vrow = &vt[t * dh..(t + 1) * dh];
                    for (cv, &vv) in crow[c0..c0 + dh].iter_mut().zip(vrow.iter()) {
                        *cv += pr * vv;
                    }
                }
            }
        }
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Random slab pair + matching f32 rows for `slots` sequences at the
    /// given depths.
    fn filled_slabs(
        dtype: KvDtype,
        depths: &[usize],
        max_seq: usize,
        n_heads: usize,
        dh: usize,
        rng: &mut Pcg32,
    ) -> (KvSlab, KvSlab) {
        let d = n_heads * dh;
        let mut ks = KvSlab::new(dtype, depths.len(), max_seq, n_heads, dh);
        let mut vs = KvSlab::new(dtype, depths.len(), max_seq, n_heads, dh);
        for (slot, &depth) in depths.iter().enumerate() {
            for pos in 0..depth {
                let krow: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
                let vrow: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
                ks.write(slot, pos, &krow);
                vs.write(slot, pos, &vrow);
            }
        }
        (ks, vs)
    }

    #[test]
    fn blocked_matches_scalar_reference_exactly_fresh() {
        // Full-forward shape: mixed batch, span == kvlen, p0 == 0. The f32
        // blocked path must be bit-identical to the scalar loops.
        let mut rng = Pcg32::seeded(1);
        let (n_heads, dh, seq, batch) = (4usize, 8usize, 13usize, 3usize);
        let d = n_heads * dh;
        let n = batch * seq;
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let spans: Vec<AttnSpan> = (0..batch)
            .map(|b| AttnSpan { q_base: b * seq, span: seq, p0: 0, kv: b * seq, start: 0 })
            .collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Fresh { k: &k, v: &v };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn blocked_matches_scalar_reference_exactly_pool() {
        // Serving shape: mixed spans (a prefill batched with decode steps)
        // over cached prefixes of different depths.
        let mut rng = Pcg32::seeded(2);
        let (n_heads, dh, max_seq) = (2usize, 16usize, 32usize);
        let d = n_heads * dh;
        // slot depths INCLUDE the fresh span rows (already written).
        let depths = [9usize, 20, 1];
        let spans = [
            AttnSpan { q_base: 0, span: 4, p0: 5, kv: 0, start: 0 }, // mid-decode burst
            AttnSpan { q_base: 4, span: 1, p0: 19, kv: 1, start: 0 }, // one-token decode
            AttnSpan { q_base: 5, span: 1, p0: 0, kv: 2, start: 0 },  // fresh prefill
        ];
        let (ks, vs) = filled_slabs(KvDtype::F32, &depths, max_seq, n_heads, dh, &mut rng);
        let q = Matrix::randn(6, d, 1.0, &mut rng);
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Pool { k: &ks, v: &vs };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn threaded_path_matches_serial_exactly() {
        // Big enough to cross PAR_THRESHOLD so attend() takes the
        // scope-spawn path; the reference is fully serial.
        let mut rng = Pcg32::seeded(3);
        let (n_heads, dh, depth, batch) = (4usize, 64usize, 128usize, 4usize);
        let d = n_heads * dh;
        let depths: Vec<usize> = (0..batch).map(|_| depth).collect();
        let (ks, vs) = filled_slabs(KvDtype::F32, &depths, depth, n_heads, dh, &mut rng);
        let q = Matrix::randn(batch, d, 1.0, &mut rng);
        let spans: Vec<AttnSpan> = (0..batch)
            .map(|b| AttnSpan { q_base: b, span: 1, p0: depth - 1, kv: b, start: 0 })
            .collect();
        let total_cost: usize = spans.iter().map(|sp| n_heads * 2 * (sp.p0 + 1) * dh).sum();
        assert!(total_cost >= crate::tensor::PAR_THRESHOLD, "test must cross the threshold");
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Pool { k: &ks, v: &vs };
        let blocked = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(blocked, reference);
    }

    #[test]
    fn int8_slab_small_error_and_4x_fewer_bytes() {
        let mut rng = Pcg32::seeded(4);
        let (n_heads, dh, max_seq) = (4usize, 32usize, 16usize);
        let d = n_heads * dh;
        let mut f32s = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
        let mut int8 = KvSlab::new(KvDtype::Int8, 1, max_seq, n_heads, dh);
        let mut fp8 = KvSlab::new(KvDtype::Fp8E4M3, 1, max_seq, n_heads, dh);
        for pos in 0..max_seq {
            let row: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
            f32s.write(0, pos, &row);
            int8.write(0, pos, &row);
            fp8.write(0, pos, &row);
        }
        let mut sf = Vec::new();
        let mut s8 = Vec::new();
        let mut se = Vec::new();
        for h in 0..n_heads {
            let exact = f32s.tile(0, h, 0, max_seq, &mut sf).to_vec();
            let i8t = int8.tile(0, h, 0, max_seq, &mut s8);
            let f8t = fp8.tile(0, h, 0, max_seq, &mut se);
            let norm: f32 = exact.iter().map(|x| x * x).sum::<f32>().sqrt();
            let err8: f32 =
                exact.iter().zip(i8t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            let errf: f32 =
                exact.iter().zip(f8t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            assert!(err8 / norm < 0.01, "int8 head {h}: rel err {}", err8 / norm);
            assert!(errf / norm < 0.05, "fp8 head {h}: rel err {}", errf / norm);
        }
        // ~4× fewer cache bytes (int8 pays a small per-(row, head) scale).
        assert!(f32s.bytes() as f64 / int8.bytes() as f64 > 3.5, "int8 ratio");
        assert_eq!(f32s.bytes(), 4 * fp8.bytes());
    }

    /// F16/Bf16 slabs: exactly 2× fewer cache bytes than f32 (no scale
    /// overhead) and sub-percent row fidelity.
    #[test]
    fn half_slab_small_error_and_2x_fewer_bytes() {
        let mut rng = Pcg32::seeded(8);
        let (n_heads, dh, max_seq) = (4usize, 32usize, 16usize);
        let d = n_heads * dh;
        let mut f32s = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
        let mut f16s = KvSlab::new(KvDtype::F16, 1, max_seq, n_heads, dh);
        let mut bf16s = KvSlab::new(KvDtype::Bf16, 1, max_seq, n_heads, dh);
        for pos in 0..max_seq {
            let row: Vec<f32> = (0..d).map(|_| rng.gauss()).collect();
            f32s.write(0, pos, &row);
            f16s.write(0, pos, &row);
            bf16s.write(0, pos, &row);
        }
        let (mut sf, mut sh, mut sb) = (Vec::new(), Vec::new(), Vec::new());
        for h in 0..n_heads {
            let exact = f32s.tile(0, h, 0, max_seq, &mut sf).to_vec();
            let f16t = f16s.tile(0, h, 0, max_seq, &mut sh);
            let bf16t = bf16s.tile(0, h, 0, max_seq, &mut sb);
            let norm: f32 = exact.iter().map(|x| x * x).sum::<f32>().sqrt();
            let errh: f32 =
                exact.iter().zip(f16t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            let errb: f32 =
                exact.iter().zip(bf16t.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            assert!(errh / norm < 1e-3, "f16 head {h}: rel err {}", errh / norm);
            assert!(errb / norm < 8e-3, "bf16 head {h}: rel err {}", errb / norm);
        }
        // Exactly half the bytes — no scale storage.
        assert_eq!(f32s.bytes(), 2 * f16s.bytes());
        assert_eq!(f32s.bytes(), 2 * bf16s.bytes());
    }

    /// The half GEMM fast path (raw u16 tiles) must be bit-identical to
    /// forcing the dequantize-to-scratch fallback on the same slabs, and
    /// within half tolerance of full-f32 attention — for unwrapped AND
    /// wrapped (two-arc, fallback) windows.
    #[test]
    fn half_pool_attention_fast_path_matches_scratch_fallback() {
        let (n_heads, dh, max_seq) = (2usize, 16usize, 24usize);
        let d = n_heads * dh;
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let mut rng = Pcg32::seeded(9);
            let mut rng2 = Pcg32::seeded(9);
            let depth = max_seq; // unwrapped, full window
            let (kf, vf) = filled_slabs(KvDtype::F32, &[depth], max_seq, n_heads, dh, &mut rng);
            let (kh, vh) = filled_slabs(dtype, &[depth], max_seq, n_heads, dh, &mut rng2);
            let q = Matrix::randn(2, d, 1.0, &mut rng);
            let spans = [AttnSpan { q_base: 0, span: 2, p0: depth - 2, kv: 0, start: 0 }];
            let scale = 1.0 / (dh as f32).sqrt();
            let exact = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &kf, v: &vf });
            let half = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &kh, v: &vh });
            let tol = if dtype == KvDtype::F16 { 2e-3 } else { 2e-2 };
            assert!(half.rel_err(&exact) < tol, "{} err {}", dtype.name(), half.rel_err(&exact));

            // Scratch reference: run the same math on a manually dequantized
            // f32 copy of the half slabs — the fast path must match it
            // bit-for-bit (inline decode preserves accumulation order).
            let mut kd = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
            let mut vd = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
            let (mut sk, mut sv) = (Vec::new(), Vec::new());
            for pos in 0..depth {
                let mut krow = vec![0.0f32; d];
                let mut vrow = vec![0.0f32; d];
                for h in 0..n_heads {
                    let kt = kh.tile(0, h, pos, 1, &mut sk);
                    krow[h * dh..(h + 1) * dh].copy_from_slice(kt);
                    let vt = vh.tile(0, h, pos, 1, &mut sv);
                    vrow[h * dh..(h + 1) * dh].copy_from_slice(vt);
                }
                kd.write(0, pos, &krow);
                vd.write(0, pos, &vrow);
            }
            let deq = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &kd, v: &vd });
            assert_eq!(half, deq, "{} fast path != scratch path", dtype.name());

            // Wrapped window: write past max_seq so the ring wraps; the
            // fast path declines (tile_half → None) and the two-arc decode
            // fallback must agree with a straight slab of the same window.
            let mut rng3 = Pcg32::seeded(10);
            let depth2 = max_seq + 5;
            let rows: Vec<Vec<f32>> =
                (0..depth2).map(|_| (0..d).map(|_| rng3.gauss()).collect()).collect();
            let mut ring = KvSlab::new(dtype, 1, max_seq, n_heads, dh);
            let mut straight = KvSlab::new(dtype, 1, max_seq, n_heads, dh);
            for (logical, row) in rows.iter().enumerate() {
                ring.write_logical(0, logical, row, KvLayout::Ring);
            }
            for (pos, row) in rows[depth2 - max_seq..].iter().enumerate() {
                straight.write(0, pos, row);
            }
            let start = depth2 % max_seq;
            assert!(ring.tile_half(0, 0, start, max_seq).is_none(), "wrapped must decline");
            let sp_ring = [AttnSpan { q_base: 0, span: 1, p0: max_seq - 1, kv: 0, start }];
            let sp_str = [AttnSpan { q_base: 0, span: 1, p0: max_seq - 1, kv: 0, start: 0 }];
            let q1 = Matrix::randn(1, d, 1.0, &mut rng3);
            let a_ring =
                attend(n_heads, dh, scale, &sp_ring, &q1, &KvSource::Pool { k: &ring, v: &ring });
            let a_str = attend(
                n_heads,
                dh,
                scale,
                &sp_str,
                &q1,
                &KvSource::Pool { k: &straight, v: &straight },
            );
            assert_eq!(a_ring, a_str, "{} wrapped window", dtype.name());
        }
    }

    /// Splitting spans into query tiles must be bit-exact for every tile
    /// size, on both fresh and pool sources.
    #[test]
    fn attn_tile_split_is_bit_exact() {
        use crate::kernels::{DEFAULT_ATTN_TILE, DEFAULT_GT, DEFAULT_KT, TILES};
        let mut rng = Pcg32::seeded(11);
        let (n_heads, dh, seq, batch) = (2usize, 8usize, 13usize, 2usize);
        let d = n_heads * dh;
        let n = batch * seq;
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let spans: Vec<AttnSpan> = (0..batch)
            .map(|b| AttnSpan { q_base: b * seq, span: seq, p0: 0, kv: b * seq, start: 0 })
            .collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let src = KvSource::Fresh { k: &k, v: &v };
        TILES.set(DEFAULT_KT, DEFAULT_GT, DEFAULT_ATTN_TILE);
        let want = attend(n_heads, dh, scale, &spans, &q, &src);
        let reference = attend_reference(n_heads, dh, scale, &spans, &q, &src);
        assert_eq!(want, reference);
        for tile in [1usize, 2, 4, 5, 13, 64] {
            TILES.set(DEFAULT_KT, DEFAULT_GT, tile);
            assert_eq!(attend(n_heads, dh, scale, &spans, &q, &src), want, "tile {tile}");
        }
        TILES.reset();
    }

    #[test]
    fn quantized_pool_attention_close_to_f32() {
        let mut rng = Pcg32::seeded(5);
        let (n_heads, dh, depth) = (2usize, 16usize, 24usize);
        let d = n_heads * dh;
        // Same rows into an f32 and an int8 slab (clone the rng stream).
        let mut rng2 = Pcg32::seeded(5);
        let (kf, vf) = filled_slabs(KvDtype::F32, &[depth], depth, n_heads, dh, &mut rng);
        let (k8, v8) = filled_slabs(KvDtype::Int8, &[depth], depth, n_heads, dh, &mut rng2);
        let q = Matrix::randn(2, d, 1.0, &mut rng);
        let spans = [AttnSpan { q_base: 0, span: 2, p0: depth - 2, kv: 0, start: 0 }];
        let scale = 1.0 / (dh as f32).sqrt();
        let exact = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &kf, v: &vf });
        let approx = attend(n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: &k8, v: &v8 });
        assert!(approx.rel_err(&exact) < 0.02, "int8 attn err {}", approx.rel_err(&exact));
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(KvDtype::parse("f32"), Ok(KvDtype::F32));
        assert_eq!(KvDtype::parse("f16"), Ok(KvDtype::F16));
        assert_eq!(KvDtype::parse("fp16"), Ok(KvDtype::F16));
        assert_eq!(KvDtype::parse("bf16"), Ok(KvDtype::Bf16));
        assert_eq!(KvDtype::parse("int8"), Ok(KvDtype::Int8));
        assert_eq!(KvDtype::parse("fp8"), Ok(KvDtype::Fp8E4M3));
        assert_eq!(KvDtype::default(), KvDtype::F32);
        // Unknown names are a hard error that lists the valid spellings.
        let err = KvDtype::parse("float8").unwrap_err();
        assert!(err.contains("float8") && err.contains(KV_DTYPE_NAMES), "{err}");
    }

    /// Wrap-aware addressing: writing `depth > max_seq` logical rows
    /// through the ring must read back (as a two-arc tile in logical
    /// order) the exact bytes of a fresh slab holding only the retained
    /// window — for every dtype, i.e. int8 scales wrap with their rows.
    #[test]
    fn ring_tile_matches_logical_rewrite_all_dtypes() {
        let (n_heads, dh, max_seq) = (3usize, 8usize, 16usize);
        let d = n_heads * dh;
        for dtype in
            [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::Int8, KvDtype::Fp8E4M3]
        {
            let mut rng = Pcg32::seeded(7);
            let depth = 2 * max_seq + 5; // wraps twice, lands mid-stripe
            let rows: Vec<Vec<f32>> =
                (0..depth).map(|_| (0..d).map(|_| rng.gauss()).collect()).collect();
            let mut ring = KvSlab::new(dtype, 1, max_seq, n_heads, dh);
            let mut shift = KvSlab::new(dtype, 1, max_seq, n_heads, dh);
            for (logical, row) in rows.iter().enumerate() {
                ring.write_logical(0, logical, row, KvLayout::Ring);
                shift.write_logical(0, logical, row, KvLayout::Shift);
            }
            // A fresh slab given only the window rows, in logical order.
            let mut fresh = KvSlab::new(dtype, 1, max_seq, n_heads, dh);
            for (pos, row) in rows[depth - max_seq..].iter().enumerate() {
                fresh.write(0, pos, row);
            }
            let start = depth % max_seq; // physical row of the oldest retained
            let (mut sr, mut ss, mut sf) = (Vec::new(), Vec::new(), Vec::new());
            for h in 0..n_heads {
                let want = fresh.tile(0, h, 0, max_seq, &mut sf).to_vec();
                let ring_tile = ring.tile(0, h, start, max_seq, &mut sr);
                let shift_tile = shift.tile(0, h, 0, max_seq, &mut ss);
                assert_eq!(ring_tile, &want[..], "{} ring head {h}", dtype.name());
                assert_eq!(shift_tile, &want[..], "{} shift head {h}", dtype.name());
            }
        }
    }

    /// A wrapped f32 window still reads back in logical order (the
    /// two-arc copy path replaces the zero-copy borrow), and partial
    /// windows starting mid-stripe work for any (start, len).
    #[test]
    fn f32_wrapped_tile_is_logically_ordered() {
        let (n_heads, dh, max_seq) = (1usize, 4usize, 8usize);
        let mut slab = KvSlab::new(KvDtype::F32, 1, max_seq, n_heads, dh);
        // Row for logical L is filled with the value L.
        for logical in 0..max_seq + 3 {
            let row = vec![logical as f32; dh];
            slab.write_logical(0, logical, &row, KvLayout::Ring);
        }
        // Window = logical 3..11, physically [3..8) then [0..3).
        let mut scratch = Vec::new();
        let tile = slab.tile(0, 0, 3, max_seq, &mut scratch);
        let got: Vec<f32> = tile.chunks_exact(dh).map(|r| r[0]).collect();
        assert_eq!(got, (3..11).map(|v| v as f32).collect::<Vec<_>>());
        // Unwrapped sub-window is still the zero-copy fast path (the
        // scratch buffer stays untouched).
        let mut untouched = Vec::new();
        let sub = slab.tile(0, 0, 4, 3, &mut untouched);
        assert_eq!(sub.len(), 3 * dh);
        assert_eq!(sub[0], 4.0);
        assert!(untouched.is_empty());
    }

    #[test]
    fn layout_names_and_default() {
        assert_eq!(KvLayout::default(), KvLayout::Ring);
        assert_eq!(KvLayout::Ring.name(), "ring");
        assert_eq!(KvLayout::Shift.name(), "shift");
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 1e4];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[3] > 0.99);
        // Masked (−∞) entries come out as exact zeros.
        let mut ys = vec![0.5f32, f32::NEG_INFINITY, 1.0];
        softmax_inplace(&mut ys);
        assert_eq!(ys[1], 0.0);
    }
}
