//! Transformer configurations — the "sim" model family.
//!
//! The paper evaluates OPT-125M…13B and LLaMA-2-7B/13B. Those checkpoints
//! are not available here, so we train a scaled-down family from scratch
//! (see DESIGN.md §2): same architecture skeleton (decoder-only,
//! pre-LayerNorm, learned positions, tied embeddings), with widths/depths
//! chosen so the whole family trains on CPU in minutes while preserving the
//! size ordering the paper's cross-model tables rely on.

/// Architecture + size description of one model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Registry name, e.g. `sim-125m`.
    pub name: String,
    /// Hidden width d.
    pub d_model: usize,
    /// Number of transformer blocks n.
    pub n_layers: usize,
    /// Attention heads (d_model % n_heads == 0).
    pub n_heads: usize,
    /// MLP expansion ratio `a` (paper's up/down-projection ratio).
    pub d_ff_ratio: usize,
    /// Vocabulary size V.
    pub vocab: usize,
    /// Maximum (and training) sequence length.
    pub max_seq: usize,
    /// Which paper model this stands in for (for table labels).
    pub stands_for: String,
}

impl ModelConfig {
    /// MLP hidden width.
    pub fn d_ff(&self) -> usize {
        self.d_model * self.d_ff_ratio
    }

    /// Head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (tied embeddings; LayerNorm and biases
    /// included).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_block = 4 * d * d            // Wq, Wk, Wv, Wo
            + 2 * d * self.d_ff()            // fc1, fc2
            + 4 * d                          // attn/mlp biases folded: ln scales+biases
            + d + self.d_ff();               // fc biases
        let embed = self.vocab * d + self.max_seq * d;
        let final_ln = 2 * d;
        embed + self.n_layers * per_block + final_ln
    }

    /// The six compressible linear layers per block, with shapes.
    /// (name, d_in, d_out)
    pub fn linear_layers(&self) -> Vec<(String, usize, usize)> {
        let d = self.d_model;
        let ff = self.d_ff();
        let mut out = Vec::new();
        for b in 0..self.n_layers {
            for (suffix, din, dout) in [
                ("attn.wq", d, d),
                ("attn.wk", d, d),
                ("attn.wv", d, d),
                ("attn.wo", d, d),
                ("mlp.fc1", d, ff),
                ("mlp.fc2", ff, d),
            ] {
                out.push((format!("block{b}.{suffix}"), din, dout));
            }
        }
        out
    }
}

/// The full sim family, ordered by size (mirrors OPT-125M…13B +
/// LLaMA-2-7B/13B in the paper's tables).
pub fn family() -> Vec<ModelConfig> {
    let mk = |name: &str, d: usize, l: usize, h: usize, stands_for: &str| ModelConfig {
        name: name.to_string(),
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_ff_ratio: 4,
        vocab: 512,
        max_seq: 64,
        stands_for: stands_for.to_string(),
    };
    vec![
        mk("sim-125m", 64, 2, 2, "OPT-125M"),
        mk("sim-350m", 96, 3, 3, "OPT-350M"),
        mk("sim-1.3b", 128, 4, 4, "OPT-1.3B"),
        mk("sim-2.7b", 160, 4, 4, "OPT-2.7B"),
        mk("sim-6.7b", 192, 5, 4, "OPT-6.7B"),
        mk("sim-13b", 224, 6, 4, "OPT-13B"),
        mk("sim-llama-7b", 208, 5, 4, "LLaMA-2-7B"),
        mk("sim-llama-13b", 256, 6, 4, "LLaMA-2-13B"),
    ]
}

/// Look up a config by name.
pub fn by_name(name: &str) -> Option<ModelConfig> {
    family().into_iter().find(|c| c.name == name)
}

/// The subset used by quick experiment runs (keeps table wall-clock low).
pub fn quick_family() -> Vec<ModelConfig> {
    family()
        .into_iter()
        .filter(|c| matches!(c.name.as_str(), "sim-125m" | "sim-350m" | "sim-1.3b" | "sim-llama-7b"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_ordered_by_params() {
        let fam: Vec<ModelConfig> = family()
            .into_iter()
            .filter(|c| {
                ["sim-1", "sim-2", "sim-3", "sim-6"].iter().any(|p| c.name.starts_with(p))
            })
            .collect();
        for w in fam.windows(2) {
            assert!(w[0].param_count() < w[1].param_count(), "{} vs {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn lookup_works() {
        assert!(by_name("sim-125m").is_some());
        assert!(by_name("gpt-5").is_none());
    }

    #[test]
    fn heads_divide_width() {
        for c in family() {
            assert_eq!(c.d_model % c.n_heads, 0, "{}", c.name);
        }
    }

    #[test]
    fn linear_layer_inventory() {
        let c = by_name("sim-125m").unwrap();
        let layers = c.linear_layers();
        assert_eq!(layers.len(), 6 * c.n_layers);
        assert!(layers.iter().any(|(n, _, _)| n == "block0.mlp.fc1"));
        let (_, din, dout) = layers.iter().find(|(n, _, _)| n == "block1.mlp.fc2").unwrap().clone();
        assert_eq!((din, dout), (c.d_ff(), c.d_model));
    }

    #[test]
    fn param_count_sane() {
        let c = by_name("sim-125m").unwrap();
        // embed 512*64 + pos 64*64 + 2 blocks*(4*64²+2*64*256+...) ≈ 150k
        let p = c.param_count();
        assert!(p > 100_000 && p < 300_000, "params {p}");
    }
}
