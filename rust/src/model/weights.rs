//! Named weight containers + binary checkpoint IO.
//!
//! Checkpoints use a tiny self-describing format (`SLIMW001`): tensor count,
//! then per tensor `name | rows | cols | f32 LE data`. Both the Rust trainer
//! and the examples read/write it; Python never needs weights (shapes are
//! static at AOT time), so no interop format is required.

use crate::rng::Pcg32;
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use super::config::ModelConfig;

const MAGIC: &[u8; 8] = b"SLIMW001";

/// Ordered, named tensor collection.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    tensors: Vec<(String, Matrix)>,
    index: HashMap<String, usize>,
}

impl Weights {
    pub fn new() -> Self {
        Weights::default()
    }

    /// Insert (or replace) a tensor.
    pub fn set(&mut self, name: &str, m: Matrix) {
        if let Some(&i) = self.index.get(name) {
            self.tensors[i].1 = m;
        } else {
            self.index.insert(name.to_string(), self.tensors.len());
            self.tensors.push((name.to_string(), m));
        }
    }

    pub fn get(&self, name: &str) -> Option<&Matrix> {
        self.index.get(name).map(|&i| &self.tensors[i].1)
    }

    /// Like `get` but panics with the tensor name on miss (model code path).
    pub fn expect(&self, name: &str) -> &Matrix {
        self.get(name).unwrap_or_else(|| panic!("missing tensor {name}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.iter().map(|(n, _)| n.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Matrix)> {
        self.tensors.iter().map(|(n, m)| (n.as_str(), m))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total f32 parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|(_, m)| m.len()).sum()
    }

    /// Save to disk.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, m) in &self.tensors {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(m.rows() as u32).to_le_bytes())?;
            f.write_all(&(m.cols() as u32).to_le_bytes())?;
            for &v in m.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load from disk.
    pub fn load(path: &Path) -> Result<Weights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a SLIMW001 checkpoint", path.display());
        }
        let mut buf4 = [0u8; 4];
        f.read_exact(&mut buf4)?;
        let count = u32::from_le_bytes(buf4) as usize;
        let mut out = Weights::new();
        for _ in 0..count {
            let mut buf2 = [0u8; 2];
            f.read_exact(&mut buf2)?;
            let nlen = u16::from_le_bytes(buf2) as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            f.read_exact(&mut buf4)?;
            let rows = u32::from_le_bytes(buf4) as usize;
            f.read_exact(&mut buf4)?;
            let cols = u32::from_le_bytes(buf4) as usize;
            let mut data = vec![0f32; rows * cols];
            let mut raw = vec![0u8; rows * cols * 4];
            f.read_exact(&mut raw)?;
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            out.set(&name, Matrix::from_vec(rows, cols, data));
        }
        Ok(out)
    }
}

/// Random initialization of the full parameter set for a config
/// (truncated-normal-ish scaled init, LN at identity).
pub fn init(cfg: &ModelConfig, rng: &mut Pcg32) -> Weights {
    let d = cfg.d_model;
    let ff = cfg.d_ff();
    let std = 0.02f32;
    let proj_std = std / (2.0 * cfg.n_layers as f32).sqrt();
    let mut w = Weights::new();
    w.set("embed.tok", Matrix::randn(cfg.vocab, d, std, rng));
    w.set("embed.pos", Matrix::randn(cfg.max_seq, d, std, rng));
    for b in 0..cfg.n_layers {
        let p = |s: &str| format!("block{b}.{s}");
        w.set(&p("ln1.g"), Matrix::from_fn(1, d, |_, _| 1.0));
        w.set(&p("ln1.b"), Matrix::zeros(1, d));
        w.set(&p("attn.wq"), Matrix::randn(d, d, std, rng));
        w.set(&p("attn.wk"), Matrix::randn(d, d, std, rng));
        w.set(&p("attn.wv"), Matrix::randn(d, d, std, rng));
        w.set(&p("attn.wo"), Matrix::randn(d, d, proj_std, rng));
        w.set(&p("ln2.g"), Matrix::from_fn(1, d, |_, _| 1.0));
        w.set(&p("ln2.b"), Matrix::zeros(1, d));
        w.set(&p("mlp.fc1"), Matrix::randn(d, ff, std, rng));
        w.set(&p("mlp.fc1_b"), Matrix::zeros(1, ff));
        w.set(&p("mlp.fc2"), Matrix::randn(ff, d, proj_std, rng));
        w.set(&p("mlp.fc2_b"), Matrix::zeros(1, d));
    }
    w.set("final_ln.g", Matrix::from_fn(1, d, |_, _| 1.0));
    w.set("final_ln.b", Matrix::zeros(1, d));
    w
}

/// The canonical tensor ordering used by the AOT artifacts: the python side
/// declares the same order in `model.py::param_order`, so Rust can marshal
/// `Weights` → positional HLO arguments.
pub fn param_order(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec!["embed.tok".to_string(), "embed.pos".to_string()];
    for b in 0..cfg.n_layers {
        for s in [
            "ln1.g", "ln1.b", "attn.wq", "attn.wk", "attn.wv", "attn.wo", "ln2.g", "ln2.b",
            "mlp.fc1", "mlp.fc1_b", "mlp.fc2", "mlp.fc2_b",
        ] {
            names.push(format!("block{b}.{s}"));
        }
    }
    names.push("final_ln.g".to_string());
    names.push("final_ln.b".to_string());
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::by_name;

    #[test]
    fn init_has_all_ordered_params() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        for name in param_order(&cfg) {
            assert!(w.get(&name).is_some(), "missing {name}");
        }
        assert_eq!(w.len(), param_order(&cfg).len());
    }

    #[test]
    fn param_count_matches_config() {
        let cfg = by_name("sim-350m").unwrap();
        let mut rng = Pcg32::seeded(2);
        let w = init(&cfg, &mut rng);
        assert_eq!(w.param_count(), cfg.param_count());
    }

    #[test]
    fn save_load_round_trip() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(3);
        let w = init(&cfg, &mut rng);
        let path = std::env::temp_dir().join("slim_test_ckpt.bin");
        w.save(&path).unwrap();
        let loaded = Weights::load(&path).unwrap();
        assert_eq!(loaded.len(), w.len());
        for (name, m) in w.iter() {
            assert_eq!(loaded.expect(name), m, "{name}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join("slim_bad_magic.bin");
        std::fs::write(&path, b"NOTSLIMW....").unwrap();
        assert!(Weights::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn set_replaces() {
        let mut w = Weights::new();
        w.set("a", Matrix::zeros(2, 2));
        w.set("a", Matrix::eye(3));
        assert_eq!(w.len(), 1);
        assert_eq!(w.expect("a").shape(), (3, 3));
    }
}
