//! Model definitions: the sim transformer family, weight containers,
//! the native forward pass, and size/FLOP accounting.

pub mod attention;
pub mod compiled;
pub mod config;
pub mod sample;
pub mod size;
pub mod transformer;
pub mod weights;

pub use attention::{page_rows_for, AttnSpan, KvDtype, KvLayout, KvSlab, KvSource, PAGE_ROWS};
pub use compiled::CompressedWeights;
pub use config::{by_name, family, quick_family, ModelConfig};
pub use sample::{SampleParams, Sampler};
pub use transformer::{
    forward, forward_cached, forward_slots, greedy_pick, nll, prefix_page_hashes, ActivationTap,
    Batch, KvCache, KvCachePool, KvPageStats, Linears, Overrides,
};
pub use weights::{init, param_order, Weights};

use crate::compress::{compress_layer, CompressConfig, CompressedLayer, LayerCalib};
use std::collections::HashMap;

/// A fully compressed model: per-layer compression results + the override
/// map for evaluation.
pub struct CompressedModel {
    pub layers: HashMap<String, CompressedLayer>,
    pub overrides: Overrides,
}

/// Compress every linear layer of a model given per-layer calibration taps.
pub fn compress_model(
    cfg: &ModelConfig,
    w: &Weights,
    taps: &ActivationTap,
    ccfg: &CompressConfig,
) -> CompressedModel {
    let mut layers = HashMap::new();
    let mut overrides = Overrides::new();
    for (name, d_in, _d_out) in cfg.linear_layers() {
        let calib = match taps.get(&name) {
            Some(x) => LayerCalib::from_activations(x.clone()),
            None => LayerCalib::uniform(d_in),
        };
        let out = compress_layer(w.expect(&name), &calib, ccfg);
        overrides.insert(name.clone(), out.effective());
        layers.insert(name, out);
    }
    CompressedModel { layers, overrides }
}

/// JSQ has its own joint loop; compress a model with it.
pub fn compress_model_jsq(
    cfg: &ModelConfig,
    w: &Weights,
    taps: &ActivationTap,
    bits: u8,
    pattern: crate::sparse::SparsityPattern,
) -> CompressedModel {
    let mut layers = HashMap::new();
    let mut overrides = Overrides::new();
    for (name, d_in, d_out) in cfg.linear_layers() {
        let calib = match taps.get(&name) {
            Some(x) => LayerCalib::from_activations(x.clone()),
            None => LayerCalib::uniform(d_in),
        };
        let (wc, mask) =
            crate::compress::jsq::compress(w.expect(&name), &calib.x_l2, bits, pattern);
        let e_final = wc.sub(w.expect(&name)).fro_norm_sq();
        let layer = CompressedLayer {
            wc: wc.clone(),
            mask,
            adapters: None,
            e_quant: 0.0,
            e_sparse: 0.0,
            e_final,
            bits,
            scales: vec![],
            group_size: 0,
        };
        overrides.insert(name.clone(), wc);
        layers.insert(name, layer);
        let _ = d_out;
    }
    CompressedModel { layers, overrides }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::sparse::SparsityPattern;

    #[test]
    fn compress_model_covers_all_layers() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model(
            &cfg,
            &w,
            &taps,
            &CompressConfig::slim(SparsityPattern::TWO_FOUR),
        );
        assert_eq!(cm.layers.len(), 6 * cfg.n_layers);
        // Compressed model still produces finite logits.
        let logits = forward(&cfg, &w, &batch, None, Some(&cm.overrides));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn jsq_model_compression() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(2);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model_jsq(&cfg, &w, &taps, 4, SparsityPattern::TWO_FOUR);
        for (name, layer) in &cm.layers {
            assert!(layer.mask.satisfies_nofm(2, 4), "{name}");
        }
    }
}
