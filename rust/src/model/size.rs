//! Parameter-size, memory-reduction and FLOP-reduction accounting
//! (paper Apx L Eq. 12, Apx M Eq. 13, and Figure 2's x-axis).
//!
//! The paper's closed forms, with `d` = hidden dim, `n` = blocks, `V` =
//! vocab, `a` = MLP up/down ratio, `r` = adapter rank ratio:
//!
//! ```text
//! mem ratio  = [n(4d² + 2d²a) + dV]
//!            / [n(4d²/2 + 4·2d²r + 2d²a/2 + 2d(dr + dra)) + dV]   (Eq. 12)
//! flop ratio = same structure over b-batched matmuls                (Eq. 13)
//! ```
//!
//! We additionally provide exact byte-level accounting for our sim models
//! (used for the Figure 2 Pareto x-axis), which includes scales, masks and
//! adapter bit-widths.

use super::config::ModelConfig;

/// Compression scheme descriptor for size accounting.
#[derive(Clone, Copy, Debug)]
pub struct SizeSpec {
    /// Weight bits (4, 2, or 16/32 for none).
    pub weight_bits: f64,
    /// Kept fraction after pruning (0.5 for 50%; 1.0 dense).
    pub density: f64,
    /// Adapter rank ratio r (0 = no adapters).
    pub rank_ratio: f64,
    /// Adapter bits (16 for fp16 adapters, 4 for quantized; ignored if r=0).
    pub adapter_bits: f64,
    /// 2:4 metadata overhead (2 bits per kept element) — true for
    /// semi-structured sparse storage.
    pub two_four_metadata: bool,
}

impl SizeSpec {
    pub fn dense() -> Self {
        SizeSpec {
            weight_bits: 16.0,
            density: 1.0,
            rank_ratio: 0.0,
            adapter_bits: 16.0,
            two_four_metadata: false,
        }
    }

    /// The paper's SLiM config: 4-bit, 50% 2:4, r=0.1, fp16 adapters.
    pub fn slim(quantize_adapters: bool) -> Self {
        SizeSpec {
            weight_bits: 4.0,
            density: 0.5,
            rank_ratio: 0.1,
            adapter_bits: if quantize_adapters { 4.0 } else { 16.0 },
            two_four_metadata: true,
        }
    }
}

/// Paper Eq. 12 — memory ratio (compressed / dense); lower is better.
///
/// The paper's equation assumes 4-bit weights (the /2 terms are vs fp16…
/// actually 2× from sparsity and implicit 4× from bits folded as in the
/// paper's table); we parameterize it faithfully: each linear's cost is
/// `bits/16 × density` of its dense fp16 cost, adapters cost
/// `2·d·(dr + dra)`-style terms at their own bits.
pub fn memory_ratio_eq12(cfg: &ModelConfig, spec: &SizeSpec) -> f64 {
    let d = cfg.d_model as f64;
    let n = cfg.n_layers as f64;
    let v = cfg.vocab as f64;
    let a = cfg.d_ff_ratio as f64;
    let r = spec.rank_ratio;

    // Dense numerator: attention 4d² + MLP 2d²a per block, plus embeddings.
    let dense = n * (4.0 * d * d + 2.0 * d * d * a) + d * v;

    // Compressed weights: bits/16 × density of each linear.
    let wfrac = spec.weight_bits / 16.0 * spec.density
        + if spec.two_four_metadata { 2.0 / 16.0 * spec.density } else { 0.0 };
    let base = n * (4.0 * d * d + 2.0 * d * d * a) * wfrac;
    // Adapters: attention side 4 matrices of 2·d·(dr); MLP side L∈d×(dr·?),
    // following Eq. 12's 2d(dr + dra) per block times adapter bits.
    let afrac = spec.adapter_bits / 16.0;
    let adapters = if r > 0.0 {
        n * (4.0 * 2.0 * d * d * r + 2.0 * d * (d * r + d * r * a)) * afrac
    } else {
        0.0
    };
    let compressed = base + adapters + d * v; // embeddings stay fp16
    compressed / dense
}

/// Paper Eq. 13 — FLOP ratio (dense / compressed); higher is better.
/// Quantization does not reduce FLOPs (computation stays floating point,
/// per Apx M); sparsity halves the matmul FLOPs; adapters add theirs.
pub fn flop_reduction_eq13(cfg: &ModelConfig, spec: &SizeSpec) -> f64 {
    let d = cfg.d_model as f64;
    let n = cfg.n_layers as f64;
    let v = cfg.vocab as f64;
    let a = cfg.d_ff_ratio as f64;
    let r = spec.rank_ratio;

    let dense = n * (4.0 * d * d + 2.0 * d * d * a) + d * v;
    let base = n * (4.0 * d * d + 2.0 * d * d * a) * spec.density;
    let adapters = if r > 0.0 {
        n * (4.0 * 2.0 * d * d * r + 2.0 * (d * d * r + d * d * r * a))
    } else {
        0.0
    };
    let compressed = base + adapters + d * v;
    dense / compressed
}

/// Exact storage bytes of a compressed sim model (Figure 2 x-axis).
pub fn model_bytes(cfg: &ModelConfig, spec: &SizeSpec) -> u64 {
    let mut bits = 0.0f64;
    // Embeddings (+positions) stay fp16.
    bits += ((cfg.vocab + cfg.max_seq) * cfg.d_model) as f64 * 16.0;
    for (_, d_in, d_out) in cfg.linear_layers() {
        let numel = (d_in * d_out) as f64;
        bits += numel * spec.density * spec.weight_bits;
        if spec.two_four_metadata {
            bits += numel * spec.density * 2.0; // 2-bit index per kept elem
        }
        // group scales: one fp16 per 128 elements when quantized
        if spec.weight_bits < 16.0 {
            bits += numel / 128.0 * 16.0;
        }
        if spec.rank_ratio > 0.0 {
            let rank = (d_in.min(d_out) as f64 * spec.rank_ratio).round();
            bits += (d_in as f64 + d_out as f64) * rank * spec.adapter_bits;
        }
    }
    // LN params fp16.
    bits += (cfg.n_layers * 4 * cfg.d_model + 2 * cfg.d_model) as f64 * 16.0;
    (bits / 8.0).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::by_name;

    #[test]
    fn eq12_matches_paper_ballpark() {
        // Paper Table 19: SLiM-LoRA + SLiM-Quant ≈ 0.29–0.31 for large
        // models; SLiM-LoRA^Q ≈ 0.18–0.19; Wanda+AbsMax ≈ 0.14–0.15.
        let cfg = by_name("sim-llama-7b").unwrap();
        let slim = memory_ratio_eq12(&cfg, &SizeSpec::slim(false));
        let slim_q = memory_ratio_eq12(&cfg, &SizeSpec::slim(true));
        let wanda = memory_ratio_eq12(
            &cfg,
            &SizeSpec { rank_ratio: 0.0, ..SizeSpec::slim(false) },
        );
        assert!((0.2..0.45).contains(&slim), "slim {slim}");
        assert!((0.1..0.3).contains(&slim_q), "slim_q {slim_q}");
        assert!(wanda < slim_q, "wanda {wanda} must be smallest");
        assert!(slim_q < slim);
    }

    #[test]
    fn eq13_flop_ordering() {
        // Paper Table 20: pruned-only ≈ 1.95×, with adapters ≈ 1.49×.
        let cfg = by_name("sim-llama-7b").unwrap();
        let no_adapter =
            flop_reduction_eq13(&cfg, &SizeSpec { rank_ratio: 0.0, ..SizeSpec::slim(false) });
        let with_adapter = flop_reduction_eq13(&cfg, &SizeSpec::slim(false));
        assert!(no_adapter > with_adapter);
        assert!(no_adapter > 1.4 && no_adapter < 2.05, "{no_adapter}");
        assert!(with_adapter > 1.1, "{with_adapter}");
    }

    #[test]
    fn dense_ratios_are_identity() {
        let cfg = by_name("sim-125m").unwrap();
        let m = memory_ratio_eq12(&cfg, &SizeSpec::dense());
        let f = flop_reduction_eq13(&cfg, &SizeSpec::dense());
        assert!((m - 1.0).abs() < 1e-9);
        assert!((f - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_shrink_with_compression() {
        let cfg = by_name("sim-1.3b").unwrap();
        let dense = model_bytes(&cfg, &SizeSpec::dense());
        let slim = model_bytes(&cfg, &SizeSpec::slim(false));
        let slim_q = model_bytes(&cfg, &SizeSpec::slim(true));
        assert!(slim < dense);
        assert!(slim_q < slim);
    }

    #[test]
    fn smaller_models_less_reduction() {
        // Embeddings dominate small models → less relative reduction,
        // exactly the trend in paper Table 19 (0.50 at 125M → 0.30 at 13B).
        let small = by_name("sim-125m").unwrap();
        let large = by_name("sim-13b").unwrap();
        let rs = memory_ratio_eq12(&small, &SizeSpec::slim(false));
        let rl = memory_ratio_eq12(&large, &SizeSpec::slim(false));
        assert!(rs > rl, "small {rs} vs large {rl}");
    }
}
