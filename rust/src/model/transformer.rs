//! Native Rust transformer forward pass.
//!
//! A decoder-only pre-LN transformer matching `python/compile/model.py`
//! op-for-op (LN ε, tanh-GELU, causal softmax, tied embeddings), so the AOT
//! path can be validated against this one. Used for:
//!
//! * calibration — capturing the input activations of every linear layer,
//! * evaluation fallbacks and tests,
//! * the compressed-model accuracy path (effective weights substituted).

use std::collections::HashMap;

use super::config::ModelConfig;
use super::weights::Weights;
use crate::tensor::{matmul_a_bt, Matrix};

/// LayerNorm epsilon (matches jax default in model.py).
pub const LN_EPS: f32 = 1e-5;

/// tanh-approximated GELU (jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise LayerNorm with gain/bias (1 × d each).
pub fn layernorm(x: &Matrix, g: &Matrix, b: &Matrix) -> Matrix {
    let (rows, d) = x.shape();
    assert_eq!(g.cols(), d);
    let mut out = Matrix::zeros(rows, d);
    for i in 0..rows {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let orow = out.row_mut(i);
        for j in 0..d {
            orow[j] = (row[j] - mean) * inv * g.get(0, j) + b.get(0, j);
        }
    }
    out
}

/// In-place numerically-stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Token batch: `tokens[b][s]`, all rows of length `seq`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn new(tokens: Vec<u32>, batch: usize, seq: usize) -> Self {
        assert_eq!(tokens.len(), batch * seq);
        Batch { tokens, batch, seq }
    }

    #[inline]
    pub fn tok(&self, b: usize, s: usize) -> u32 {
        self.tokens[b * self.seq + s]
    }
}

/// Optional hook to capture the inputs to each linear layer (for
/// calibration). Keyed by layer name (`block0.attn.wq`, …); values are the
/// activation matrices fed to that weight.
pub type ActivationTap = HashMap<String, Matrix>;

/// Weight-override map: layer name → effective weight (used to evaluate
/// compressed models without materializing a full `Weights` clone).
pub type Overrides = HashMap<String, Matrix>;

/// Forward pass producing logits `[(batch·seq) × vocab]`.
///
/// * `taps` — if `Some`, records the input activations of every linear.
/// * `overrides` — replaces named linear weights (compressed eval).
pub fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
) -> Matrix {
    forward_iq(cfg, w, batch, taps, overrides, crate::quant::fp8::InputQuant::None)
}

/// [`forward`] with activation (input) quantization applied to the inputs
/// of every linear layer — the paper's Apx B evaluation mode.
pub fn forward_iq(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    mut taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
    iq: crate::quant::fp8::InputQuant,
) -> Matrix {
    use crate::quant::fp8::quantize_input;
    let d = cfg.d_model;
    let n = batch.batch * batch.seq;
    assert!(batch.seq <= cfg.max_seq, "seq {} > max {}", batch.seq, cfg.max_seq);
    let pick = |name: &str| -> &Matrix {
        if let Some(ov) = overrides {
            if let Some(m) = ov.get(name) {
                return m;
            }
        }
        w.expect(name)
    };

    // Embedding lookup + learned positions.
    let tok_emb = w.expect("embed.tok");
    let pos_emb = w.expect("embed.pos");
    let mut x = Matrix::zeros(n, d);
    for b in 0..batch.batch {
        for s in 0..batch.seq {
            let t = batch.tok(b, s) as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let row = x.row_mut(b * batch.seq + s);
            for j in 0..d {
                row[j] = tok_emb.get(t, j) + pos_emb.get(s, j);
            }
        }
    }

    let scale = 1.0 / (cfg.d_head() as f32).sqrt();
    for blk in 0..cfg.n_layers {
        let p = |s: &str| format!("block{blk}.{s}");
        // ── Attention ────────────────────────────────────────────────
        let h = layernorm(&x, w.expect(&p("ln1.g")), w.expect(&p("ln1.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wq"), h.clone());
            t.insert(p("attn.wk"), h.clone());
            t.insert(p("attn.wv"), h.clone());
        }
        let hq = quantize_input(&h, iq);
        let q = hq.matmul(pick(&p("attn.wq")));
        let k = hq.matmul(pick(&p("attn.wk")));
        let v = hq.matmul(pick(&p("attn.wv")));
        let mut ctx = Matrix::zeros(n, d);
        let dh = cfg.d_head();
        for b in 0..batch.batch {
            let base = b * batch.seq;
            for head in 0..cfg.n_heads {
                let c0 = head * dh;
                for s in 0..batch.seq {
                    // Causal scores over positions 0..=s.
                    let qrow = &q.row(base + s)[c0..c0 + dh];
                    let mut scores = vec![0.0f32; s + 1];
                    for (t, sc) in scores.iter_mut().enumerate() {
                        let krow = &k.row(base + t)[c0..c0 + dh];
                        let mut dot = 0.0f32;
                        for (a, b2) in qrow.iter().zip(krow.iter()) {
                            dot += a * b2;
                        }
                        *sc = dot * scale;
                    }
                    softmax_inplace(&mut scores);
                    let crow = ctx.row_mut(base + s);
                    for (t, &pr) in scores.iter().enumerate() {
                        let vrow = &v.row(base + t)[c0..c0 + dh];
                        for j in 0..dh {
                            crow[c0 + j] += pr * vrow[j];
                        }
                    }
                }
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wo"), ctx.clone());
        }
        let attn_out = quantize_input(&ctx, iq).matmul(pick(&p("attn.wo")));
        x = x.add(&attn_out);

        // ── MLP ──────────────────────────────────────────────────────
        let h2 = layernorm(&x, w.expect(&p("ln2.g")), w.expect(&p("ln2.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc1"), h2.clone());
        }
        let mut u = quantize_input(&h2, iq).matmul(pick(&p("mlp.fc1")));
        let b1 = w.expect(&p("mlp.fc1_b"));
        for i in 0..n {
            let row = u.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 = gelu(*v2 + b1.get(0, j));
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc2"), u.clone());
        }
        let mut mlp_out = quantize_input(&u, iq).matmul(pick(&p("mlp.fc2")));
        let b2 = w.expect(&p("mlp.fc2_b"));
        for i in 0..n {
            let row = mlp_out.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 += b2.get(0, j);
            }
        }
        x = x.add(&mlp_out);
    }

    // Final LN + tied-embedding logits.
    let xf = layernorm(&x, w.expect("final_ln.g"), w.expect("final_ln.b"));
    matmul_a_bt(&xf, tok_emb)
}

/// Mean next-token negative log-likelihood over the batch (positions
/// 0..seq-1 predict 1..seq).
pub fn nll(cfg: &ModelConfig, logits: &Matrix, batch: &Batch) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..batch.batch {
        for s in 0..batch.seq - 1 {
            let row = logits.row(b * batch.seq + s);
            let target = batch.tok(b, s + 1) as usize;
            // log-softmax at the target index.
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - row[target]) as f64;
            count += 1;
        }
    }
    let _ = cfg;
    total / count.max(1) as f64
}

/// Sum of log-probabilities the model assigns to `continuation` given
/// `prefix` (for the zero-shot likelihood-ranking tasks).
pub fn continuation_logprob(
    cfg: &ModelConfig,
    w: &Weights,
    prefix: &[u32],
    continuation: &[u32],
    overrides: Option<&Overrides>,
) -> f64 {
    let mut toks = prefix.to_vec();
    toks.extend_from_slice(continuation);
    let seq = toks.len().min(cfg.max_seq);
    let toks = &toks[toks.len() - seq..];
    let batch = Batch::new(toks.to_vec(), 1, seq);
    let logits = forward(cfg, w, &batch, None, overrides);
    let start = seq - continuation.len().min(seq);
    let mut lp = 0.0f64;
    for s in start..seq {
        if s == 0 {
            continue;
        }
        let row = logits.row(s - 1);
        let target = toks[s] as usize;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        lp += (row[target] - lse) as f64;
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::by_name;
    use crate::model::weights::init;
    use crate::rng::Pcg32;

    fn setup() -> (ModelConfig, Weights, Batch) {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..2 * 16).map(|_| rng.below(cfg.vocab as u32)).collect();
        (cfg.clone(), w, Batch::new(toks, 2, 16))
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        assert_eq!(logits.shape(), (32, cfg.vocab));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn untrained_nll_near_uniform() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let loss = nll(&cfg, &logits, &batch);
        let uniform = (cfg.vocab as f64).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn taps_capture_all_linear_inputs() {
        let (cfg, w, batch) = setup();
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        for (name, d_in, _) in cfg.linear_layers() {
            let x = taps.get(&name).unwrap_or_else(|| panic!("missing tap {name}"));
            assert_eq!(x.cols(), d_in, "{name}");
            assert_eq!(x.rows(), 32);
        }
    }

    #[test]
    fn overrides_change_output() {
        let (cfg, w, batch) = setup();
        let base = forward(&cfg, &w, &batch, None, None);
        let mut ov = Overrides::new();
        ov.insert("block0.mlp.fc1".into(), Matrix::zeros(cfg.d_model, cfg.d_ff()));
        let changed = forward(&cfg, &w, &batch, None, Some(&ov));
        assert!(changed.rel_err(&base) > 1e-4);
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let mut toks2 = batch.tokens.clone();
        toks2[15] = (toks2[15] + 1) % cfg.vocab as u32; // last pos of sample 0
        let batch2 = Batch::new(toks2, 2, 16);
        let logits2 = forward(&cfg, &w, &batch2, None, None);
        for s in 0..14 {
            let a = logits.row(s);
            let b = logits2.row(s);
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-5, "pos {s} leaked");
            }
        }
    }

    #[test]
    fn continuation_logprob_is_negative_and_finite() {
        let (cfg, w, _) = setup();
        let lp = continuation_logprob(&cfg, &w, &[1, 2, 3], &[4, 5], None);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn gelu_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 1e4];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[3] > 0.99);
    }
}
