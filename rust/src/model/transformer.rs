//! Native Rust transformer forward pass.
//!
//! A decoder-only pre-LN transformer matching `python/compile/model.py`
//! op-for-op (LN ε, tanh-GELU, causal softmax, tied embeddings), so the AOT
//! path can be validated against this one. Used for:
//!
//! * calibration — capturing the input activations of every linear layer,
//! * evaluation fallbacks and tests,
//! * the compressed-model accuracy path (effective weights substituted).
//!
//! Two entry points:
//!
//! * [`forward`] — full forward over a whole batch (prefill / reference /
//!   calibration path).
//! * [`forward_slots`] — incremental forward over only the *new*
//!   position(s) of each sequence, attending over per-sequence cache slots
//!   in a [`KvCachePool`] — the continuous-batching serving path. Entries
//!   may mix span lengths (a prompt prefill batched with one-token decode
//!   steps of other sequences), and each sequence's logits are independent
//!   of its batchmates.
//! * [`forward_cached`] — equal-length wrapper over [`forward_slots`]
//!   through the lockstep [`KvCache`] view (benches, scoring, tests).
//!
//! Attention in every path runs through the single blocked implementation
//! in [`super::attention`] (`attend`): per-(sequence, head) Q·Kᵀ / P·V
//! tiles over contiguous cache stripes, threaded across spans×heads. The
//! K/V cache itself ([`KvCachePool`]) has a pluggable storage dtype
//! ([`KvDtype`]): f32 (bit-exact), f16 / bf16 half-precision rows at 2×
//! fewer cache bytes (near-f32 fidelity; attention reads the 16-bit codes
//! directly through its half fast path), or int8 / FP8-E4M3 quantized rows
//! at ~4× fewer cache bytes (quantized on write, dequantized block-wise
//! inside the attention kernel). Each slot is a **ring buffer** over
//! `max_seq` physical rows with a logical per-slot base: generation past
//! the context length overwrites the oldest retained position and rebases
//! the new token's position embedding to the window frame, keeping deep
//! decode O(1) per token (see the `KvCachePool` docs).
//!
//! Linear layers dispatch through [`Linears`], which can route matmuls to
//! packed compressed kernels ([`crate::kernels::LinearOp`]) instead of
//! dense f32 overrides.

use std::collections::HashMap;

use super::attention::{
    attend, page_rows_for, AttnSpan, KvDtype, KvLayout, KvSlab, KvSource, UNMAPPED,
};
use super::compiled::CompressedWeights;
use super::config::ModelConfig;
use super::weights::Weights;
use crate::tensor::{matmul_a_bt, Matrix};

pub use super::attention::softmax_inplace;

/// LayerNorm epsilon (matches jax default in model.py).
pub const LN_EPS: f32 = 1e-5;

/// tanh-approximated GELU (jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise LayerNorm with gain/bias (1 × d each).
pub fn layernorm(x: &Matrix, g: &Matrix, b: &Matrix) -> Matrix {
    let (rows, d) = x.shape();
    assert_eq!(g.cols(), d);
    let mut out = Matrix::zeros(rows, d);
    for i in 0..rows {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let orow = out.row_mut(i);
        for j in 0..d {
            orow[j] = (row[j] - mean) * inv * g.get(0, j) + b.get(0, j);
        }
    }
    out
}

/// Token batch: `tokens[b][s]`, all rows of length `seq`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn new(tokens: Vec<u32>, batch: usize, seq: usize) -> Self {
        assert_eq!(tokens.len(), batch * seq);
        Batch { tokens, batch, seq }
    }

    #[inline]
    pub fn tok(&self, b: usize, s: usize) -> u32 {
        self.tokens[b * self.seq + s]
    }
}

/// Optional hook to capture the inputs to each linear layer (for
/// calibration). Keyed by layer name (`block0.attn.wq`, …); values are the
/// activation matrices fed to that weight.
pub type ActivationTap = HashMap<String, Matrix>;

/// Weight-override map: layer name → effective weight (used to evaluate
/// compressed models without materializing a full `Weights` clone).
pub type Overrides = HashMap<String, Matrix>;

/// How a forward pass resolves each linear layer's matmul.
pub enum Linears<'a> {
    /// Plain dense weights from the [`Weights`] map.
    Dense,
    /// Dense effective-weight overrides (the accuracy-eval path).
    Overrides(&'a Overrides),
    /// Packed compressed kernels (the serving hot path).
    Kernels(&'a CompressedWeights),
}

impl Linears<'_> {
    /// `y = x · W(name)` through the configured backend; layers without an
    /// override/kernel entry fall back to the dense weight.
    pub fn apply(&self, w: &Weights, name: &str, x: &Matrix) -> Matrix {
        match self {
            Linears::Dense => x.matmul(w.expect(name)),
            Linears::Overrides(ov) => match ov.get(name) {
                Some(m) => x.matmul(m),
                None => x.matmul(w.expect(name)),
            },
            Linears::Kernels(cw) => match cw.get(name) {
                Some(op) => op.matmul(x),
                None => x.matmul(w.expect(name)),
            },
        }
    }
}

/// **Paged** per-layer K/V storage for continuous batching — the vLLM
/// PagedAttention design.
///
/// The pool owns one [`KvSlab`] pair (K and V) per layer, each a pool of
/// ref-counted physical **page frames** of [`page_rows_for`]`(max_seq)`
/// rows, stored in the pool's [`KvDtype`] (f32, f16/bf16, int8, or
/// FP8-E4M3 — quantized dtypes cut cache bytes 2–4×). A sequence slot is
/// a **page table**: logical position `L` resolves to physical row
/// `L % max_seq`, whose page `(L % max_seq) / page` maps to a frame. The
/// pool is the single refcount owner (frame mappings are mirrored into
/// every slab so the attention kernel reads through them without pool
/// access):
///
/// * **Allocation** is lazy and page-granular: [`KvCachePool::prepare_span`]
///   maps frames just before [`forward_slots`] writes a span. The frame
///   free-list is LIFO, so a sequence's frames are normally consecutive
///   and its windows read back as single contiguous runs (preserving the
///   zero-copy f32 / half-GEMM fast paths).
/// * **Sharing + copy-on-write:** frames may back pages of several slots
///   at once (`refs > 1`). Writing a shared page first splits it
///   ([`KvSlab::copy_frame`] into a fresh frame), so
///   [`KvCachePool::fork`] — a page-table copy plus refcount bumps — is
///   O(pages), and a fork's writes can never alter its parent's rows.
/// * **Prefix caching:** full prompt-prefix pages are content-addressed by
///   a chained token hash ([`prefix_page_hashes`]). When enabled
///   ([`KvCachePool::set_prefix_cache`] — serving routes only; off by
///   default), a new request whose windowed prompt prefix is already
///   resident maps the cached frames instead of re-prefilling them
///   ([`KvCachePool::lookup_prefix`]), so a cache hit skips that prefill
///   compute entirely. Retired frames stay resident (refs 0, still on the
///   free-list) until reallocation evicts their hash entry — lazy
///   eviction, so a shared system prompt survives request churn.
///
/// Each slot has its own cached length, so sequences of different lengths
/// coexist in one pool: a scheduler allocates a slot per admitted request
/// ([`KvCachePool::alloc`]), [`forward_slots`] appends new K/V rows and
/// attends over each slot's own prefix, and retiring a sequence unmaps its
/// pages and returns its slot to the free-list ([`KvCachePool::free`]) for
/// the next request — no lockstep batches, no left-padding.
///
/// ## Ring slots: logical vs physical positions
///
/// Slot lengths are **logical** — [`KvCachePool::len`] keeps growing past
/// `max_seq` as a sequence decodes. The stripes only hold the most recent
/// `window(slot) = min(len, max_seq)` positions: logical position `L`
/// lives at physical row `L % max_seq` (the default [`KvLayout::Ring`]),
/// so a write past the context length overwrites the oldest retained row
/// in O(1) and `base(slot) = len − window` is the logical index of the
/// oldest survivor. Deep decode therefore costs one quantized KV write
/// plus one attention pass over the (two-arc) window, never a re-prefill —
/// per-token latency is flat in generation depth. The [`KvLayout::Shift`]
/// layout implements the same window by memmoving rows (O(window) per
/// token) and is kept as the legacy sliding-window *cache* reference:
/// both layouts produce bit-identical attention inputs, which the
/// overflow greedy-equivalence tests assert. (The old overflow behavior —
/// re-prefilling the window every token — recomputed cached rows with
/// shifted positions; its post-overflow outputs are intentionally NOT
/// preserved, only its window contents. Pre-overflow decoding is
/// unchanged and still matches the full forward exactly.)
pub struct KvCachePool {
    k: Vec<KvSlab>,
    v: Vec<KvSlab>,
    n_slots: usize,
    max_seq: usize,
    dtype: KvDtype,
    layout: KvLayout,
    /// Logical positions appended per slot (may exceed `max_seq`; only the
    /// trailing `min(len, max_seq)` are retained in the mapped pages).
    lens: Vec<usize>,
    /// Slot occupancy (true between `alloc` and `free`).
    live: Vec<bool>,
    /// LIFO free-list, so retired slots are reused first.
    free_list: Vec<usize>,
    /// Rows per page frame (`page_rows_for(max_seq)`).
    page: usize,
    /// Page-table entries per slot (`⌈max_seq/page⌉`).
    pps: usize,
    /// Physical frames per layer slab (`n_slots · pps` — every slot can
    /// always map a private frame for each of its pages, so frame
    /// allocation can never fail while slot allocation succeeds; sharing
    /// only adds slack).
    n_frames: usize,
    /// Authoritative page tables, slot-major (`slot·pps + i`), mirrored
    /// into every slab. [`UNMAPPED`] = no frame.
    tables: Vec<u32>,
    /// Mappings per frame (table entries across all slots pointing at it).
    refs: Vec<u32>,
    /// LIFO frame free-list — frames with `refs == 0`. Retired
    /// prefix-cache frames stay here *and* hash-resident until reallocated
    /// (lazy eviction).
    free_frames: Vec<u32>,
    /// Content hash a frame is registered under in `hash_index`, if any.
    frame_hash: Vec<Option<u64>>,
    /// Prefix-cache index: chained page hash → resident frame.
    hash_index: HashMap<u64, u32>,
    /// Prefix lookup/registration gate — off by default (private pools,
    /// unit tests); serving schedulers turn it on per route.
    prefix_enabled: bool,
    /// Prefix-cache counters (cumulative; exported via `page_stats`).
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_evictions: u64,
    prefix_saved_tokens: u64,
}

/// Point-in-time page-pool occupancy + prefix-cache counters, for the
/// scheduler's metrics tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvPageStats {
    /// Physical frames per layer slab.
    pub pages_total: usize,
    /// Frames currently mapped by at least one slot.
    pub pages_used: usize,
    /// Frames mapped by more than one slot (prefix / fork sharing).
    pub pages_shared: usize,
    /// Admissions that mapped ≥ 1 resident prefix page.
    pub prefix_hits: u64,
    /// Admissions that found no resident prefix page.
    pub prefix_misses: u64,
    /// Hash entries dropped (reallocation or divergent write).
    pub prefix_evictions: u64,
    /// Prompt tokens whose prefill compute was skipped via prefix hits.
    pub prefix_saved_tokens: u64,
}

impl KvCachePool {
    /// Empty f32 pool with `slots` sequence slots, all free.
    pub fn new(cfg: &ModelConfig, slots: usize) -> Self {
        Self::with_dtype(cfg, slots, KvDtype::F32)
    }

    /// Empty ring pool storing cached K/V in `dtype`.
    pub fn with_dtype(cfg: &ModelConfig, slots: usize, dtype: KvDtype) -> Self {
        Self::with_layout(cfg, slots, dtype, KvLayout::Ring)
    }

    /// Empty pool with an explicit overflow layout ([`KvLayout::Shift`] is
    /// the slow reference; serving uses the default ring).
    pub fn with_layout(cfg: &ModelConfig, slots: usize, dtype: KvDtype, layout: KvLayout) -> Self {
        assert!(slots > 0, "KvCachePool needs at least one slot");
        let page = page_rows_for(cfg.max_seq);
        let pps = cfg.max_seq.div_ceil(page);
        let n_frames = slots * pps;
        let mk = || -> Vec<KvSlab> {
            (0..cfg.n_layers)
                .map(|_| {
                    KvSlab::paged(dtype, slots, cfg.max_seq, cfg.n_heads, cfg.d_head(), n_frames)
                })
                .collect()
        };
        KvCachePool {
            k: mk(),
            v: mk(),
            n_slots: slots,
            max_seq: cfg.max_seq,
            dtype,
            layout,
            lens: vec![0; slots],
            live: vec![false; slots],
            free_list: (0..slots).rev().collect(),
            page,
            pps,
            n_frames,
            tables: vec![UNMAPPED; slots * pps],
            refs: vec![0; n_frames],
            free_frames: (0..n_frames as u32).rev().collect(),
            frame_hash: vec![None; n_frames],
            hash_index: HashMap::new(),
            prefix_enabled: false,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_evictions: 0,
            prefix_saved_tokens: 0,
        }
    }

    /// Total slots in the pool.
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Storage dtype of the cached K/V rows.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Overflow layout of the slot stripes (ring, or the shift reference).
    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    /// Total bytes of K/V cache storage across all layers (codes + scales)
    /// — what the decode bench reports as cache traffic.
    pub fn cache_bytes(&self) -> usize {
        self.k.iter().map(KvSlab::bytes).sum::<usize>()
            + self.v.iter().map(KvSlab::bytes).sum::<usize>()
    }

    /// Layer `blk`'s (K, V) slabs, for the attention kernel.
    pub(crate) fn layer(&self, blk: usize) -> (&KvSlab, &KvSlab) {
        (&self.k[blk], &self.v[blk])
    }

    /// Slots currently free for admission.
    pub fn free_slots(&self) -> usize {
        self.free_list.len()
    }

    /// Maximum cacheable positions per slot (the model's context length).
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// Claim a free slot (empty, length 0, no pages mapped), or `None` if
    /// the pool is full.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free_list.pop()?;
        self.lens[slot] = 0;
        self.live[slot] = true;
        Some(slot)
    }

    /// Return a slot to the free-list, unmapping (and unreferencing) every
    /// page it held. Frames dropping to zero refs go back on the frame
    /// free-list but keep their prefix-hash registration until reallocated,
    /// so a later identical prompt can still revive them.
    pub fn free(&mut self, slot: usize) {
        assert!(self.live[slot], "free of non-live slot {slot}");
        self.unmap_slot(slot);
        self.live[slot] = false;
        self.free_list.push(slot);
    }

    /// Drop every page mapping of `slot` (refcounts decremented; slab
    /// tables cleared).
    fn unmap_slot(&mut self, slot: usize) {
        for idx in 0..self.pps {
            self.unmap_page(slot, idx);
        }
    }

    /// Unmap logical page `idx` of `slot`, if mapped.
    fn unmap_page(&mut self, slot: usize, idx: usize) {
        let e = slot * self.pps + idx;
        let f = self.tables[e];
        if f == UNMAPPED {
            return;
        }
        self.tables[e] = UNMAPPED;
        for slab in self.k.iter_mut().chain(self.v.iter_mut()) {
            slab.clear_page(slot, idx);
        }
        self.refs[f as usize] -= 1;
        if self.refs[f as usize] == 0 {
            self.free_frames.push(f);
        }
    }

    /// Map logical page `idx` of `slot` to `frame` in the authoritative
    /// table and every layer slab.
    fn map_page(&mut self, slot: usize, idx: usize, frame: u32) {
        self.tables[slot * self.pps + idx] = frame;
        for slab in self.k.iter_mut().chain(self.v.iter_mut()) {
            slab.set_page(slot, idx, frame);
        }
    }

    /// Pop a free frame. Reallocating a hash-resident frame evicts its
    /// prefix-cache entry (lazy eviction). Never fails: the pool holds
    /// `pps` frames per slot, so live slots can always map privately —
    /// sharing only adds slack.
    fn alloc_frame(&mut self) -> u32 {
        let f = self.free_frames.pop().expect("kv page pool exhausted");
        debug_assert_eq!(self.refs[f as usize], 0);
        self.unregister_frame(f);
        f
    }

    /// Drop frame `f`'s prefix-hash registration, if any (reallocation, or
    /// a refs==1 write about to diverge its contents).
    fn unregister_frame(&mut self, f: u32) {
        if let Some(h) = self.frame_hash[f as usize].take() {
            if self.hash_index.get(&h) == Some(&f) {
                self.hash_index.remove(&h);
            }
            self.prefix_evictions += 1;
        }
    }

    /// Make logical page `idx` of `slot` privately writable: map a fresh
    /// frame if unmapped, split via copy-on-write if shared, and
    /// unregister its hash if its contents are about to diverge.
    fn prepare_page(&mut self, slot: usize, idx: usize) {
        let f = self.tables[slot * self.pps + idx];
        if f == UNMAPPED {
            let nf = self.alloc_frame();
            self.refs[nf as usize] = 1;
            self.map_page(slot, idx, nf);
        } else if self.refs[f as usize] > 1 {
            let nf = self.alloc_frame();
            for slab in self.k.iter_mut().chain(self.v.iter_mut()) {
                slab.copy_frame(f as usize, nf as usize);
            }
            self.refs[f as usize] -= 1;
            self.refs[nf as usize] = 1;
            self.map_page(slot, idx, nf);
        } else if self.frame_hash[f as usize].is_some() {
            self.unregister_frame(f);
        }
    }

    /// Map / CoW-split every page a `span`-token append to `slot` will
    /// write, *before* [`forward_slots`] starts writing — the allocation
    /// edge of the paged pool. Shift-layout appends past capacity memmove
    /// every retained row, so they make all mapped pages writable first.
    pub(crate) fn prepare_span(&mut self, slot: usize, span: usize) {
        let p0 = self.lens[slot];
        if self.layout == KvLayout::Shift && p0 + span > self.max_seq {
            for idx in 0..self.pps {
                if self.tables[slot * self.pps + idx] != UNMAPPED {
                    self.prepare_page(slot, idx);
                }
            }
        }
        let mut prev = usize::MAX;
        for s in 0..span {
            let idx = ((p0 + s) % self.max_seq) / self.page;
            if idx != prev {
                self.prepare_page(slot, idx);
                prev = idx;
            }
        }
    }

    /// Logical positions appended to `slot` so far (keeps growing past
    /// `max_seq`; the stripes retain the trailing [`KvCachePool::window`]).
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Retained window size of `slot`: `min(len, max_seq)`.
    pub fn window(&self, slot: usize) -> usize {
        self.lens[slot].min(self.max_seq)
    }

    /// Logical position of the oldest retained row of `slot` (`0` until
    /// the ring wraps) — the per-slot base that position embeddings are
    /// rebased against.
    pub fn base(&self, slot: usize) -> usize {
        self.lens[slot] - self.window(slot)
    }

    /// Whether `slot` is currently allocated.
    pub fn is_live(&self, slot: usize) -> bool {
        self.live[slot]
    }

    /// Tokens that can still be appended to `slot` before an append would
    /// wrap the ring: `max_seq − len` while the slot is filling, 0 once
    /// full. Chunked prefill clamps its spans to this, so a multi-token
    /// continuation span never wraps (wrapping is reserved for the
    /// single-token decode steps, which overwrite exactly one retained
    /// row); during prefill the windowed prompt always fits, so the clamp
    /// only guards misuse.
    pub fn span_room(&self, slot: usize) -> usize {
        self.max_seq.saturating_sub(self.lens[slot])
    }

    /// Forget `slot`'s cached positions without freeing it (used by the
    /// legacy re-prefill baseline in `benches/decode.rs`; serving never
    /// resets — overflow wraps the ring instead). Unmaps the slot's pages;
    /// the next prefill maps fresh frames.
    pub fn reset_slot(&mut self, slot: usize) {
        self.unmap_slot(slot);
        self.lens[slot] = 0;
    }

    /// Rewind `slot` to `new_len` logical positions — the speculative-decode
    /// rollback primitive: after a batched verify span is appended, the
    /// rejected suffix is discarded by truncating back to the accepted
    /// length, and the next append overwrites the stale rows (re-encoding
    /// quantized dtypes row-by-row exactly as a first write would, since
    /// int8/fp8 scales live per physical row and are recomputed on every
    /// [`KvSlab::write_logical`]).
    ///
    /// Truncation is lossless only while no *discarded* position had
    /// wrapped the ring: once `len > max_seq`, physical row `L % max_seq`
    /// has been overwritten by logical position `L`, so rewinding past the
    /// wrap would resurrect rows that no longer exist. Callers guarantee
    /// this by clamping multi-token verify spans to [`span_room`]
    /// (`KvCachePool::span_room`) — the same invariant chunked prefill
    /// maintains — which keeps every speculative append, and therefore
    /// every rewind, inside the un-wrapped region. The no-op case
    /// (`new_len == len`) is always legal, wrapped or not.
    pub fn truncate(&mut self, slot: usize, new_len: usize) {
        assert!(self.live[slot], "truncate of non-live slot {slot}");
        assert!(
            new_len <= self.lens[slot],
            "truncate({slot}) cannot grow: {new_len} > {}",
            self.lens[slot]
        );
        assert!(
            new_len == self.lens[slot] || self.lens[slot] <= self.max_seq,
            "truncate({slot}) past the ring wrap would discard positions whose physical \
             rows were already overwritten (len {} > max_seq {})",
            self.lens[slot],
            self.max_seq
        );
        // Pages wholly past the new length are dropped (unmapped and, if
        // shared, simply unreferenced — a CoW sibling keeps the frame).
        // The boundary page is kept; re-appends CoW-split it if shared.
        if new_len < self.lens[slot] {
            for idx in new_len.div_ceil(self.page)..self.pps {
                self.unmap_page(slot, idx);
            }
        }
        self.lens[slot] = new_len;
    }

    /// Fork `src` into a fresh slot sharing every one of its pages — a
    /// page-table copy plus refcount bumps, no row copies. Writes on
    /// either side copy-on-write split the affected page, so neither
    /// sequence can ever alter the other's rows. Returns `None` if no
    /// slot is free.
    pub fn fork(&mut self, src: usize) -> Option<usize> {
        assert!(self.live[src], "fork of non-live slot {src}");
        let dst = self.free_list.pop()?;
        self.live[dst] = true;
        self.lens[dst] = self.lens[src];
        for idx in 0..self.pps {
            let f = self.tables[src * self.pps + idx];
            if f != UNMAPPED {
                self.refs[f as usize] += 1;
                self.map_page(dst, idx, f);
            }
        }
        Some(dst)
    }

    /// Attention geometry for appending a `span`-token entry to `slot`:
    /// `(p0, start)` where `p0` is the number of retained window positions
    /// preceding the span's first query and `start` is the physical row of
    /// the window's oldest position after the span is written.
    pub(crate) fn span_geometry(&self, slot: usize, span: usize) -> (usize, usize) {
        let w = (self.lens[slot] + span).min(self.max_seq);
        let start = match self.layout {
            KvLayout::Shift => 0,
            KvLayout::Ring => (self.lens[slot] + span - w) % self.max_seq,
        };
        (w - span, start)
    }

    /// Write (and, for quantized dtypes, encode) one freshly computed K/V
    /// row for layer `blk` at *logical* position `pos` of `slot` — wraps
    /// (or shifts) past `max_seq` per the pool layout.
    fn write(&mut self, blk: usize, slot: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        self.k[blk].write_logical(slot, pos, krow, self.layout);
        self.v[blk].write_logical(slot, pos, vrow, self.layout);
    }

    /// Rows per page frame.
    pub fn page_rows(&self) -> usize {
        self.page
    }

    /// Page-table entries per slot.
    pub fn pages_per_slot(&self) -> usize {
        self.pps
    }

    /// Enable / disable the prefix cache (off by default; serving
    /// schedulers turn it on for non-speculative routes).
    pub fn set_prefix_cache(&mut self, on: bool) {
        self.prefix_enabled = on;
    }

    /// Whether prefix lookup / registration is active.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_enabled
    }

    /// Map every *leading* page of `hashes` that is already resident into
    /// freshly-allocated `slot` (which must be empty) and advance its
    /// length past them — the prefill compute for those tokens is skipped
    /// entirely. `hashes` come from [`prefix_page_hashes`] over the
    /// windowed prompt; the caller caps the slice so at least one prompt
    /// token remains to feed (the completing forward needs a query row).
    /// Returns the number of prompt tokens satisfied from cache.
    pub fn lookup_prefix(&mut self, slot: usize, hashes: &[u64]) -> usize {
        if !self.prefix_enabled {
            return 0;
        }
        assert!(self.live[slot] && self.lens[slot] == 0, "prefix lookup needs a fresh slot");
        let mut matched = 0;
        for (idx, h) in hashes.iter().enumerate() {
            let Some(&f) = self.hash_index.get(h) else { break };
            if self.refs[f as usize] == 0 {
                // Revive a retired frame off the free-list.
                let at = self.free_frames.iter().rposition(|&x| x == f).unwrap();
                self.free_frames.swap_remove(at);
            }
            self.refs[f as usize] += 1;
            self.map_page(slot, idx, f);
            matched += 1;
        }
        if matched > 0 {
            self.prefix_hits += 1;
            self.prefix_saved_tokens += (matched * self.page) as u64;
        } else {
            self.prefix_misses += 1;
        }
        self.lens[slot] = matched * self.page;
        self.lens[slot]
    }

    /// Register `slot`'s leading pages (full windowed-prompt pages only —
    /// the caller hashes exactly those) in the prefix-cache index, called
    /// once when a prefill completes its prompt. Pages already registered,
    /// or whose hash another frame holds, are skipped.
    pub fn register_prefix(&mut self, slot: usize, hashes: &[u64]) {
        if !self.prefix_enabled {
            return;
        }
        for (idx, &h) in hashes.iter().enumerate() {
            let f = self.tables[slot * self.pps + idx];
            if f == UNMAPPED {
                break;
            }
            if self.frame_hash[f as usize].is_some() || self.hash_index.contains_key(&h) {
                continue;
            }
            self.frame_hash[f as usize] = Some(h);
            self.hash_index.insert(h, f);
        }
    }

    /// Occupancy + prefix-cache counters for the metrics exporters.
    pub fn page_stats(&self) -> KvPageStats {
        KvPageStats {
            pages_total: self.n_frames,
            pages_used: self.refs.iter().filter(|&&r| r > 0).count(),
            pages_shared: self.refs.iter().filter(|&&r| r > 1).count(),
            prefix_hits: self.prefix_hits,
            prefix_misses: self.prefix_misses,
            prefix_evictions: self.prefix_evictions,
            prefix_saved_tokens: self.prefix_saved_tokens,
        }
    }

    /// Leak check: every frame's refcount equals the number of live-slot
    /// table entries mapping it, and the frame free-list holds exactly the
    /// zero-ref frames. Cheap enough for a per-shutdown `debug_assert!`.
    pub fn refs_balanced(&self) -> bool {
        let mut counts = vec![0u32; self.n_frames];
        for (e, &f) in self.tables.iter().enumerate() {
            if f != UNMAPPED {
                if !self.live[e / self.pps] {
                    return false;
                }
                counts[f as usize] += 1;
            }
        }
        counts == self.refs
            && self.free_frames.len() == self.refs.iter().filter(|&&r| r == 0).count()
    }

    /// Assert the pool is fully quiescent — no live slots, every frame
    /// refcount back at zero, every slot and frame on its free-list. The
    /// leak check the property suites run after all sequences retire.
    pub fn assert_quiescent(&self) {
        assert!(!self.live.iter().any(|&l| l), "quiescent pool has live slots");
        assert!(self.refs.iter().all(|&r| r == 0), "quiescent pool has referenced frames");
        assert_eq!(self.free_frames.len(), self.n_frames, "frame leak: free-list short");
        assert_eq!(self.free_list.len(), self.n_slots, "slot leak: free-list short");
        assert!(self.refs_balanced(), "refcounts out of balance");
    }
}

/// Chained content hash of each successive *full* `page`-row block of
/// `tokens` (FNV-1a over the token bytes, carried across pages) — the
/// prefix-cache key. Page `i`'s hash commits to every token in pages
/// `0..=i`, so equal hashes ⇒ equal windowed token prefixes ⇒ equal K/V
/// rows (rows depend only on window-relative positions and the tokens at
/// or before them, regardless of the chunk schedule that fed them).
pub fn prefix_page_hashes(tokens: &[u32], page: usize) -> Vec<u64> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut out = Vec::with_capacity(tokens.len() / page);
    for (i, &t) in tokens.iter().enumerate() {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        if (i + 1) % page == 0 {
            out.push(h);
        }
    }
    out
}

/// Fixed-batch KV cache: `batch` pool slots advanced in lockstep.
///
/// Kept as the simple API for equal-length batched decode ([`forward_cached`],
/// `Engine::score`, benches); it is now a thin view over a [`KvCachePool`]
/// whose slots `0..batch` all hold the same number of positions.
pub struct KvCache {
    pool: KvCachePool,
    batch: usize,
}

impl KvCache {
    /// Empty f32 cache for `batch` concurrent sequences.
    pub fn new(cfg: &ModelConfig, batch: usize) -> Self {
        Self::with_dtype(cfg, batch, KvDtype::F32)
    }

    /// Empty ring cache storing K/V in `dtype`.
    pub fn with_dtype(cfg: &ModelConfig, batch: usize, dtype: KvDtype) -> Self {
        Self::with_layout(cfg, batch, dtype, KvLayout::Ring)
    }

    /// Empty cache with an explicit overflow layout (see
    /// [`KvCachePool::with_layout`]).
    pub fn with_layout(cfg: &ModelConfig, batch: usize, dtype: KvDtype, layout: KvLayout) -> Self {
        assert!(batch > 0, "KvCache needs at least one sequence");
        let mut pool = KvCachePool::with_layout(cfg, batch, dtype, layout);
        for _ in 0..batch {
            pool.alloc().unwrap();
        }
        KvCache { pool, batch }
    }

    /// The backing pool (cache-byte accounting for benches).
    pub fn pool(&self) -> &KvCachePool {
        &self.pool
    }

    /// Logical positions appended so far (may exceed `capacity()` once the
    /// ring has wrapped; the stripes retain the trailing window).
    pub fn len(&self) -> usize {
        self.pool.len(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of concurrent sequences.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Maximum cacheable positions (the model's context length).
    pub fn capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Forget all cached positions (rows are overwritten by later appends).
    pub fn reset(&mut self) {
        for slot in 0..self.batch {
            self.pool.reset_slot(slot);
        }
    }
}

/// Incremental forward pass over per-sequence cache slots — the serving
/// hot path for continuous batching.
///
/// `seqs` is a list of `(slot, new_tokens)` entries: each sequence feeds
/// its own span of new tokens (any length ≥ 1), occupying logical
/// positions `pool.len(slot) .. pool.len(slot) + new_tokens.len()` within
/// its slot. Mixed spans are fine — a prompt-prefill chunk can share one
/// batched pass with single-token decode steps of other sequences, which
/// keeps the compressed kernels saturated across request churn. A
/// multi-token span may start at any logical base below `max_seq` — this
/// is what chunked prefill builds on: feeding a prompt as successive
/// continuation spans writes exactly the K/V rows a one-shot span would
/// (quantize-on-write is per row), and each query row attends over the
/// same logical prefix in the same order, so the per-position logits are
/// bit-identical to the one-shot pass for every chunk schedule (see
/// `chunked_continuation_spans_match_oneshot` below and
/// `tests/property.rs`). Callers size chunks with
/// [`KvCachePool::span_room`] so a span never crosses the wrap boundary.
/// Returns logits for the new positions only, rows packed in `seqs` order
/// (entry `i`'s rows start at the sum of earlier entries' span lengths).
///
/// Logical positions may exceed `max_seq`: the write wraps the slot's ring
/// (overwriting the oldest retained position) and the token's learned
/// position embedding is **rebased** to its window-relative index at write
/// time, `L − base = min(L, max_seq − 1)` — every post-overflow token
/// embeds at the window's last position, while retained rows keep their
/// write-time embeddings (cached K/V is never recomputed, so causal order
/// comes from the attention mask, not from re-embedding). This is the
/// standard cached sliding-window trade-off: post-overflow logits
/// *differ* from the deleted re-prefill path, which re-embedded the whole
/// window each token at O(window) cost — the semantics are pinned instead
/// by the bit-identical [`KvLayout::Shift`] reference (see the
/// [`KvCachePool`] docs). Context overflow therefore costs one KV write
/// plus one window pass, never a re-prefill. Only single-token spans may
/// wrap (a longer span would overwrite history its own earlier rows still
/// attend to); prompt prefills always fit because callers window prompts
/// to `max_seq`.
///
/// Every per-sequence computation (embedding offsets, causal attention over
/// the slot's own prefix, LN/MLP rows) is independent of the other entries,
/// so greedy decoding through this function is batching-invariant: a
/// sequence produces bit-identical logits whether it runs solo or packed
/// with arbitrary other sequences.
pub fn forward_slots(
    cfg: &ModelConfig,
    w: &Weights,
    seqs: &[(usize, &[u32])],
    pool: &mut KvCachePool,
    linears: &Linears,
) -> Matrix {
    assert!(!seqs.is_empty(), "forward_slots needs at least one sequence");
    let d = cfg.d_model;
    // Row base of each entry within the packed activation matrix.
    let mut bases = Vec::with_capacity(seqs.len());
    let mut n = 0usize;
    for (slot, toks) in seqs {
        assert!(*slot < pool.n_slots, "slot {slot} out of range");
        assert!(pool.live[*slot], "slot {slot} not allocated");
        assert!(!toks.is_empty(), "empty token span for slot {slot}");
        let p0 = pool.lens[*slot];
        assert!(
            p0 + toks.len() <= cfg.max_seq || toks.len() == 1,
            "kv cache overflow: {p0} cached + {} new > max_seq {} (slot {slot}); \
             only single-token spans may wrap the ring",
            toks.len(),
            cfg.max_seq
        );
        // Map (or CoW-split) the pages this span will write before any
        // layer touches them — geometry and frame mappings are then fixed
        // for the whole pass.
        pool.prepare_span(*slot, toks.len());
        bases.push(n);
        n += toks.len();
    }
    // Attention geometry is fixed for the whole pass: slot lengths only
    // advance after every layer has appended at the same positions.
    let spans: Vec<AttnSpan> = seqs
        .iter()
        .zip(bases.iter())
        .map(|(&(slot, toks), &base)| {
            let (p0, start) = pool.span_geometry(slot, toks.len());
            AttnSpan { q_base: base, span: toks.len(), p0, kv: slot, start }
        })
        .collect();

    // Embedding lookup + learned positions, rebased to the slot window:
    // logical position L embeds at min(L, max_seq − 1), so a wrapped
    // token always sits at the window's last position.
    let tok_emb = w.expect("embed.tok");
    let pos_emb = w.expect("embed.pos");
    let mut x = Matrix::zeros(n, d);
    for (i, (slot, toks)) in seqs.iter().enumerate() {
        let p0 = pool.lens[*slot];
        for (s, &tk) in toks.iter().enumerate() {
            let t = tk as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let pos = (p0 + s).min(cfg.max_seq - 1);
            let row = x.row_mut(bases[i] + s);
            for j in 0..d {
                row[j] = tok_emb.get(t, j) + pos_emb.get(pos, j);
            }
        }
    }

    let scale = 1.0 / (cfg.d_head() as f32).sqrt();
    let dh = cfg.d_head();
    for blk in 0..cfg.n_layers {
        let p = |s: &str| format!("block{blk}.{s}");
        // ── Attention over each slot's cache + its new positions ─────
        let h = layernorm(&x, w.expect(&p("ln1.g")), w.expect(&p("ln1.b")));
        let q = linears.apply(w, &p("attn.wq"), &h);
        let k = linears.apply(w, &p("attn.wk"), &h);
        let v = linears.apply(w, &p("attn.wv"), &h);
        for (i, &(slot, toks)) in seqs.iter().enumerate() {
            // Write at *logical* positions — the pool wraps them into the
            // ring (slot lengths only advance after the layer loop, so
            // every layer writes the same positions).
            let p0 = pool.lens[slot];
            for s in 0..toks.len() {
                pool.write(blk, slot, p0 + s, k.row(bases[i] + s), v.row(bases[i] + s));
            }
        }
        // Blocked causal attention over the freshly appended cache stripes
        // (the one shared implementation — see `model::attention`).
        let (ks, vs) = pool.layer(blk);
        let ctx = attend(cfg.n_heads, dh, scale, &spans, &q, &KvSource::Pool { k: ks, v: vs });
        let attn_out = linears.apply(w, &p("attn.wo"), &ctx);
        x = x.add(&attn_out);

        // ── MLP ──────────────────────────────────────────────────────
        let h2 = layernorm(&x, w.expect(&p("ln2.g")), w.expect(&p("ln2.b")));
        let mut u = linears.apply(w, &p("mlp.fc1"), &h2);
        let b1 = w.expect(&p("mlp.fc1_b"));
        for i in 0..n {
            let row = u.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 = gelu(*v2 + b1.get(0, j));
            }
        }
        let mut mlp_out = linears.apply(w, &p("mlp.fc2"), &u);
        let b2 = w.expect(&p("mlp.fc2_b"));
        for i in 0..n {
            let row = mlp_out.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 += b2.get(0, j);
            }
        }
        x = x.add(&mlp_out);
    }
    // Advance every slot's cached length once, after all layers appended at
    // the same positions.
    for (slot, toks) in seqs {
        pool.lens[*slot] += toks.len();
    }

    // Final LN + tied-embedding logits.
    let xf = layernorm(&x, w.expect("final_ln.g"), w.expect("final_ln.b"));
    matmul_a_bt(&xf, tok_emb)
}

/// Incremental forward pass: process only the `s_new = tokens.len()/batch`
/// new position(s) per sequence, attending over the cached K/V prefix, and
/// return logits `[(batch·s_new) × vocab]` for the new positions only.
///
/// `tokens` is batch-major (`tokens[b*s_new + s]`); the new tokens occupy
/// logical positions `cache.len() .. cache.len()+s_new`. Calling this with
/// a full prompt on an empty cache is the prefill; calling it with one
/// token per sequence afterwards is a decode step — including past
/// `capacity()`, where each step wraps the ring instead of overflowing.
/// The per-step logits reproduce the full [`forward`] logits at the same
/// positions within fp tolerance (exactly, for the dense path).
/// Equal-length wrapper over [`forward_slots`].
pub fn forward_cached(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[u32],
    cache: &mut KvCache,
    linears: &Linears,
) -> Matrix {
    let bsz = cache.batch();
    assert!(
        !tokens.is_empty() && tokens.len() % bsz == 0,
        "token count {} not divisible by cache batch {bsz}",
        tokens.len()
    );
    let s_new = tokens.len() / bsz;
    // Borrowed spans — the per-step decode path allocates nothing here.
    let seqs: Vec<(usize, &[u32])> = (0..bsz)
        .map(|b| (b, &tokens[b * s_new..(b + 1) * s_new]))
        .collect();
    forward_slots(cfg, w, &seqs, &mut cache.pool, linears)
}

/// Forward pass producing logits `[(batch·seq) × vocab]`.
///
/// * `taps` — if `Some`, records the input activations of every linear.
/// * `overrides` — replaces named linear weights (compressed eval).
pub fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
) -> Matrix {
    forward_iq(cfg, w, batch, taps, overrides, crate::quant::fp8::InputQuant::None)
}

/// [`forward`] with activation (input) quantization applied to the inputs
/// of every linear layer — the paper's Apx B evaluation mode.
pub fn forward_iq(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    mut taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
    iq: crate::quant::fp8::InputQuant,
) -> Matrix {
    use crate::quant::fp8::quantize_input;
    let d = cfg.d_model;
    let n = batch.batch * batch.seq;
    assert!(batch.seq <= cfg.max_seq, "seq {} > max {}", batch.seq, cfg.max_seq);
    let pick = |name: &str| -> &Matrix {
        if let Some(ov) = overrides {
            if let Some(m) = ov.get(name) {
                return m;
            }
        }
        w.expect(name)
    };

    // Embedding lookup + learned positions.
    let tok_emb = w.expect("embed.tok");
    let pos_emb = w.expect("embed.pos");
    let mut x = Matrix::zeros(n, d);
    for b in 0..batch.batch {
        for s in 0..batch.seq {
            let t = batch.tok(b, s) as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let row = x.row_mut(b * batch.seq + s);
            for j in 0..d {
                row[j] = tok_emb.get(t, j) + pos_emb.get(s, j);
            }
        }
    }

    let dh = cfg.d_head();
    let scale = 1.0 / (dh as f32).sqrt();
    // Every sample attends causally over its own fresh K/V rows.
    let spans: Vec<AttnSpan> = (0..batch.batch)
        .map(|b| AttnSpan {
            q_base: b * batch.seq,
            span: batch.seq,
            p0: 0,
            kv: b * batch.seq,
            start: 0,
        })
        .collect();
    for blk in 0..cfg.n_layers {
        let p = |s: &str| format!("block{blk}.{s}");
        // ── Attention ────────────────────────────────────────────────
        let h = layernorm(&x, w.expect(&p("ln1.g")), w.expect(&p("ln1.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wq"), h.clone());
            t.insert(p("attn.wk"), h.clone());
            t.insert(p("attn.wv"), h.clone());
        }
        let hq = quantize_input(&h, iq);
        let q = hq.matmul(pick(&p("attn.wq")));
        let k = hq.matmul(pick(&p("attn.wk")));
        let v = hq.matmul(pick(&p("attn.wv")));
        // Blocked causal attention — the same implementation the serving
        // path runs (see `model::attention`).
        let ctx = attend(cfg.n_heads, dh, scale, &spans, &q, &KvSource::Fresh { k: &k, v: &v });
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wo"), ctx.clone());
        }
        let attn_out = quantize_input(&ctx, iq).matmul(pick(&p("attn.wo")));
        x = x.add(&attn_out);

        // ── MLP ──────────────────────────────────────────────────────
        let h2 = layernorm(&x, w.expect(&p("ln2.g")), w.expect(&p("ln2.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc1"), h2.clone());
        }
        let mut u = quantize_input(&h2, iq).matmul(pick(&p("mlp.fc1")));
        let b1 = w.expect(&p("mlp.fc1_b"));
        for i in 0..n {
            let row = u.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 = gelu(*v2 + b1.get(0, j));
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc2"), u.clone());
        }
        let mut mlp_out = quantize_input(&u, iq).matmul(pick(&p("mlp.fc2")));
        let b2 = w.expect(&p("mlp.fc2_b"));
        for i in 0..n {
            let row = mlp_out.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 += b2.get(0, j);
            }
        }
        x = x.add(&mlp_out);
    }

    // Final LN + tied-embedding logits.
    let xf = layernorm(&x, w.expect("final_ln.g"), w.expect("final_ln.b"));
    matmul_a_bt(&xf, tok_emb)
}

/// Greedy token choice from one logits row: the argmax with a **documented
/// lowest-index tie-break** (strict `>` comparison, so the first of any
/// equal maxima wins). Every greedy consumer — the serving engine, the
/// speculative draft AND its verifying target — must share this exact
/// rule: if draft and target broke ties differently, speculative
/// acceptance would silently degrade on tied logits even though the
/// models agree.
pub fn greedy_pick(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Mean next-token negative log-likelihood over the batch (positions
/// 0..seq-1 predict 1..seq).
pub fn nll(cfg: &ModelConfig, logits: &Matrix, batch: &Batch) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..batch.batch {
        for s in 0..batch.seq - 1 {
            let row = logits.row(b * batch.seq + s);
            let target = batch.tok(b, s + 1) as usize;
            // log-softmax at the target index.
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - row[target]) as f64;
            count += 1;
        }
    }
    let _ = cfg;
    total / count.max(1) as f64
}

/// Sum of log-probabilities the model assigns to `continuation` given
/// `prefix` (for the zero-shot likelihood-ranking tasks).
pub fn continuation_logprob(
    cfg: &ModelConfig,
    w: &Weights,
    prefix: &[u32],
    continuation: &[u32],
    overrides: Option<&Overrides>,
) -> f64 {
    let mut toks = prefix.to_vec();
    toks.extend_from_slice(continuation);
    let seq = toks.len().min(cfg.max_seq);
    let toks = &toks[toks.len() - seq..];
    let batch = Batch::new(toks.to_vec(), 1, seq);
    let logits = forward(cfg, w, &batch, None, overrides);
    let start = seq - continuation.len().min(seq);
    let mut lp = 0.0f64;
    for s in start..seq {
        if s == 0 {
            continue;
        }
        let row = logits.row(s - 1);
        let target = toks[s] as usize;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        lp += (row[target] - lse) as f64;
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::by_name;
    use crate::model::weights::init;
    use crate::rng::Pcg32;

    fn setup() -> (ModelConfig, Weights, Batch) {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..2 * 16).map(|_| rng.below(cfg.vocab as u32)).collect();
        (cfg.clone(), w, Batch::new(toks, 2, 16))
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        assert_eq!(logits.shape(), (32, cfg.vocab));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn untrained_nll_near_uniform() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let loss = nll(&cfg, &logits, &batch);
        let uniform = (cfg.vocab as f64).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn taps_capture_all_linear_inputs() {
        let (cfg, w, batch) = setup();
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        for (name, d_in, _) in cfg.linear_layers() {
            let x = taps.get(&name).unwrap_or_else(|| panic!("missing tap {name}"));
            assert_eq!(x.cols(), d_in, "{name}");
            assert_eq!(x.rows(), 32);
        }
    }

    #[test]
    fn overrides_change_output() {
        let (cfg, w, batch) = setup();
        let base = forward(&cfg, &w, &batch, None, None);
        let mut ov = Overrides::new();
        ov.insert("block0.mlp.fc1".into(), Matrix::zeros(cfg.d_model, cfg.d_ff()));
        let changed = forward(&cfg, &w, &batch, None, Some(&ov));
        assert!(changed.rel_err(&base) > 1e-4);
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let mut toks2 = batch.tokens.clone();
        toks2[15] = (toks2[15] + 1) % cfg.vocab as u32; // last pos of sample 0
        let batch2 = Batch::new(toks2, 2, 16);
        let logits2 = forward(&cfg, &w, &batch2, None, None);
        for s in 0..14 {
            let a = logits.row(s);
            let b = logits2.row(s);
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-5, "pos {s} leaked");
            }
        }
    }

    #[test]
    fn continuation_logprob_is_negative_and_finite() {
        let (cfg, w, _) = setup();
        let lp = continuation_logprob(&cfg, &w, &[1, 2, 3], &[4, 5], None);
        assert!(lp.is_finite() && lp < 0.0);
    }

    /// Assert every per-step cached-decode logit row matches the full
    /// forward's row at the same position within `tol` relative error.
    fn assert_cached_parity(
        cfg: &ModelConfig,
        w: &Weights,
        batch: &Batch,
        full: &Matrix,
        linears: &Linears,
        tol: f32,
    ) {
        let prefill = 8usize;
        let mut cache = KvCache::new(cfg, batch.batch);
        let row_err = |got: &[f32], want: &[f32]| {
            let a = Matrix::from_vec(1, got.len(), got.to_vec());
            let b = Matrix::from_vec(1, want.len(), want.to_vec());
            a.rel_err(&b)
        };
        // Multi-token prefill covers positions 0..prefill at once.
        let toks: Vec<u32> = (0..batch.batch)
            .flat_map(|b| (0..prefill).map(move |s| batch.tok(b, s)))
            .collect();
        let lg = forward_cached(cfg, w, &toks, &mut cache, linears);
        for b in 0..batch.batch {
            for s in 0..prefill {
                let err = row_err(lg.row(b * prefill + s), full.row(b * batch.seq + s));
                assert!(err < tol, "prefill b{b} s{s}: err {err}");
            }
        }
        // Then decode the remaining positions one token at a time.
        for s in prefill..batch.seq {
            let step: Vec<u32> = (0..batch.batch).map(|b| batch.tok(b, s)).collect();
            let lg = forward_cached(cfg, w, &step, &mut cache, linears);
            assert_eq!(lg.rows(), batch.batch);
            for b in 0..batch.batch {
                let err = row_err(lg.row(b), full.row(b * batch.seq + s));
                assert!(err < tol, "decode b{b} s{s}: err {err}");
            }
        }
        assert_eq!(cache.len(), batch.seq);
    }

    #[test]
    fn cached_decode_matches_full_forward_dense() {
        let (cfg, w, batch) = setup();
        let full = forward(&cfg, &w, &batch, None, None);
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Dense, 1e-4);
    }

    #[test]
    fn cached_decode_matches_full_forward_compressed() {
        use crate::compress::CompressConfig;
        use crate::model::compiled::CompressedWeights;
        use crate::sparse::SparsityPattern;
        let (cfg, w, batch) = setup();
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = crate::model::compress_model(
            &cfg,
            &w,
            &taps,
            &CompressConfig::slim(SparsityPattern::TWO_FOUR),
        );
        let full = forward(&cfg, &w, &batch, None, Some(&cm.overrides));
        // Dense-override linears reproduce the override eval path...
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Overrides(&cm.overrides), 1e-4);
        // ...and the packed-kernel path agrees with it too.
        let cw = CompressedWeights::from_model(&cm);
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Kernels(&cw), 1e-4);
    }

    #[test]
    fn kv_cache_reset_allows_reprefill() {
        let (cfg, w, batch) = setup();
        let full = forward(&cfg, &w, &batch, None, None);
        let mut cache = KvCache::new(&cfg, batch.batch);
        let bt = &batch;
        let toks: Vec<u32> = (0..bt.batch)
            .flat_map(|b| (0..bt.seq).map(move |s| bt.tok(b, s)))
            .collect();
        let a = forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
        cache.reset();
        assert!(cache.is_empty());
        let b = forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
        assert_eq!(a, b);
        assert!(a.rel_err(&full) < 1e-5);
        assert_eq!(cache.capacity(), cfg.max_seq);
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn kv_cache_overflow_panics() {
        let (cfg, w, _) = setup();
        let mut cache = KvCache::new(&cfg, 1);
        let toks = vec![1u32; cfg.max_seq + 1];
        forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
    }

    #[test]
    fn pool_alloc_free_reuses_slots() {
        let cfg = by_name("sim-125m").unwrap();
        let mut pool = KvCachePool::new(&cfg, 2);
        assert_eq!(pool.n_slots(), 2);
        assert_eq!(pool.free_slots(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!((a, b), (0, 1));
        assert!(pool.alloc().is_none());
        assert!(pool.is_live(a));
        pool.free(a);
        assert!(!pool.is_live(a));
        assert_eq!(pool.free_slots(), 1);
        // The retired slot is handed out again, empty.
        let c = pool.alloc().unwrap();
        assert_eq!(c, a);
        assert_eq!(pool.len(c), 0);
    }

    #[test]
    #[should_panic(expected = "free of non-live slot")]
    fn pool_double_free_panics() {
        let cfg = by_name("sim-125m").unwrap();
        let mut pool = KvCachePool::new(&cfg, 1);
        let s = pool.alloc().unwrap();
        pool.free(s);
        pool.free(s);
    }

    #[test]
    fn slot_forward_matches_full_forward_mixed_lengths() {
        // Three prompts of different lengths prefilled in ONE forward_slots
        // call must reproduce each prompt's solo full-forward logits — the
        // no-padding property the continuous scheduler relies on.
        let (cfg, w, _) = setup();
        let mut rng = Pcg32::seeded(9);
        let prompts: Vec<Vec<u32>> = [5usize, 9, 1]
            .iter()
            .map(|&len| (0..len).map(|_| rng.below(cfg.vocab as u32)).collect())
            .collect();
        let mut pool = KvCachePool::new(&cfg, 3);
        let entries: Vec<(usize, &[u32])> =
            prompts.iter().map(|p| (pool.alloc().unwrap(), p.as_slice())).collect();
        let lg = forward_slots(&cfg, &w, &entries, &mut pool, &Linears::Dense);
        let mut base = 0usize;
        for p in &prompts {
            let full =
                forward(&cfg, &w, &Batch::new(p.clone(), 1, p.len()), None, None);
            for s in 0..p.len() {
                let got = Matrix::from_vec(1, cfg.vocab, lg.row(base + s).to_vec());
                let want = Matrix::from_vec(1, cfg.vocab, full.row(s).to_vec());
                assert!(got.rel_err(&want) < 1e-5, "prefill row {s}");
            }
            base += p.len();
        }
        // One decode step per sequence at three different cache depths,
        // batched together, still matches the solo full forward.
        let nexts: Vec<u32> = prompts.iter().map(|p| p[0] ^ 1).collect();
        let steps: Vec<(usize, &[u32])> = entries
            .iter()
            .zip(nexts.iter())
            .map(|(&(slot, _), t)| (slot, std::slice::from_ref(t)))
            .collect();
        let lg2 = forward_slots(&cfg, &w, &steps, &mut pool, &Linears::Dense);
        for (i, (p, &t)) in prompts.iter().zip(nexts.iter()).enumerate() {
            let mut ext = p.clone();
            ext.push(t);
            let full =
                forward(&cfg, &w, &Batch::new(ext.clone(), 1, ext.len()), None, None);
            let got = Matrix::from_vec(1, cfg.vocab, lg2.row(i).to_vec());
            let want = Matrix::from_vec(1, cfg.vocab, full.row(ext.len() - 1).to_vec());
            assert!(got.rel_err(&want) < 1e-5, "decode seq {i}");
            assert_eq!(pool.len(entries[i].0), ext.len());
        }
    }

    #[test]
    fn chunked_continuation_spans_match_oneshot_bitwise() {
        // Feeding a prompt as multi-token continuation spans at the slot's
        // current logical base must reproduce the one-shot prefill logits
        // BIT-exactly (f32): same K/V rows written, same logical attention
        // prefix per query row, same accumulation order. Also checks
        // span_room's countdown as the slot fills.
        let (cfg, w, _) = setup();
        let mut rng = Pcg32::seeded(31);
        let prompt: Vec<u32> = (0..12).map(|_| rng.below(cfg.vocab as u32)).collect();
        let mut one_pool = KvCachePool::new(&cfg, 1);
        let s1 = one_pool.alloc().unwrap();
        let oneshot =
            forward_slots(&cfg, &w, &[(s1, &prompt[..])], &mut one_pool, &Linears::Dense);
        for chunks in [vec![1usize; 12], vec![5, 4, 3], vec![3, 9], vec![12]] {
            let mut pool = KvCachePool::new(&cfg, 1);
            let slot = pool.alloc().unwrap();
            let mut fed = 0usize;
            for c in chunks {
                assert!(pool.span_room(slot) >= c, "chunk must fit the ring");
                assert_eq!(pool.span_room(slot), cfg.max_seq - fed);
                let lg = forward_slots(
                    &cfg,
                    &w,
                    &[(slot, &prompt[fed..fed + c])],
                    &mut pool,
                    &Linears::Dense,
                );
                for s in 0..c {
                    assert_eq!(
                        lg.row(s),
                        oneshot.row(fed + s),
                        "position {} diverged from one-shot",
                        fed + s
                    );
                }
                fed += c;
            }
            assert_eq!(fed, prompt.len());
            assert_eq!(pool.len(slot), prompt.len());
        }
    }

    #[test]
    fn slot_forward_is_batching_invariant() {
        // Bit-identical logits whether a sequence runs solo or packed with
        // others — the property that makes continuous batching safe.
        let (cfg, w, _) = setup();
        let a: Vec<u32> = vec![5, 6, 7, 8];
        let b: Vec<u32> = vec![9, 10];
        let mut solo_pool = KvCachePool::new(&cfg, 1);
        let sa = solo_pool.alloc().unwrap();
        let solo = forward_slots(&cfg, &w, &[(sa, a.as_slice())], &mut solo_pool, &Linears::Dense);
        let mut pool = KvCachePool::new(&cfg, 2);
        let s1 = pool.alloc().unwrap();
        let s2 = pool.alloc().unwrap();
        let both = forward_slots(
            &cfg,
            &w,
            &[(s2, b.as_slice()), (s1, a.as_slice())],
            &mut pool,
            &Linears::Dense,
        );
        // Entry 1 (= sequence a) occupies rows b.len().. in the packed output.
        for s in 0..a.len() {
            assert_eq!(solo.row(s), both.row(b.len() + s), "row {s} differs");
        }
    }

    #[test]
    fn gelu_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }

    /// Cached decode with a quantized (or half-precision) KV store must
    /// track the f32 full forward within a small logit tolerance (the
    /// rounding noise), at `min_ratio`× fewer cache bytes (~4 for the
    /// 8-bit dtypes, ~2 for f16/bf16).
    fn assert_quantized_kv_close(dtype: KvDtype, tol: f32, min_ratio: f64) {
        let (cfg, w, batch) = setup();
        let full = forward(&cfg, &w, &batch, None, None);
        let mut cache = KvCache::with_dtype(&cfg, batch.batch, dtype);
        let prefill = 8usize;
        let toks: Vec<u32> = (0..batch.batch)
            .flat_map(|b| (0..prefill).map(move |s| batch.tok(b, s)))
            .collect();
        let lg = forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
        for b in 0..batch.batch {
            for s in 0..prefill {
                let got = Matrix::from_vec(1, cfg.vocab, lg.row(b * prefill + s).to_vec());
                let want = Matrix::from_vec(1, cfg.vocab, full.row(b * batch.seq + s).to_vec());
                let err = got.rel_err(&want);
                assert!(err < tol, "{} prefill b{b} s{s}: err {err}", dtype.name());
                assert!(got.data().iter().all(|v| v.is_finite()));
            }
        }
        for s in prefill..batch.seq {
            let step: Vec<u32> = (0..batch.batch).map(|b| batch.tok(b, s)).collect();
            let lg = forward_cached(&cfg, &w, &step, &mut cache, &Linears::Dense);
            for b in 0..batch.batch {
                let got = Matrix::from_vec(1, cfg.vocab, lg.row(b).to_vec());
                let want = Matrix::from_vec(1, cfg.vocab, full.row(b * batch.seq + s).to_vec());
                let err = got.rel_err(&want);
                assert!(err < tol, "{} decode b{b} s{s}: err {err}", dtype.name());
            }
        }
        // The compressed pool really holds `min_ratio`× fewer bytes.
        let f32_bytes = KvCache::new(&cfg, batch.batch).pool().cache_bytes();
        let q_bytes = cache.pool().cache_bytes();
        assert!(
            f32_bytes as f64 / q_bytes as f64 > min_ratio,
            "{}: {f32_bytes} / {q_bytes}",
            dtype.name()
        );
    }

    #[test]
    fn int8_kv_decode_tracks_full_forward() {
        assert_quantized_kv_close(KvDtype::Int8, 0.1, 3.5);
    }

    #[test]
    fn fp8_kv_decode_tracks_full_forward() {
        assert_quantized_kv_close(KvDtype::Fp8E4M3, 0.3, 3.5);
    }

    /// f16 rows carry 11 significand bits — an order of magnitude tighter
    /// than int8's per-row grid — so the tolerance is 5× stricter, at
    /// exactly 2× fewer cache bytes (no scale sidecar).
    #[test]
    fn f16_kv_decode_tracks_full_forward() {
        assert_quantized_kv_close(KvDtype::F16, 0.02, 1.99);
    }

    #[test]
    fn bf16_kv_decode_tracks_full_forward() {
        assert_quantized_kv_close(KvDtype::Bf16, 0.05, 1.99);
    }

    /// A small config whose ring wraps cheaply in tests.
    fn ring_cfg() -> ModelConfig {
        ModelConfig {
            name: "ring-test".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "ring test".to_string(),
        }
    }

    /// Decoding past the context length through the ring must produce the
    /// exact same logits as the shift-buffer reference at EVERY step, for
    /// every KV dtype: the two layouts hold byte-identical windows, so this
    /// pins the wrap addressing (two-arc reads, scales wrapping with rows)
    /// and the position rebasing end to end.
    #[test]
    fn ring_decode_matches_shift_reference_past_wrap() {
        let cfg = ring_cfg();
        let mut rng = Pcg32::seeded(21);
        let w = init(&cfg, &mut rng);
        for dtype in
            [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::Int8, KvDtype::Fp8E4M3]
        {
            let mut ring = KvCache::with_layout(&cfg, 1, dtype, KvLayout::Ring);
            let mut shift = KvCache::with_layout(&cfg, 1, dtype, KvLayout::Shift);
            // Prefill 3 tokens, then decode to 2.5× the context length.
            let prompt: Vec<u32> = (0..3).map(|_| rng.below(cfg.vocab as u32)).collect();
            let a = forward_cached(&cfg, &w, &prompt, &mut ring, &Linears::Dense);
            let b = forward_cached(&cfg, &w, &prompt, &mut shift, &Linears::Dense);
            assert_eq!(a, b, "{} prefill", dtype.name());
            for step in 0..2 * cfg.max_seq + 4 {
                let tok = [rng.below(cfg.vocab as u32)];
                let a = forward_cached(&cfg, &w, &tok, &mut ring, &Linears::Dense);
                let b = forward_cached(&cfg, &w, &tok, &mut shift, &Linears::Dense);
                assert_eq!(a, b, "{} step {step}", dtype.name());
            }
            assert_eq!(ring.len(), shift.len());
            assert!(ring.len() > 2 * cfg.max_seq, "the ring must have wrapped twice");
        }
    }

    /// Logical length, retained window and base across a wrap; a freed and
    /// reallocated slot starts logically empty again.
    #[test]
    fn pool_window_and_base_track_the_ring() {
        let cfg = ring_cfg();
        let w = {
            let mut rng = Pcg32::seeded(22);
            init(&cfg, &mut rng)
        };
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let prompt: Vec<u32> = (0..cfg.max_seq as u32).collect();
        forward_slots(&cfg, &w, &[(slot, &prompt[..])], &mut pool, &Linears::Dense);
        assert_eq!((pool.len(slot), pool.window(slot), pool.base(slot)), (8, 8, 0));
        for i in 0..5u32 {
            forward_slots(&cfg, &w, &[(slot, &[i][..])], &mut pool, &Linears::Dense);
        }
        // 13 logical positions, 8 retained, base 5.
        assert_eq!((pool.len(slot), pool.window(slot), pool.base(slot)), (13, 8, 5));
        pool.free(slot);
        let slot2 = pool.alloc().unwrap();
        assert_eq!(slot2, slot);
        assert_eq!((pool.len(slot2), pool.window(slot2), pool.base(slot2)), (0, 0, 0));
    }

    /// `truncate` rewinds logical length (and therefore window and base)
    /// while the slot is un-wrapped, and a rewound slot accepts appends at
    /// the rewound position.
    #[test]
    fn truncate_rewinds_len_window_and_base() {
        let cfg = ring_cfg();
        let w = {
            let mut rng = Pcg32::seeded(31);
            init(&cfg, &mut rng)
        };
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let prompt: Vec<u32> = (0..6u32).collect();
        forward_slots(&cfg, &w, &[(slot, &prompt[..])], &mut pool, &Linears::Dense);
        assert_eq!((pool.len(slot), pool.window(slot), pool.base(slot)), (6, 6, 0));
        pool.truncate(slot, 4);
        assert_eq!((pool.len(slot), pool.window(slot), pool.base(slot)), (4, 4, 0));
        // The rewound slot keeps serving: span_room reopened and appends
        // land at the rewound position.
        assert_eq!(pool.span_room(slot), cfg.max_seq - 4);
        forward_slots(&cfg, &w, &[(slot, &[7u32][..])], &mut pool, &Linears::Dense);
        assert_eq!(pool.len(slot), 5);
        // Truncating to the current length is a no-op.
        pool.truncate(slot, 5);
        assert_eq!(pool.len(slot), 5);
    }

    /// The speculative-decode rollback round-trip: append a verify span,
    /// truncate back to the accepted prefix, re-append the corrected
    /// continuation — logits must be bit-identical to a control slot that
    /// never speculated, for every KV dtype (quantized dtypes re-encode the
    /// overwritten rows and their scale entries exactly as a first write).
    #[test]
    fn truncate_then_reappend_matches_straight_run() {
        let cfg = ring_cfg();
        let mut rng = Pcg32::seeded(32);
        let w = init(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..4).map(|_| rng.below(cfg.vocab as u32)).collect();
        for dtype in
            [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::Int8, KvDtype::Fp8E4M3]
        {
            let mut spec = KvCachePool::with_dtype(&cfg, 1, dtype);
            let mut ctrl = KvCachePool::with_dtype(&cfg, 1, dtype);
            let s = spec.alloc().unwrap();
            let c = ctrl.alloc().unwrap();
            forward_slots(&cfg, &w, &[(s, &prompt[..])], &mut spec, &Linears::Dense);
            forward_slots(&cfg, &w, &[(c, &prompt[..])], &mut ctrl, &Linears::Dense);
            // Speculative slot verifies a 3-token span [10, 11, 12], of
            // which only the first token is "accepted".
            forward_slots(&cfg, &w, &[(s, &[10u32, 11, 12][..])], &mut spec, &Linears::Dense);
            spec.truncate(s, 5);
            // Control slot only ever sees the accepted token.
            forward_slots(&cfg, &w, &[(c, &[10u32][..])], &mut ctrl, &Linears::Dense);
            // Both continue with the correction token; the rejected rows
            // (and for int8 their per-row scales) are overwritten.
            let a = forward_slots(&cfg, &w, &[(s, &[20u32, 21][..])], &mut spec, &Linears::Dense);
            let b = forward_slots(&cfg, &w, &[(c, &[20u32, 21][..])], &mut ctrl, &Linears::Dense);
            assert_eq!(a, b, "{} rollback round-trip", dtype.name());
            assert_eq!(spec.len(s), ctrl.len(c));
        }
    }

    /// Truncating to the current length stays legal after the ring wraps
    /// (a fully-accepted speculation rolls back nothing), but an actual
    /// rewind past the wrap is refused — those physical rows are gone.
    #[test]
    fn truncate_noop_legal_after_wrap() {
        let cfg = ring_cfg();
        let w = {
            let mut rng = Pcg32::seeded(33);
            init(&cfg, &mut rng)
        };
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let prompt: Vec<u32> = (0..cfg.max_seq as u32).collect();
        forward_slots(&cfg, &w, &[(slot, &prompt[..])], &mut pool, &Linears::Dense);
        for i in 0..3u32 {
            forward_slots(&cfg, &w, &[(slot, &[i][..])], &mut pool, &Linears::Dense);
        }
        assert!(pool.len(slot) > cfg.max_seq, "the ring must have wrapped");
        pool.truncate(slot, pool.len(slot));
        assert_eq!(pool.len(slot), cfg.max_seq + 3);
    }

    #[test]
    #[should_panic(expected = "past the ring wrap")]
    fn truncate_rewind_refused_after_wrap() {
        let cfg = ring_cfg();
        let w = {
            let mut rng = Pcg32::seeded(34);
            init(&cfg, &mut rng)
        };
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let prompt: Vec<u32> = (0..cfg.max_seq as u32).collect();
        forward_slots(&cfg, &w, &[(slot, &prompt[..])], &mut pool, &Linears::Dense);
        forward_slots(&cfg, &w, &[(slot, &[1u32][..])], &mut pool, &Linears::Dense);
        // len 9 > max_seq 8: logical position 8 overwrote physical row 0,
        // so rewinding to 8 cannot restore the original row.
        pool.truncate(slot, cfg.max_seq);
    }

    #[test]
    #[should_panic(expected = "truncate of non-live slot")]
    fn truncate_non_live_slot_refused() {
        let cfg = ring_cfg();
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        pool.free(slot);
        pool.truncate(slot, 0);
    }

    /// Greedy picks use a strict lowest-index tie-break — the rule draft
    /// and target must share for speculative acceptance to be exact.
    #[test]
    fn greedy_pick_breaks_ties_toward_lowest_index() {
        assert_eq!(greedy_pick(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(greedy_pick(&[5.0, 5.0]), 0);
        assert_eq!(greedy_pick(&[-2.0, -1.0, -1.5]), 1);
        assert_eq!(greedy_pick(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
    }

    /// Multi-token spans may not wrap (they would overwrite history their
    /// own earlier rows attend to) — single-token spans do instead.
    #[test]
    #[should_panic(expected = "only single-token spans may wrap")]
    fn multi_token_span_cannot_wrap() {
        let cfg = ring_cfg();
        let mut rng = Pcg32::seeded(23);
        let w = init(&cfg, &mut rng);
        let mut pool = KvCachePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let prompt: Vec<u32> = (0..cfg.max_seq as u32 - 1).collect();
        forward_slots(&cfg, &w, &[(slot, &prompt[..])], &mut pool, &Linears::Dense);
        // 7 cached + 2 new > 8 and span != 1 → refused.
        forward_slots(&cfg, &w, &[(slot, &[1u32, 2][..])], &mut pool, &Linears::Dense);
    }

    #[test]
    fn f32_dtype_pool_is_bit_identical_to_default() {
        // KvDtype::F32 through the pluggable store reproduces the default
        // pool exactly (same storage, head-major layout is transparent).
        let (cfg, w, batch) = setup();
        let toks: Vec<u32> = (0..batch.batch)
            .flat_map(|b| (0..batch.seq).map(move |s| batch.tok(b, s)))
            .collect();
        let mut c1 = KvCache::new(&cfg, batch.batch);
        let mut c2 = KvCache::with_dtype(&cfg, batch.batch, KvDtype::F32);
        let a = forward_cached(&cfg, &w, &toks, &mut c1, &Linears::Dense);
        let b = forward_cached(&cfg, &w, &toks, &mut c2, &Linears::Dense);
        assert_eq!(a, b);
        assert_eq!(c2.pool().dtype(), KvDtype::F32);
    }

    /// Forked slots share pages until a write splits them: while the fork
    /// decodes a divergent continuation over the shared prefix, the
    /// parent's subsequent logits stay bit-identical to a never-forked
    /// control pool.
    #[test]
    fn fork_cow_isolation_bitwise() {
        let cfg = ring_cfg();
        let mut rng = Pcg32::seeded(41);
        let w = init(&cfg, &mut rng);
        for dtype in [KvDtype::F32, KvDtype::Int8] {
            let mut pool = KvCachePool::with_dtype(&cfg, 2, dtype);
            let mut ctrl = KvCachePool::with_dtype(&cfg, 1, dtype);
            let parent = pool.alloc().unwrap();
            let c = ctrl.alloc().unwrap();
            let prompt: Vec<u32> = (0..4).map(|_| rng.below(cfg.vocab as u32)).collect();
            forward_slots(&cfg, &w, &[(parent, &prompt[..])], &mut pool, &Linears::Dense);
            forward_slots(&cfg, &w, &[(c, &prompt[..])], &mut ctrl, &Linears::Dense);
            let child = pool.fork(parent).unwrap();
            assert!(pool.page_stats().pages_shared > 0, "fork must share pages");
            forward_slots(&cfg, &w, &[(child, &[7u32][..])], &mut pool, &Linears::Dense);
            forward_slots(&cfg, &w, &[(child, &[9u32][..])], &mut pool, &Linears::Dense);
            let a = forward_slots(&cfg, &w, &[(parent, &[3u32][..])], &mut pool, &Linears::Dense);
            let b = forward_slots(&cfg, &w, &[(c, &[3u32][..])], &mut ctrl, &Linears::Dense);
            assert_eq!(a, b, "{}: fork writes leaked into parent pages", dtype.name());
            pool.free(child);
            pool.free(parent);
            pool.assert_quiescent();
        }
    }

    /// Prefix round-trip: a retired sequence's full prompt pages are
    /// revived off the free list by an identical later prompt, which
    /// skips that prefill compute yet reproduces bit-equal logits; a
    /// different prompt misses.
    #[test]
    fn prefix_pages_revive_and_match_cold_logits() {
        let (cfg, w, _) = setup(); // sim-125m: max_seq 64, 16-row pages
        let mut rng = Pcg32::seeded(43);
        let prompt: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab as u32)).collect();
        let mut pool = KvCachePool::new(&cfg, 2);
        pool.set_prefix_cache(true);
        let page = pool.page_rows();
        let hashes = prefix_page_hashes(&prompt, page);
        assert_eq!(hashes.len(), 1, "20-token prompt fills one 16-row page");
        let a = pool.alloc().unwrap();
        let cold = forward_slots(&cfg, &w, &[(a, &prompt[..])], &mut pool, &Linears::Dense);
        pool.register_prefix(a, &hashes);
        pool.free(a);
        let b = pool.alloc().unwrap();
        assert_eq!(pool.lookup_prefix(b, &hashes), page);
        let warm = forward_slots(&cfg, &w, &[(b, &prompt[page..])], &mut pool, &Linears::Dense);
        for (i, s) in (page..prompt.len()).enumerate() {
            assert_eq!(warm.row(i), cold.row(s), "row {s} not bit-equal over shared prefix");
        }
        let stats = pool.page_stats();
        assert_eq!(stats.prefix_hits, 1);
        assert_eq!(stats.prefix_saved_tokens, page as u64);
        pool.free(b);
        let other: Vec<u32> = prompt.iter().map(|&t| (t + 1) % cfg.vocab as u32).collect();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.lookup_prefix(c, &prefix_page_hashes(&other, page)), 0);
        assert_eq!(pool.page_stats().prefix_misses, 1);
        pool.free(c);
        // Hash-resident frames sit on the free list at refcount zero.
        pool.assert_quiescent();
    }

    /// Alloc / fork / free churn in shuffled order always returns the
    /// pool to a fully quiescent state — the leak check behind the
    /// scheduler's shutdown assert.
    #[test]
    fn pool_quiescent_after_fork_churn() {
        let cfg = ring_cfg();
        let mut rng = Pcg32::seeded(44);
        let w = init(&cfg, &mut rng);
        let mut pool = KvCachePool::new(&cfg, 4);
        for round in 0..8u32 {
            let a = pool.alloc().unwrap();
            let toks: Vec<u32> =
                (0..1 + rng.below_usize(5)).map(|_| rng.below(cfg.vocab as u32)).collect();
            forward_slots(&cfg, &w, &[(a, &toks[..])], &mut pool, &Linears::Dense);
            let b = pool.fork(a).unwrap();
            let c = pool.fork(b).unwrap();
            forward_slots(&cfg, &w, &[(c, &[round][..])], &mut pool, &Linears::Dense);
            assert!(pool.refs_balanced(), "round {round}");
            for s in [a, c, b] {
                pool.free(s);
            }
        }
        pool.assert_quiescent();
    }
}
