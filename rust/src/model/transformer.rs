//! Native Rust transformer forward pass.
//!
//! A decoder-only pre-LN transformer matching `python/compile/model.py`
//! op-for-op (LN ε, tanh-GELU, causal softmax, tied embeddings), so the AOT
//! path can be validated against this one. Used for:
//!
//! * calibration — capturing the input activations of every linear layer,
//! * evaluation fallbacks and tests,
//! * the compressed-model accuracy path (effective weights substituted).
//!
//! Two entry points:
//!
//! * [`forward`] — full forward over a whole batch (prefill / reference /
//!   calibration path).
//! * [`forward_cached`] — incremental forward over only the *new*
//!   position(s), attending over a [`KvCache`] — the serving decode path.
//!   Linear layers dispatch through [`Linears`], which can route matmuls to
//!   packed compressed kernels ([`crate::kernels::LinearOp`]) instead of
//!   dense f32 overrides.

use std::collections::HashMap;

use super::compiled::CompressedWeights;
use super::config::ModelConfig;
use super::weights::Weights;
use crate::tensor::{matmul_a_bt, Matrix};

/// LayerNorm epsilon (matches jax default in model.py).
pub const LN_EPS: f32 = 1e-5;

/// tanh-approximated GELU (jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise LayerNorm with gain/bias (1 × d each).
pub fn layernorm(x: &Matrix, g: &Matrix, b: &Matrix) -> Matrix {
    let (rows, d) = x.shape();
    assert_eq!(g.cols(), d);
    let mut out = Matrix::zeros(rows, d);
    for i in 0..rows {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let orow = out.row_mut(i);
        for j in 0..d {
            orow[j] = (row[j] - mean) * inv * g.get(0, j) + b.get(0, j);
        }
    }
    out
}

/// In-place numerically-stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Token batch: `tokens[b][s]`, all rows of length `seq`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn new(tokens: Vec<u32>, batch: usize, seq: usize) -> Self {
        assert_eq!(tokens.len(), batch * seq);
        Batch { tokens, batch, seq }
    }

    #[inline]
    pub fn tok(&self, b: usize, s: usize) -> u32 {
        self.tokens[b * self.seq + s]
    }
}

/// Optional hook to capture the inputs to each linear layer (for
/// calibration). Keyed by layer name (`block0.attn.wq`, …); values are the
/// activation matrices fed to that weight.
pub type ActivationTap = HashMap<String, Matrix>;

/// Weight-override map: layer name → effective weight (used to evaluate
/// compressed models without materializing a full `Weights` clone).
pub type Overrides = HashMap<String, Matrix>;

/// How a forward pass resolves each linear layer's matmul.
pub enum Linears<'a> {
    /// Plain dense weights from the [`Weights`] map.
    Dense,
    /// Dense effective-weight overrides (the accuracy-eval path).
    Overrides(&'a Overrides),
    /// Packed compressed kernels (the serving hot path).
    Kernels(&'a CompressedWeights),
}

impl Linears<'_> {
    /// `y = x · W(name)` through the configured backend; layers without an
    /// override/kernel entry fall back to the dense weight.
    pub fn apply(&self, w: &Weights, name: &str, x: &Matrix) -> Matrix {
        match self {
            Linears::Dense => x.matmul(w.expect(name)),
            Linears::Overrides(ov) => match ov.get(name) {
                Some(m) => x.matmul(m),
                None => x.matmul(w.expect(name)),
            },
            Linears::Kernels(cw) => match cw.get(name) {
                Some(op) => op.matmul(x),
                None => x.matmul(w.expect(name)),
            },
        }
    }
}

/// Per-layer K/V tensors for incremental (KV-cached) decoding.
///
/// Rows are laid out `b * max_seq + t`, so each sequence's cache is
/// contiguous and pre-allocated at the model's context length.
/// [`forward_cached`] appends the new positions' K/V each step and attends
/// over the cached prefix, making per-token decode cost linear in the
/// sequence length instead of quadratic (the full-reforward serving path
/// this replaces).
pub struct KvCache {
    k: Vec<Matrix>,
    v: Vec<Matrix>,
    batch: usize,
    max_seq: usize,
    len: usize,
}

impl KvCache {
    /// Empty cache for `batch` concurrent sequences.
    pub fn new(cfg: &ModelConfig, batch: usize) -> Self {
        assert!(batch > 0, "KvCache needs at least one sequence");
        let mk = || -> Vec<Matrix> {
            (0..cfg.n_layers)
                .map(|_| Matrix::zeros(batch * cfg.max_seq, cfg.d_model))
                .collect()
        };
        KvCache { k: mk(), v: mk(), batch, max_seq: cfg.max_seq, len: 0 }
    }

    /// Positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of concurrent sequences.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Maximum cacheable positions (the model's context length).
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// Forget all cached positions (rows are overwritten by later appends).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Copy freshly computed K/V rows (`batch × s_new` layout) for layer
    /// `blk` into positions `len .. len + s_new`.
    fn append(&mut self, blk: usize, k: &Matrix, v: &Matrix) {
        let s_new = k.rows() / self.batch;
        for b in 0..self.batch {
            for s in 0..s_new {
                let dst = b * self.max_seq + self.len + s;
                self.k[blk].row_mut(dst).copy_from_slice(k.row(b * s_new + s));
                self.v[blk].row_mut(dst).copy_from_slice(v.row(b * s_new + s));
            }
        }
    }
}

/// Incremental forward pass: process only the `s_new = tokens.len()/batch`
/// new position(s) per sequence, attending over the cached K/V prefix, and
/// return logits `[(batch·s_new) × vocab]` for the new positions only.
///
/// `tokens` is batch-major (`tokens[b*s_new + s]`); the new tokens occupy
/// absolute positions `cache.len() .. cache.len()+s_new`. Calling this with
/// a full prompt on an empty cache is the prefill; calling it with one
/// token per sequence afterwards is a decode step. The per-step logits
/// reproduce the full [`forward`] logits at the same positions within fp
/// tolerance (exactly, for the dense path).
pub fn forward_cached(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[u32],
    cache: &mut KvCache,
    linears: &Linears,
) -> Matrix {
    let d = cfg.d_model;
    let bsz = cache.batch();
    assert!(
        !tokens.is_empty() && tokens.len() % bsz == 0,
        "token count {} not divisible by cache batch {bsz}",
        tokens.len()
    );
    let s_new = tokens.len() / bsz;
    let p0 = cache.len();
    assert!(
        p0 + s_new <= cfg.max_seq,
        "kv cache overflow: {p0} cached + {s_new} new > max_seq {}",
        cfg.max_seq
    );
    let n = bsz * s_new;

    // Embedding lookup + learned positions (offset by the cached prefix).
    let tok_emb = w.expect("embed.tok");
    let pos_emb = w.expect("embed.pos");
    let mut x = Matrix::zeros(n, d);
    for b in 0..bsz {
        for s in 0..s_new {
            let t = tokens[b * s_new + s] as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let row = x.row_mut(b * s_new + s);
            for j in 0..d {
                row[j] = tok_emb.get(t, j) + pos_emb.get(p0 + s, j);
            }
        }
    }

    let scale = 1.0 / (cfg.d_head() as f32).sqrt();
    let dh = cfg.d_head();
    for blk in 0..cfg.n_layers {
        let p = |s: &str| format!("block{blk}.{s}");
        // ── Attention over cache + new positions ─────────────────────
        let h = layernorm(&x, w.expect(&p("ln1.g")), w.expect(&p("ln1.b")));
        let q = linears.apply(w, &p("attn.wq"), &h);
        let k = linears.apply(w, &p("attn.wk"), &h);
        let v = linears.apply(w, &p("attn.wv"), &h);
        cache.append(blk, &k, &v);
        let mut ctx = Matrix::zeros(n, d);
        let kc = &cache.k[blk];
        let vc = &cache.v[blk];
        for b in 0..bsz {
            let cbase = b * cache.max_seq;
            for head in 0..cfg.n_heads {
                let c0 = head * dh;
                for s in 0..s_new {
                    // Causal scores over cached positions 0..=p0+s.
                    let gp = p0 + s;
                    let qrow = &q.row(b * s_new + s)[c0..c0 + dh];
                    let mut scores = vec![0.0f32; gp + 1];
                    for (t, sc) in scores.iter_mut().enumerate() {
                        let krow = &kc.row(cbase + t)[c0..c0 + dh];
                        let mut dot = 0.0f32;
                        for (a, b2) in qrow.iter().zip(krow.iter()) {
                            dot += a * b2;
                        }
                        *sc = dot * scale;
                    }
                    softmax_inplace(&mut scores);
                    let crow = ctx.row_mut(b * s_new + s);
                    for (t, &pr) in scores.iter().enumerate() {
                        let vrow = &vc.row(cbase + t)[c0..c0 + dh];
                        for j in 0..dh {
                            crow[c0 + j] += pr * vrow[j];
                        }
                    }
                }
            }
        }
        let attn_out = linears.apply(w, &p("attn.wo"), &ctx);
        x = x.add(&attn_out);

        // ── MLP ──────────────────────────────────────────────────────
        let h2 = layernorm(&x, w.expect(&p("ln2.g")), w.expect(&p("ln2.b")));
        let mut u = linears.apply(w, &p("mlp.fc1"), &h2);
        let b1 = w.expect(&p("mlp.fc1_b"));
        for i in 0..n {
            let row = u.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 = gelu(*v2 + b1.get(0, j));
            }
        }
        let mut mlp_out = linears.apply(w, &p("mlp.fc2"), &u);
        let b2 = w.expect(&p("mlp.fc2_b"));
        for i in 0..n {
            let row = mlp_out.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 += b2.get(0, j);
            }
        }
        x = x.add(&mlp_out);
    }
    cache.len += s_new;

    // Final LN + tied-embedding logits.
    let xf = layernorm(&x, w.expect("final_ln.g"), w.expect("final_ln.b"));
    matmul_a_bt(&xf, tok_emb)
}

/// Forward pass producing logits `[(batch·seq) × vocab]`.
///
/// * `taps` — if `Some`, records the input activations of every linear.
/// * `overrides` — replaces named linear weights (compressed eval).
pub fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
) -> Matrix {
    forward_iq(cfg, w, batch, taps, overrides, crate::quant::fp8::InputQuant::None)
}

/// [`forward`] with activation (input) quantization applied to the inputs
/// of every linear layer — the paper's Apx B evaluation mode.
pub fn forward_iq(
    cfg: &ModelConfig,
    w: &Weights,
    batch: &Batch,
    mut taps: Option<&mut ActivationTap>,
    overrides: Option<&Overrides>,
    iq: crate::quant::fp8::InputQuant,
) -> Matrix {
    use crate::quant::fp8::quantize_input;
    let d = cfg.d_model;
    let n = batch.batch * batch.seq;
    assert!(batch.seq <= cfg.max_seq, "seq {} > max {}", batch.seq, cfg.max_seq);
    let pick = |name: &str| -> &Matrix {
        if let Some(ov) = overrides {
            if let Some(m) = ov.get(name) {
                return m;
            }
        }
        w.expect(name)
    };

    // Embedding lookup + learned positions.
    let tok_emb = w.expect("embed.tok");
    let pos_emb = w.expect("embed.pos");
    let mut x = Matrix::zeros(n, d);
    for b in 0..batch.batch {
        for s in 0..batch.seq {
            let t = batch.tok(b, s) as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let row = x.row_mut(b * batch.seq + s);
            for j in 0..d {
                row[j] = tok_emb.get(t, j) + pos_emb.get(s, j);
            }
        }
    }

    let scale = 1.0 / (cfg.d_head() as f32).sqrt();
    for blk in 0..cfg.n_layers {
        let p = |s: &str| format!("block{blk}.{s}");
        // ── Attention ────────────────────────────────────────────────
        let h = layernorm(&x, w.expect(&p("ln1.g")), w.expect(&p("ln1.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wq"), h.clone());
            t.insert(p("attn.wk"), h.clone());
            t.insert(p("attn.wv"), h.clone());
        }
        let hq = quantize_input(&h, iq);
        let q = hq.matmul(pick(&p("attn.wq")));
        let k = hq.matmul(pick(&p("attn.wk")));
        let v = hq.matmul(pick(&p("attn.wv")));
        let mut ctx = Matrix::zeros(n, d);
        let dh = cfg.d_head();
        for b in 0..batch.batch {
            let base = b * batch.seq;
            for head in 0..cfg.n_heads {
                let c0 = head * dh;
                for s in 0..batch.seq {
                    // Causal scores over positions 0..=s.
                    let qrow = &q.row(base + s)[c0..c0 + dh];
                    let mut scores = vec![0.0f32; s + 1];
                    for (t, sc) in scores.iter_mut().enumerate() {
                        let krow = &k.row(base + t)[c0..c0 + dh];
                        let mut dot = 0.0f32;
                        for (a, b2) in qrow.iter().zip(krow.iter()) {
                            dot += a * b2;
                        }
                        *sc = dot * scale;
                    }
                    softmax_inplace(&mut scores);
                    let crow = ctx.row_mut(base + s);
                    for (t, &pr) in scores.iter().enumerate() {
                        let vrow = &v.row(base + t)[c0..c0 + dh];
                        for j in 0..dh {
                            crow[c0 + j] += pr * vrow[j];
                        }
                    }
                }
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("attn.wo"), ctx.clone());
        }
        let attn_out = quantize_input(&ctx, iq).matmul(pick(&p("attn.wo")));
        x = x.add(&attn_out);

        // ── MLP ──────────────────────────────────────────────────────
        let h2 = layernorm(&x, w.expect(&p("ln2.g")), w.expect(&p("ln2.b")));
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc1"), h2.clone());
        }
        let mut u = quantize_input(&h2, iq).matmul(pick(&p("mlp.fc1")));
        let b1 = w.expect(&p("mlp.fc1_b"));
        for i in 0..n {
            let row = u.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 = gelu(*v2 + b1.get(0, j));
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.insert(p("mlp.fc2"), u.clone());
        }
        let mut mlp_out = quantize_input(&u, iq).matmul(pick(&p("mlp.fc2")));
        let b2 = w.expect(&p("mlp.fc2_b"));
        for i in 0..n {
            let row = mlp_out.row_mut(i);
            for (j, v2) in row.iter_mut().enumerate() {
                *v2 += b2.get(0, j);
            }
        }
        x = x.add(&mlp_out);
    }

    // Final LN + tied-embedding logits.
    let xf = layernorm(&x, w.expect("final_ln.g"), w.expect("final_ln.b"));
    matmul_a_bt(&xf, tok_emb)
}

/// Mean next-token negative log-likelihood over the batch (positions
/// 0..seq-1 predict 1..seq).
pub fn nll(cfg: &ModelConfig, logits: &Matrix, batch: &Batch) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..batch.batch {
        for s in 0..batch.seq - 1 {
            let row = logits.row(b * batch.seq + s);
            let target = batch.tok(b, s + 1) as usize;
            // log-softmax at the target index.
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - row[target]) as f64;
            count += 1;
        }
    }
    let _ = cfg;
    total / count.max(1) as f64
}

/// Sum of log-probabilities the model assigns to `continuation` given
/// `prefix` (for the zero-shot likelihood-ranking tasks).
pub fn continuation_logprob(
    cfg: &ModelConfig,
    w: &Weights,
    prefix: &[u32],
    continuation: &[u32],
    overrides: Option<&Overrides>,
) -> f64 {
    let mut toks = prefix.to_vec();
    toks.extend_from_slice(continuation);
    let seq = toks.len().min(cfg.max_seq);
    let toks = &toks[toks.len() - seq..];
    let batch = Batch::new(toks.to_vec(), 1, seq);
    let logits = forward(cfg, w, &batch, None, overrides);
    let start = seq - continuation.len().min(seq);
    let mut lp = 0.0f64;
    for s in start..seq {
        if s == 0 {
            continue;
        }
        let row = logits.row(s - 1);
        let target = toks[s] as usize;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        lp += (row[target] - lse) as f64;
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::by_name;
    use crate::model::weights::init;
    use crate::rng::Pcg32;

    fn setup() -> (ModelConfig, Weights, Batch) {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..2 * 16).map(|_| rng.below(cfg.vocab as u32)).collect();
        (cfg.clone(), w, Batch::new(toks, 2, 16))
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        assert_eq!(logits.shape(), (32, cfg.vocab));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn untrained_nll_near_uniform() {
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let loss = nll(&cfg, &logits, &batch);
        let uniform = (cfg.vocab as f64).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn taps_capture_all_linear_inputs() {
        let (cfg, w, batch) = setup();
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        for (name, d_in, _) in cfg.linear_layers() {
            let x = taps.get(&name).unwrap_or_else(|| panic!("missing tap {name}"));
            assert_eq!(x.cols(), d_in, "{name}");
            assert_eq!(x.rows(), 32);
        }
    }

    #[test]
    fn overrides_change_output() {
        let (cfg, w, batch) = setup();
        let base = forward(&cfg, &w, &batch, None, None);
        let mut ov = Overrides::new();
        ov.insert("block0.mlp.fc1".into(), Matrix::zeros(cfg.d_model, cfg.d_ff()));
        let changed = forward(&cfg, &w, &batch, None, Some(&ov));
        assert!(changed.rel_err(&base) > 1e-4);
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let (cfg, w, batch) = setup();
        let logits = forward(&cfg, &w, &batch, None, None);
        let mut toks2 = batch.tokens.clone();
        toks2[15] = (toks2[15] + 1) % cfg.vocab as u32; // last pos of sample 0
        let batch2 = Batch::new(toks2, 2, 16);
        let logits2 = forward(&cfg, &w, &batch2, None, None);
        for s in 0..14 {
            let a = logits.row(s);
            let b = logits2.row(s);
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-5, "pos {s} leaked");
            }
        }
    }

    #[test]
    fn continuation_logprob_is_negative_and_finite() {
        let (cfg, w, _) = setup();
        let lp = continuation_logprob(&cfg, &w, &[1, 2, 3], &[4, 5], None);
        assert!(lp.is_finite() && lp < 0.0);
    }

    /// Assert every per-step cached-decode logit row matches the full
    /// forward's row at the same position within `tol` relative error.
    fn assert_cached_parity(
        cfg: &ModelConfig,
        w: &Weights,
        batch: &Batch,
        full: &Matrix,
        linears: &Linears,
        tol: f32,
    ) {
        let prefill = 8usize;
        let mut cache = KvCache::new(cfg, batch.batch);
        let row_err = |got: &[f32], want: &[f32]| {
            let a = Matrix::from_vec(1, got.len(), got.to_vec());
            let b = Matrix::from_vec(1, want.len(), want.to_vec());
            a.rel_err(&b)
        };
        // Multi-token prefill covers positions 0..prefill at once.
        let toks: Vec<u32> = (0..batch.batch)
            .flat_map(|b| (0..prefill).map(move |s| batch.tok(b, s)))
            .collect();
        let lg = forward_cached(cfg, w, &toks, &mut cache, linears);
        for b in 0..batch.batch {
            for s in 0..prefill {
                let err = row_err(lg.row(b * prefill + s), full.row(b * batch.seq + s));
                assert!(err < tol, "prefill b{b} s{s}: err {err}");
            }
        }
        // Then decode the remaining positions one token at a time.
        for s in prefill..batch.seq {
            let step: Vec<u32> = (0..batch.batch).map(|b| batch.tok(b, s)).collect();
            let lg = forward_cached(cfg, w, &step, &mut cache, linears);
            assert_eq!(lg.rows(), batch.batch);
            for b in 0..batch.batch {
                let err = row_err(lg.row(b), full.row(b * batch.seq + s));
                assert!(err < tol, "decode b{b} s{s}: err {err}");
            }
        }
        assert_eq!(cache.len(), batch.seq);
    }

    #[test]
    fn cached_decode_matches_full_forward_dense() {
        let (cfg, w, batch) = setup();
        let full = forward(&cfg, &w, &batch, None, None);
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Dense, 1e-4);
    }

    #[test]
    fn cached_decode_matches_full_forward_compressed() {
        use crate::compress::CompressConfig;
        use crate::model::compiled::CompressedWeights;
        use crate::sparse::SparsityPattern;
        let (cfg, w, batch) = setup();
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = crate::model::compress_model(
            &cfg,
            &w,
            &taps,
            &CompressConfig::slim(SparsityPattern::TWO_FOUR),
        );
        let full = forward(&cfg, &w, &batch, None, Some(&cm.overrides));
        // Dense-override linears reproduce the override eval path...
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Overrides(&cm.overrides), 1e-4);
        // ...and the packed-kernel path agrees with it too.
        let cw = CompressedWeights::from_model(&cm);
        assert_cached_parity(&cfg, &w, &batch, &full, &Linears::Kernels(&cw), 1e-4);
    }

    #[test]
    fn kv_cache_reset_allows_reprefill() {
        let (cfg, w, batch) = setup();
        let full = forward(&cfg, &w, &batch, None, None);
        let mut cache = KvCache::new(&cfg, batch.batch);
        let bt = &batch;
        let toks: Vec<u32> = (0..bt.batch)
            .flat_map(|b| (0..bt.seq).map(move |s| bt.tok(b, s)))
            .collect();
        let a = forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
        cache.reset();
        assert!(cache.is_empty());
        let b = forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
        assert_eq!(a, b);
        assert!(a.rel_err(&full) < 1e-5);
        assert_eq!(cache.capacity(), cfg.max_seq);
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn kv_cache_overflow_panics() {
        let (cfg, w, _) = setup();
        let mut cache = KvCache::new(&cfg, 1);
        let toks = vec![1u32; cfg.max_seq + 1];
        forward_cached(&cfg, &w, &toks, &mut cache, &Linears::Dense);
    }

    #[test]
    fn gelu_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 1e4];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[3] > 0.99);
    }
}
