//! Kernel-backed compressed model weights.
//!
//! [`CompressedWeights`] maps layer names to prepared [`LinearOp`]s so the
//! KV-cached forward pass ([`super::transformer::forward_cached`]) and the
//! serving engine dispatch matmuls straight to packed kernels (int4,
//! int4-2:4, group-int4 + low-rank adapters) instead of materializing dense
//! f32 "effective weight" override matrices. This is where the compression
//! pipeline's measured kernel speedups become end-to-end decode speedups
//! (benches/decode.rs).

use std::collections::{BTreeMap, HashMap};

use super::CompressedModel;
use crate::kernels::LinearOp;

/// Name → packed linear op for every compressed layer of a model.
#[derive(Default)]
pub struct CompressedWeights {
    ops: HashMap<String, LinearOp>,
}

impl CompressedWeights {
    /// Empty map (populate with [`CompressedWeights::insert`]).
    pub fn new() -> Self {
        CompressedWeights { ops: HashMap::new() }
    }

    /// Build packed kernels from a compression-pipeline output — the
    /// constructor the serving path uses after `compress_model`.
    pub fn from_model(cm: &CompressedModel) -> Self {
        CompressedWeights {
            ops: cm
                .layers
                .iter()
                .map(|(name, layer)| (name.clone(), LinearOp::from_compressed(layer)))
                .collect(),
        }
    }

    pub fn insert(&mut self, name: &str, op: LinearOp) {
        self.ops.insert(name.to_string(), op);
    }

    pub fn get(&self, name: &str) -> Option<&LinearOp> {
        self.ops.get(name)
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total weight bytes streamed per full forward step — the traffic
    /// model behind the decode-regime speedup.
    pub fn weight_bytes(&self) -> usize {
        self.ops.values().map(|op| op.weight_bytes()).sum()
    }

    /// Kernel name → layer count (for serving logs and benches).
    pub fn kernel_census(&self) -> BTreeMap<&'static str, usize> {
        let mut census = BTreeMap::new();
        for op in self.ops.values() {
            *census.entry(op.kernel_name()).or_insert(0) += 1;
        }
        census
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressConfig;
    use crate::model::{by_name, compress_model, forward, init, ActivationTap, Batch};
    use crate::rng::Pcg32;
    use crate::sparse::SparsityPattern;

    #[test]
    fn builds_sparse24_kernels_for_slim_pipeline() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model(&cfg, &w, &taps, &CompressConfig::slim(SparsityPattern::TWO_FOUR));
        let cw = CompressedWeights::from_model(&cm);
        assert_eq!(cw.len(), 6 * cfg.n_layers);
        // The flagship config packs every layer as int4-2:4.
        let census = cw.kernel_census();
        assert_eq!(census.get("int4-2:4").copied(), Some(6 * cfg.n_layers));
        // Kernel ops agree with the dense-override eval path per layer.
        let x = crate::tensor::Matrix::randn(4, cfg.d_model, 1.0, &mut rng);
        let xf = crate::tensor::Matrix::randn(4, cfg.d_ff(), 1.0, &mut rng);
        for (name, layer) in &cm.layers {
            let op = cw.get(name).unwrap();
            let probe = if layer.wc.rows() == cfg.d_model { &x } else { &xf };
            let err = op.matmul(probe).rel_err(&probe.matmul(&layer.effective()));
            assert!(err < 1e-5, "{name}: err {err}");
        }
        // And stream far fewer bytes than dense f32 weights.
        let dense_bytes: usize = cm.layers.values().map(|l| l.wc.len() * 4).sum();
        assert!(cw.weight_bytes() < dense_bytes / 2);
    }
}
