//! Generation engine: greedy decoding over a (compressed) model, exposed
//! as explicit serving phases.
//!
//! [`Engine::prefill_begin`] admits one request into a [`KvCachePool`]
//! slot as a *resumable* [`PrefillState`]; [`Engine::step_chunked`] is the
//! one batched forward every serving tick runs — it feeds each in-progress
//! prefill a bounded chunk of its windowed prompt (≤ `chunk_tokens` per
//! sequence, ≤ `prefill_budget` in total) *and* advances every in-flight
//! decode sequence one token, all as mixed-length spans of a single
//! [`forward_slots`] pass. A prefill emits its first token only on the
//! chunk that completes its prompt, and chunked prefill is token-for-token
//! (for f32 KV, bit-for-bit) identical to a one-shot prefill for every
//! chunk size and KV dtype: each chunk writes exactly the K/V rows the
//! one-shot pass would, and attention over the slot's prefix is
//! batching-invariant. [`Engine::prefill`] / [`Engine::prefill_batch`] /
//! [`Engine::decode_step`] are thin wrappers over the same primitive
//! (one-shot prefill is just a single unbounded chunk) — the primitives
//! the continuous scheduler (`server::scheduler`) drives.
//! Context overflow is handled by the pool itself: each slot is a ring
//! buffer with position rebasing (`model::KvCachePool`), so a sequence
//! deeper than `max_seq` still costs one KV write + one window attention
//! pass per token — `decode_step` is depth-independent, with no
//! re-prefill cliff at the context boundary.
//! [`Engine::generate_batch`] is the run-to-completion wrapper over the
//! same primitives: because each sequence owns a slot, prompts are never
//! left-padded and batched greedy output is token-for-token identical to
//! solo output, even for mixed-length prompts. Compressed models can run
//! kernel-backed ([`Engine::with_kernels`]): every linear matmul dispatches
//! to packed int4 / int4-2:4 kernels, which is where the paper's Fig. 3/4
//! kernel speedups reach end-to-end token throughput (measured by
//! `benches/decode.rs` and `benches/serve.rs`). The KV cache storage dtype
//! is pluggable too ([`Engine::with_kv_dtype`]): int8 / fp8 cached K/V cuts
//! decode cache bytes ~4×, and f16 / bf16 cuts them 2× at near-f32
//! fidelity (attention then runs the half fast path — scores and context
//! GEMMs decode the 16-bit codes inline, no f32 scratch slab).
//!
//! Constructing an engine also triggers the one-shot kernel autotuner
//! ([`crate::kernels::tune::ensure_tuned`]) for the model's `d_model`:
//! the first engine built in a process times a small grid of kernel tile
//! shapes and installs the winner in [`crate::kernels::TILES`]
//! (`SLIM_TUNE=off` skips, `SLIM_TUNE_CACHE=<path>` persists the pick).

use crate::model::{
    forward_cached, forward_slots, prefix_page_hashes, CompressedWeights, KvCache, KvCachePool,
    KvDtype, KvLayout, Linears, ModelConfig, Overrides, SampleParams, Sampler, Weights,
};
use crate::tensor::Matrix;
use std::sync::Arc;

/// One generation request.
#[derive(Clone, Debug, Default)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Optional stop token: generation retires early the moment this token
    /// is produced (it is included in the output).
    pub stop: Option<u32>,
    /// Admission priority: higher values are admitted sooner by policies
    /// that consult it (`server::batcher::AdmitPolicy::FairShare`); 0 is
    /// the neutral default. The engine itself ignores it.
    pub priority: i32,
    /// Originating client, for per-client fair-share admission (0 =
    /// anonymous). The engine itself ignores it.
    pub client_id: u64,
    /// Sampling knobs (temperature / top-k / top-p / seed). The default is
    /// greedy argmax, which consumes no RNG and reproduces the
    /// pre-sampling serving stack token for token.
    pub sample: SampleParams,
    /// Serving session this request extends (`server::session`): the
    /// scheduler resumes the session's parked KV slot instead of
    /// re-prefilling history, and parks it again at retirement. The engine
    /// itself ignores it.
    pub session: Option<u64>,
}

impl GenRequest {
    /// Request `max_new` greedy tokens from `prompt` (no stop token,
    /// neutral priority, anonymous client).
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Self {
        GenRequest { id, prompt, max_new, ..Default::default() }
    }

    /// Retire early the moment `token` is produced (it is included in the
    /// output).
    pub fn with_stop(mut self, token: u32) -> Self {
        self.stop = Some(token);
        self
    }

    /// Set the admission priority (higher = admitted sooner under
    /// fair-share admission).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Tag the request with its originating client (fair-share admission
    /// round-robins across client ids).
    pub fn with_client(mut self, client_id: u64) -> Self {
        self.client_id = client_id;
        self
    }

    /// Sample with `params` instead of greedy argmax (the seed makes the
    /// output deterministic across serving paths).
    pub fn with_sample(mut self, params: SampleParams) -> Self {
        self.sample = params;
        self
    }

    /// Attach the request to a serving session (turn N+1 of a multi-turn
    /// conversation; the scheduler resumes the session's parked KV slot).
    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Server-side submit→first-token latency, when the serving path
    /// observed one (the continuous scheduler records it at first-token
    /// time); `None` from paths with no enqueue time or no per-request
    /// TTFT observation — [`Engine::generate_batch`] and the router's
    /// legacy fixed-batch route.
    pub ttft_s: Option<f64>,
    /// Speculative-decode accounting `(drafted, accepted)` when the request
    /// was served by a `server::spec::SpecEngine` route: how many draft
    /// tokens were proposed for this sequence and how many the dense target
    /// accepted (`accepted / drafted` is the per-request acceptance rate).
    /// `None` on non-speculative paths. The tokens themselves are identical
    /// either way — speculation only changes how fast they arrive.
    pub spec: Option<(usize, usize)>,
}

/// One frame of a streamed generation: the scheduler pushes a `Token` the
/// tick it is emitted and a final `Done` carrying the same [`GenResult`]
/// a non-streaming submit would have returned — so a streamed request's
/// concatenated `Token` frames always equal its `Done.tokens`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// `token` is the `index`-th generated token (0-based).
    Token { index: usize, token: u32 },
    /// Generation finished; carries the complete result.
    Done(GenResult),
}

/// One in-flight sequence: its cache slot, token history and stop state.
///
/// Produced by [`Engine::prefill`], advanced by [`Engine::decode_step`];
/// whoever owns the [`KvCachePool`] frees `slot` after retiring the
/// sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    pub slot: usize,
    pub max_new: usize,
    pub stop: Option<u32>,
    /// True once the sequence produced `max_new` tokens or its stop token;
    /// done sequences are skipped by [`Engine::decode_step`].
    pub done: bool,
    /// Prompt (BOS if empty) + generated tokens.
    seq: Vec<u32>,
    prompt_len: usize,
    /// Per-sequence sampling state (knobs + seeded RNG stream); greedy
    /// params never touch the RNG.
    sampler: Sampler,
}

impl SeqState {
    /// Tokens generated so far (one more per decode step).
    pub fn generated(&self) -> &[u32] {
        &self.seq[self.prompt_len..]
    }

    /// Full token history: prompt (BOS if empty) + generated tokens. The
    /// speculative engine reads this to catch the draft cache up to the
    /// target cache between steps.
    pub(crate) fn history(&self) -> &[u32] {
        &self.seq
    }

    /// Length of the prompt prefix of [`SeqState::history`].
    pub(crate) fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    pub(crate) fn push_token(&mut self, t: u32) {
        self.seq.push(t);
        if self.seq.len() - self.prompt_len >= self.max_new || self.stop == Some(t) {
            self.done = true;
        }
    }

    /// Sample this sequence's next token from a logits row, advancing the
    /// per-sequence RNG (greedy params draw nothing).
    pub(crate) fn pick(&mut self, row: &[f32]) -> u32 {
        self.sampler.pick(row) as u32
    }

    /// A snapshot of the sampling stream at its current position — the
    /// speculative draft proposes from this clone so real draws stay
    /// aligned with the tokens the target actually emits.
    pub(crate) fn sampler_clone(&self) -> Sampler {
        self.sampler.clone()
    }
}

/// One request's resumable chunked prefill.
///
/// Produced by [`Engine::prefill_begin`] (which claims the cache slot),
/// advanced by [`Engine::step_chunked`], which feeds up to `chunk_tokens`
/// of the windowed prompt per call as a multi-token continuation span at
/// the slot's current logical base. The first generated token is emitted
/// only by the chunk that completes the prompt; until then the underlying
/// [`SeqState`] has generated nothing. Once [`PrefillState::is_complete`],
/// [`PrefillState::into_state`] yields the decode-ready [`SeqState`]
/// (which may already be `done`, e.g. `max_new == 1` or an immediate stop
/// token). Chunking never changes output: every chunk writes exactly the
/// K/V rows a one-shot prefill would, so the completing chunk's logits are
/// identical (bit-equal on f32 KV) for every chunk schedule.
pub struct PrefillState {
    state: SeqState,
    /// Index of the windowed prompt's first token within `state.seq`
    /// (prompts longer than `max_seq` feed only their trailing window).
    win_start: usize,
    /// Windowed prompt length — tokens to feed in total.
    win: usize,
    /// Windowed prompt tokens fed to the cache so far.
    fed: usize,
}

impl PrefillState {
    /// The underlying sequence state (id, slot, generated tokens).
    pub fn state(&self) -> &SeqState {
        &self.state
    }

    /// Windowed prompt tokens not yet fed to the cache (0 when complete,
    /// and for `max_new == 0` requests, which never touch the forward
    /// pass).
    pub fn remaining(&self) -> usize {
        if self.state.done {
            0
        } else {
            self.win - self.fed
        }
    }

    /// Whether the prompt is fully cached and the first token emitted (or
    /// the request needed no forward at all).
    pub fn is_complete(&self) -> bool {
        self.state.done || self.fed == self.win
    }

    /// Finish the prefill phase, yielding the decode-ready state. Callers
    /// should only invoke this once [`PrefillState::is_complete`].
    pub fn into_state(self) -> SeqState {
        self.state
    }

    /// The next `c`-token prompt chunk as a `(slot, span)` forward entry.
    /// Shared with the speculative engine, which packs prefill chunks into
    /// the same target forward as its verify spans.
    pub(crate) fn chunk_entry(&self, c: usize) -> (usize, &[u32]) {
        let lo = self.win_start + self.fed;
        (self.state.slot, &self.state.seq[lo..lo + c])
    }

    /// Record that `c` more prompt tokens were fed to the cache.
    pub(crate) fn advance(&mut self, c: usize) {
        self.fed += c;
    }

    /// Whether the windowed prompt is fully cached (the chunk that makes
    /// this true emits the first token).
    pub(crate) fn prompt_done(&self) -> bool {
        self.fed == self.win
    }

    /// Emit the first generated token (from the completing chunk's last
    /// logits row).
    pub(crate) fn push_first(&mut self, t: u32) {
        self.state.push_token(t);
    }

    /// Sample the first token from the completing chunk's logits row,
    /// advancing the sequence's RNG.
    pub(crate) fn pick(&mut self, row: &[f32]) -> u32 {
        self.state.pick(row)
    }
}

/// What one [`Engine::step_chunked`] tick produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Prompt tokens fed into the cache across all prefill chunks.
    pub prefill_tokens: usize,
    /// Prefills that completed this tick (each emitted its first token).
    pub first_tokens: usize,
    /// Decode sequences that each generated one token.
    pub decode_tokens: usize,
}

/// A servable model: config + weights (+ compression overrides or packed
/// kernels), plus the KV cache storage dtype its private pools use.
pub struct Engine {
    pub name: String,
    cfg: ModelConfig,
    weights: Arc<Weights>,
    overrides: Option<Arc<Overrides>>,
    kernels: Option<Arc<CompressedWeights>>,
    kv_dtype: KvDtype,
    kv_layout: KvLayout,
}

impl Engine {
    pub fn new(
        name: &str,
        cfg: ModelConfig,
        weights: Arc<Weights>,
        overrides: Option<Arc<Overrides>>,
    ) -> Self {
        crate::kernels::tune::ensure_tuned(cfg.d_model);
        Engine {
            name: name.to_string(),
            cfg,
            weights,
            overrides,
            kernels: None,
            kv_dtype: KvDtype::F32,
            kv_layout: KvLayout::Ring,
        }
    }

    /// Kernel-backed engine: linear matmuls run on packed compressed
    /// kernels instead of dense f32 effective-weight overrides.
    pub fn with_kernels(
        name: &str,
        cfg: ModelConfig,
        weights: Arc<Weights>,
        kernels: Arc<CompressedWeights>,
    ) -> Self {
        crate::kernels::tune::ensure_tuned(cfg.d_model);
        Engine {
            name: name.to_string(),
            cfg,
            weights,
            overrides: None,
            kernels: Some(kernels),
            kv_dtype: KvDtype::F32,
            kv_layout: KvLayout::Ring,
        }
    }

    /// Store cached K/V in `dtype` (int8 / fp8 cut decode cache traffic
    /// ~4×) in every pool this engine creates (`generate_batch`, `score`).
    /// Scheduler-owned pools inherit this dtype too, unless the route's
    /// `SchedPolicy::kv_dtype` explicitly overrides it.
    pub fn with_kv_dtype(mut self, dtype: KvDtype) -> Self {
        self.kv_dtype = dtype;
        self
    }

    /// The KV cache storage dtype this engine's private pools use.
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    /// Use `layout` for every pool this engine creates. Serving always
    /// wants the default O(1) ring; [`KvLayout::Shift`] is the slow
    /// sliding-window reference the overflow-equivalence tests and the
    /// decode bench compare against.
    pub fn with_kv_layout(mut self, layout: KvLayout) -> Self {
        self.kv_layout = layout;
        self
    }

    /// The KV cache overflow layout this engine's pools use.
    pub fn kv_layout(&self) -> KvLayout {
        self.kv_layout
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// One batched [`forward_slots`] pass over an external pool through
    /// this engine's linear backend — the raw forward the speculative
    /// engine uses to pack draft/verify spans itself.
    pub(crate) fn forward_pool(
        &self,
        entries: &[(usize, &[u32])],
        pool: &mut KvCachePool,
    ) -> Matrix {
        forward_slots(&self.cfg, &self.weights, entries, pool, &self.linears())
    }

    /// The linear-layer backend this engine serves with.
    fn linears(&self) -> Linears<'_> {
        if let Some(cw) = &self.kernels {
            Linears::Kernels(cw.as_ref())
        } else if let Some(ov) = &self.overrides {
            Linears::Overrides(ov.as_ref())
        } else {
            Linears::Dense
        }
    }

    /// Admit one request: claim a cache slot, prefill its (windowed) prompt
    /// and generate its first token. Panics if the pool has no free slot —
    /// callers gate admission on [`KvCachePool::free_slots`]. A
    /// `max_new == 0` request comes back already `done` without touching
    /// the forward pass.
    pub fn prefill(&self, req: &GenRequest, pool: &mut KvCachePool) -> SeqState {
        self.prefill_batch(std::slice::from_ref(req), pool).pop().unwrap()
    }

    /// Claim a cache slot for `req` and return its resumable
    /// [`PrefillState`] without running any forward pass yet — the chunked
    /// admission path. Panics if the pool has no free slot — callers gate
    /// admission on [`KvCachePool::free_slots`]. A `max_new == 0` request
    /// comes back already complete (and `done`) with an untouched slot.
    /// When the pool's prefix cache is enabled, a prompt whose windowed
    /// prefix pages are already resident starts with those pages mapped
    /// (`fed > 0`) — their prefill compute is skipped entirely, so a
    /// cache-hit TTFT is one partial-page prefill plus decode.
    pub fn prefill_begin(&self, req: &GenRequest, pool: &mut KvCachePool) -> PrefillState {
        let slot = pool.alloc().expect("no free KV cache slot");
        let seq = if req.prompt.is_empty() { vec![0u32] } else { req.prompt.clone() };
        let prompt_len = seq.len();
        let win = prompt_len.min(self.cfg.max_seq);
        let win_start = prompt_len - win;
        let fed = if req.max_new == 0 {
            0
        } else {
            self.prefix_seed(pool, slot, &seq[win_start..prompt_len])
        };
        PrefillState {
            state: SeqState {
                id: req.id,
                slot,
                max_new: req.max_new,
                stop: req.stop,
                done: req.max_new == 0,
                seq,
                prompt_len,
                sampler: Sampler::new(req.sample),
            },
            win_start,
            win,
            fed,
        }
    }

    /// Map any resident prefix-cache pages of windowed prompt `window`
    /// into freshly-allocated `slot`, returning how many of its tokens are
    /// now cached. Matches are capped so at least one windowed token
    /// remains to feed — the completing chunk needs a query row to emit
    /// the first token from.
    fn prefix_seed(&self, pool: &mut KvCachePool, slot: usize, window: &[u32]) -> usize {
        if !pool.prefix_cache_enabled() || window.len() < 2 {
            return 0;
        }
        let page = pool.page_rows();
        let hashes = prefix_page_hashes(window, page);
        let cap = ((window.len() - 1) / page).min(hashes.len());
        pool.lookup_prefix(slot, &hashes[..cap])
    }

    /// Re-admit a previously **preempted** sequence: claim a fresh slot
    /// and return a [`PrefillState`] that re-feeds the sequence's FULL
    /// windowed history (prompt + tokens already generated) as an ordinary
    /// chunked prefill. Re-prefill is write-for-write identical to the
    /// original pass (chunking never changes K/V contents), and the
    /// completing chunk's last logits row belongs to the latest generated
    /// token, so generation resumes exactly where it left off; the
    /// sampler/stop/max_new state rides along untouched. Callers only
    /// preempt un-wrapped sequences (`history().len() ≤ max_seq`) — past
    /// the wrap, evicted rows could not be reconstructed. Prefix-cache
    /// hits (the released pages are usually still hash-resident) make the
    /// resume cheap.
    pub fn prefill_reprise(&self, mut state: SeqState, pool: &mut KvCachePool) -> PrefillState {
        let slot = pool.alloc().expect("no free KV cache slot");
        state.slot = slot;
        let total = state.seq.len();
        let win = total.min(self.cfg.max_seq);
        let win_start = total - win;
        let fed = self.prefix_seed(pool, slot, &state.seq[win_start..total]);
        PrefillState { state, win_start, win, fed }
    }

    /// Resume a multi-turn session onto its parked cache slot: the prompt
    /// is the FULL conversation (history + new tokens) but `slot` already
    /// caches its first `pool.len(slot)` rows from previous turns, so only
    /// the uncached suffix is fed — turn N+1 prefills the new tokens, not
    /// the whole history. The caller (the scheduler's session path)
    /// guarantees the cached rows are a prefix of the windowed prompt:
    /// sessions resume only while the full conversation fits `max_seq`
    /// (deeper conversations fall back to a fresh windowed prefill) and
    /// the parked cache always ends one row short of the history (the last
    /// emitted token is never fed back), so at least one token remains.
    pub fn prefill_resume(
        &self,
        req: &GenRequest,
        pool: &KvCachePool,
        slot: usize,
    ) -> PrefillState {
        let seq = req.prompt.clone();
        let prompt_len = seq.len();
        let win = prompt_len.min(self.cfg.max_seq);
        let win_start = prompt_len - win;
        let cached = pool.len(slot);
        assert!(
            win_start <= cached && cached < prompt_len,
            "resume: cached rows {cached} not a proper prefix of windowed prompt \
             ({win_start}..{prompt_len})"
        );
        PrefillState {
            state: SeqState {
                id: req.id,
                slot,
                max_new: req.max_new,
                stop: req.stop,
                done: req.max_new == 0,
                seq,
                prompt_len,
                sampler: Sampler::new(req.sample),
            },
            win_start,
            win,
            fed: cached - win_start,
        }
    }

    /// One serving tick: a SINGLE batched forward that feeds every
    /// in-progress prefill its next prompt chunk and every in-flight
    /// decode sequence its latest token, as mixed-length [`forward_slots`]
    /// spans. This is the token-budget primitive the continuous scheduler
    /// runs — a long prompt no longer monopolizes a tick, it contributes
    /// at most `chunk_tokens` of work while everyone else still advances.
    ///
    /// Each prefill feeds `min(chunk_tokens, remaining, budget left)`
    /// prompt tokens, where `prefill_budget` caps the total across all
    /// prefills this tick (prefills are served in slice order; later ones
    /// may get 0 this tick). Chunks are additionally clamped to
    /// [`KvCachePool::span_room`] so a span never wraps the ring — during
    /// prefill the windowed prompt always fits, so the clamp only guards
    /// misuse. A prefill that completes its prompt emits its first greedy
    /// token from the chunk's last logits row; done/complete prefills and
    /// done decode sequences are skipped. Chunking is invisible in the
    /// output: every chunk writes exactly the K/V rows a one-shot prefill
    /// would (quantize-on-write is per row) and per-row attention over the
    /// slot's prefix is independent of span packing, so the completing
    /// chunk's logits — and every token decoded after — are identical to
    /// the one-shot pass (bit-equal on f32 KV; property-tested).
    pub fn step_chunked(
        &self,
        prefills: &mut [&mut PrefillState],
        decodes: &mut [&mut SeqState],
        chunk_tokens: usize,
        prefill_budget: usize,
        pool: &mut KvCachePool,
    ) -> StepStats {
        // Chunk sizes first (pure reads): ≤ chunk_tokens each, ≤
        // prefill_budget total, never wrapping the ring.
        let mut budget = prefill_budget;
        let chunks: Vec<usize> = prefills
            .iter()
            .map(|p| {
                let c = chunk_tokens
                    .min(p.remaining())
                    .min(budget)
                    .min(pool.span_room(p.state.slot));
                budget -= c;
                c
            })
            .collect();
        // Spans borrow from each state's token history — the hot path
        // allocates no token buffers. Prefill chunks pack first, then the
        // one-token decode spans.
        let mut entries: Vec<(usize, &[u32])> = Vec::new();
        for (p, &c) in prefills.iter().zip(&chunks) {
            if c > 0 {
                let lo = p.win_start + p.fed;
                entries.push((p.state.slot, &p.state.seq[lo..lo + c]));
            }
        }
        let mut who: Vec<usize> = Vec::new();
        for (i, st) in decodes.iter().enumerate() {
            if st.done {
                continue;
            }
            entries.push((st.slot, std::slice::from_ref(st.seq.last().unwrap())));
            who.push(i);
        }
        let mut stats = StepStats::default();
        if entries.is_empty() {
            return stats;
        }
        let logits = forward_slots(&self.cfg, &self.weights, &entries, pool, &self.linears());
        drop(entries); // release the immutable borrows of the state slices
        let mut row = 0usize;
        for (p, &c) in prefills.iter_mut().zip(&chunks) {
            if c == 0 {
                continue;
            }
            row += c;
            p.fed += c;
            stats.prefill_tokens += c;
            if p.fed == p.win {
                // Publish the completed window's full pages to the prefix
                // cache, so concurrent identical prompts map them instead
                // of re-prefilling (no-op unless the pool enables it).
                if pool.prefix_cache_enabled() {
                    let lo = p.win_start;
                    let hashes =
                        prefix_page_hashes(&p.state.seq[lo..lo + p.win], pool.page_rows());
                    pool.register_prefix(p.state.slot, &hashes);
                }
                // The chunk that completes the prompt emits the first token.
                let t = p.state.pick(logits.row(row - 1));
                p.state.push_token(t);
                stats.first_tokens += 1;
            }
        }
        // Decode spans are one token each: entry j's logits are row j after
        // the prefill rows.
        for &i in &who {
            let t = decodes[i].pick(logits.row(row));
            decodes[i].push_token(t);
            row += 1;
            stats.decode_tokens += 1;
        }
        stats
    }

    /// Admit several requests at once: every prompt prefills in ONE
    /// batched forward pass — a single unbounded [`Engine::step_chunked`]
    /// tick, so the one-shot path and the chunked path are literally the
    /// same code — claiming one cache slot each and generating each
    /// sequence's first token. Panics if the pool lacks free slots for all
    /// of them.
    pub fn prefill_batch(&self, reqs: &[GenRequest], pool: &mut KvCachePool) -> Vec<SeqState> {
        let mut pres: Vec<PrefillState> =
            reqs.iter().map(|r| self.prefill_begin(r, pool)).collect();
        loop {
            let mut active: Vec<&mut PrefillState> =
                pres.iter_mut().filter(|p| !p.is_complete()).collect();
            if active.is_empty() {
                break;
            }
            let stats = self.step_chunked(&mut active, &mut [], usize::MAX, usize::MAX, pool);
            debug_assert!(stats.prefill_tokens > 0, "prefill made no progress");
        }
        pres.into_iter().map(PrefillState::into_state).collect()
    }

    /// One continuous decode step: feed every non-done sequence its latest
    /// token in a single batched forward — sequences at any cache depth mix
    /// freely — and append each sequence's next greedy token. Depth is
    /// immaterial: a sequence past the context length wraps its slot's
    /// ring (one overwrite of the oldest cached position, position
    /// embedding rebased to the window frame) inside the same batched
    /// pass, so per-token cost stays flat instead of paying a sliding-
    /// window re-prefill every step. Marks sequences `done` when they
    /// reach `max_new` or their stop token; returns the number of tokens
    /// generated. (A prefill-free [`Engine::step_chunked`] tick.)
    pub fn decode_step(&self, states: &mut [&mut SeqState], pool: &mut KvCachePool) -> usize {
        self.step_chunked(&mut [], states, 0, 0, pool).decode_tokens
    }

    /// Greedy-decode a batch of requests to completion: a thin wrapper that
    /// drives [`Engine::prefill`] / [`Engine::decode_step`] over a private
    /// [`KvCachePool`]. Each request owns a slot, so prompts are never
    /// left-padded (batched output is token-for-token identical to solo
    /// output for mixed-length prompts) and each sequence retires the
    /// moment it reaches its own `max_new` or stop token instead of riding
    /// along to the batch maximum.
    pub fn generate_batch(&self, reqs: &[GenRequest]) -> Vec<GenResult> {
        if reqs.is_empty() {
            return vec![];
        }
        let mut pool =
            KvCachePool::with_layout(&self.cfg, reqs.len(), self.kv_dtype, self.kv_layout);
        let mut states = self.prefill_batch(reqs, &mut pool);
        loop {
            let mut active: Vec<&mut SeqState> =
                states.iter_mut().filter(|s| !s.done).collect();
            if active.is_empty() {
                break;
            }
            self.decode_step(&mut active, &mut pool);
        }
        states
            .iter()
            .map(|s| GenResult {
                id: s.id,
                tokens: s.generated().to_vec(),
                ttft_s: None,
                spec: None,
            })
            .collect()
    }

    /// Per-token logits for one sequence (used by the API's scoring mode).
    /// Runs as a fresh-cache prefill so compressed engines score through
    /// the same kernel path they decode with.
    pub fn score(&self, tokens: &[u32]) -> Matrix {
        let seq = tokens.len().min(self.cfg.max_seq);
        if seq == 0 {
            return Matrix::zeros(0, self.cfg.vocab);
        }
        let mut cache = KvCache::with_layout(&self.cfg, 1, self.kv_dtype, self.kv_layout);
        forward_cached(
            &self.cfg,
            &self.weights,
            &tokens[tokens.len() - seq..],
            &mut cache,
            &self.linears(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, forward, greedy_pick, init, Batch};
    use crate::rng::Pcg32;

    fn engine() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        Engine::new("test", cfg, Arc::new(w), None)
    }

    /// Legacy decode loop (full quadratic re-forward each step) — the
    /// reference the cached path must reproduce.
    fn legacy_generate(e: &Engine, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let cfg = e.config().clone();
        let mut seq = prompt.to_vec();
        for _ in 0..max_new {
            let cur = seq.len().min(cfg.max_seq);
            let batch = Batch::new(seq[seq.len() - cur..].to_vec(), 1, cur);
            let logits = forward(&cfg, &e.weights, &batch, None, None);
            seq.push(greedy_pick(logits.row(cur - 1)) as u32);
        }
        seq[prompt.len()..].to_vec()
    }

    #[test]
    fn generates_requested_counts() {
        let e = engine();
        let reqs = vec![
            GenRequest::new(1, vec![5, 6, 7], 4),
            GenRequest::new(2, vec![9], 4),
        ];
        let out = e.generate_batch(&reqs);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[1].id, 2);
        assert!(out.iter().all(|r| r.tokens.iter().all(|&t| (t as usize) < 512)));
    }

    #[test]
    fn per_request_max_new_respected() {
        // Mixed stop counts: each request gets exactly its own max_new.
        // (The old `min(..).max(max(..))` expression was a confusing no-op
        // — always the max — so this behavior predates the cleanup; the
        // test pins it against the rewritten decode loop.)
        let e = engine();
        let reqs = vec![
            GenRequest::new(1, vec![5, 6, 7], 2),
            GenRequest::new(2, vec![8, 9, 10], 6),
        ];
        let out = e.generate_batch(&reqs);
        assert_eq!(out[0].tokens.len(), 2);
        assert_eq!(out[1].tokens.len(), 6);
        // The shorter request's tokens are a prefix of what it would have
        // produced alone.
        let req = GenRequest::new(1, vec![5, 6, 7], 6);
        let solo = e.generate_batch(&[req]);
        assert_eq!(solo[0].tokens[..2], out[0].tokens[..]);
    }

    #[test]
    fn cached_decode_matches_legacy_full_forward() {
        let e = engine();
        let prompt = vec![5u32, 6, 7, 11];
        let want = legacy_generate(&e, &prompt, 6);
        let req = GenRequest::new(1, prompt.clone(), 6);
        let got = e.generate_batch(&[req]);
        assert_eq!(got[0].tokens, want);
    }

    #[test]
    fn batched_equals_single() {
        // Greedy decoding must be batching-invariant when prompts share a
        // length (no padding effects).
        let e = engine();
        let r1 = GenRequest::new(1, vec![5, 6, 7], 3);
        let r2 = GenRequest::new(2, vec![8, 9, 10], 3);
        let both = e.generate_batch(&[r1.clone(), r2.clone()]);
        let solo1 = e.generate_batch(&[r1]);
        let solo2 = e.generate_batch(&[r2]);
        assert_eq!(both[0].tokens, solo1[0].tokens);
        assert_eq!(both[1].tokens, solo2[0].tokens);
    }

    #[test]
    fn long_generation_survives_context_overflow() {
        // Generate to 2× max_seq and beyond: the ring must keep decoding
        // (no overflow panic, no re-prefill), agree with the legacy
        // full-reforward reference for every token produced before the
        // ring first wraps, and reproduce the shift-buffer sliding-window
        // reference token for token across the whole run.
        let e = engine();
        let max_seq = e.config().max_seq;
        let prompt = vec![3u32, 4, 5];
        let max_new = 2 * max_seq + 5;
        let req = GenRequest::new(1, prompt.clone(), max_new);
        let out = e.generate_batch(std::slice::from_ref(&req));
        assert_eq!(out[0].tokens.len(), max_new);
        // The wrap write first happens on the step that caches logical
        // position max_seq, i.e. after max_seq − prompt + 1 tokens.
        let boundary = max_seq - prompt.len() + 1;
        let legacy = legacy_generate(&e, &prompt, boundary);
        assert_eq!(out[0].tokens[..boundary], legacy[..], "pre-wrap prefix diverged from legacy");
        let shift = engine().with_kv_layout(KvLayout::Shift);
        let ref_out = shift.generate_batch(&[req]);
        assert_eq!(out[0].tokens, ref_out[0].tokens, "ring diverged from shift reference");
    }

    #[test]
    fn kernel_engine_matches_override_engine() {
        use crate::compress::CompressConfig;
        use crate::model::{compress_model, ActivationTap, CompressedWeights};
        use crate::sparse::SparsityPattern;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(2);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model(&cfg, &w, &taps, &CompressConfig::slim(SparsityPattern::TWO_FOUR));
        let weights = Arc::new(w);
        let cw = Arc::new(CompressedWeights::from_model(&cm));
        let e_ov = Engine::new("ov", cfg.clone(), weights.clone(), Some(Arc::new(cm.overrides)));
        let e_kn = Engine::with_kernels("kn", cfg.clone(), weights, cw);
        // Kernel-path logits match the dense-override path.
        let score_ov = e_ov.score(&[5, 6, 7, 8]);
        let score_kn = e_kn.score(&[5, 6, 7, 8]);
        assert!(score_kn.rel_err(&score_ov) < 1e-4, "err {}", score_kn.rel_err(&score_ov));
        // And the kernel engine generates well-formed batches.
        let req = GenRequest::new(1, vec![5, 6], 4);
        let out = e_kn.generate_batch(&[req]);
        assert_eq!(out[0].tokens.len(), 4);
    }

    #[test]
    fn empty_batch_ok() {
        let e = engine();
        assert!(e.generate_batch(&[]).is_empty());
    }

    /// Build the compression-pipeline (SLiM int4-2:4 + adapters) kernel
    /// engine pair: one with f32 KV, one with the given quantized KV dtype.
    fn compressed_engine_pair(dtype: KvDtype) -> (Engine, Engine) {
        use crate::compress::CompressConfig;
        use crate::model::{compress_model, ActivationTap, CompressedWeights};
        use crate::sparse::SparsityPattern;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(3);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model(&cfg, &w, &taps, &CompressConfig::slim(SparsityPattern::TWO_FOUR));
        let weights = Arc::new(w);
        let cw = Arc::new(CompressedWeights::from_model(&cm));
        let e_f32 = Engine::with_kernels("kv-f32", cfg.clone(), weights.clone(), cw.clone());
        let e_q = Engine::with_kernels("kv-q", cfg, weights, cw).with_kv_dtype(dtype);
        (e_f32, e_q)
    }

    /// int8 KV greedy decode on the compression-pipeline model reproduces
    /// the f32-KV tokens; if quantization noise ever flips a step, it may
    /// only be across a near-tie in the f32 logits — a divergence with a
    /// clear greedy margin is a real bug.
    #[test]
    fn int8_kv_greedy_matches_f32_on_compressed_model() {
        let (e_f32, e_int8) = compressed_engine_pair(KvDtype::Int8);
        assert_eq!(e_int8.kv_dtype(), KvDtype::Int8);
        let prompt = vec![5u32, 6, 7, 8];
        // Same-input logit comparison through the scoring path.
        let s_f = e_f32.score(&prompt);
        let s_8 = e_int8.score(&prompt);
        assert!(s_8.rel_err(&s_f) < 0.1, "int8 score err {}", s_8.rel_err(&s_f));
        let max_new = 8usize;
        let req = |id| GenRequest::new(id, prompt.clone(), max_new);
        let out_f = e_f32.generate_batch(&[req(1)]).remove(0).tokens;
        let out_8 = e_int8.generate_batch(&[req(2)]).remove(0).tokens;
        if out_8 != out_f {
            let div = out_f.iter().zip(out_8.iter()).position(|(a, b)| a != b).unwrap();
            let mut prefix = prompt.clone();
            prefix.extend_from_slice(&out_f[..div]);
            let lg = e_f32.score(&prefix);
            let row = lg.row(lg.rows() - 1);
            let mut sorted = row.to_vec();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let gap = sorted[0] - sorted[1];
            let mean = row.iter().sum::<f32>() / row.len() as f32;
            let spread = (row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / row.len() as f32)
                .sqrt();
            assert!(
                gap < 0.05 * spread,
                "int8 KV diverged at step {div} despite a clear greedy margin \
                 (top-2 gap {gap}, logit spread {spread})"
            );
        }
    }

    /// fp8 KV is coarser: require logit tolerance and well-formed output.
    #[test]
    fn fp8_kv_decode_close_on_compressed_model() {
        let (e_f32, e_fp8) = compressed_engine_pair(KvDtype::Fp8E4M3);
        let prompt = vec![9u32, 10, 11];
        let s_f = e_f32.score(&prompt);
        let s_8 = e_fp8.score(&prompt);
        assert!(s_8.rel_err(&s_f) < 0.3, "fp8 score err {}", s_8.rel_err(&s_f));
        let out = e_fp8.generate_batch(&[GenRequest::new(1, prompt, 4)]);
        assert_eq!(out[0].tokens.len(), 4);
        assert!(out[0].tokens.iter().all(|&t| (t as usize) < 512));
    }

    /// f16 KV is finer than int8, so it must clear the int8 bar: logit
    /// tolerance within the int8 regime and any greedy divergence only
    /// across a near-tie in the f32 logits.
    #[test]
    fn f16_kv_greedy_matches_f32_on_compressed_model() {
        let (e_f32, e_f16) = compressed_engine_pair(KvDtype::F16);
        assert_eq!(e_f16.kv_dtype(), KvDtype::F16);
        let prompt = vec![5u32, 6, 7, 8];
        let s_f = e_f32.score(&prompt);
        let s_h = e_f16.score(&prompt);
        assert!(s_h.rel_err(&s_f) < 0.1, "f16 score err {}", s_h.rel_err(&s_f));
        let max_new = 8usize;
        let req = |id| GenRequest::new(id, prompt.clone(), max_new);
        let out_f = e_f32.generate_batch(&[req(1)]).remove(0).tokens;
        let out_h = e_f16.generate_batch(&[req(2)]).remove(0).tokens;
        if out_h != out_f {
            let div = out_f.iter().zip(out_h.iter()).position(|(a, b)| a != b).unwrap();
            let mut prefix = prompt.clone();
            prefix.extend_from_slice(&out_f[..div]);
            let lg = e_f32.score(&prefix);
            let row = lg.row(lg.rows() - 1);
            let mut sorted = row.to_vec();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let gap = sorted[0] - sorted[1];
            let mean = row.iter().sum::<f32>() / row.len() as f32;
            let spread = (row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / row.len() as f32)
                .sqrt();
            assert!(
                gap < 0.05 * spread,
                "f16 KV diverged at step {div} despite a clear greedy margin \
                 (top-2 gap {gap}, logit spread {spread})"
            );
        }
    }

    /// bf16 KV: coarser mantissa than f16 but still well inside the int8
    /// tolerance regime on the scoring path.
    #[test]
    fn bf16_kv_decode_close_on_compressed_model() {
        let (e_f32, e_bf) = compressed_engine_pair(KvDtype::Bf16);
        let prompt = vec![9u32, 10, 11];
        let s_f = e_f32.score(&prompt);
        let s_b = e_bf.score(&prompt);
        assert!(s_b.rel_err(&s_f) < 0.1, "bf16 score err {}", s_b.rel_err(&s_f));
        let out = e_bf.generate_batch(&[GenRequest::new(1, prompt, 4)]);
        assert_eq!(out[0].tokens.len(), 4);
        assert!(out[0].tokens.iter().all(|&t| (t as usize) < 512));
    }

    /// Quantized-KV greedy decode is still batching-invariant: rows are
    /// encoded per sequence, so batchmates cannot perturb each other.
    #[test]
    fn int8_kv_batched_equals_solo() {
        let (_, e) = compressed_engine_pair(KvDtype::Int8);
        let r1 = GenRequest::new(1, vec![5, 6, 7], 4);
        let r2 = GenRequest::new(2, vec![8], 4);
        let both = e.generate_batch(&[r1.clone(), r2.clone()]);
        assert_eq!(both[0].tokens, e.generate_batch(&[r1])[0].tokens);
        assert_eq!(both[1].tokens, e.generate_batch(&[r2])[0].tokens);
    }

    #[test]
    fn mixed_length_batched_equals_single() {
        // Regression for the left-padding correctness gap: prompts of
        // different lengths used to attend to pad BOS tokens, so batched
        // greedy output could differ from solo output. Per-slot prefill
        // removes the padding entirely.
        let e = engine();
        let reqs = vec![
            GenRequest::new(1, vec![9], 4),
            GenRequest::new(2, vec![5, 6, 7], 4),
            GenRequest::new(3, vec![20, 21, 22, 23, 24, 25, 26], 4),
        ];
        let both = e.generate_batch(&reqs);
        for (req, got) in reqs.iter().zip(both.iter()) {
            let solo = e.generate_batch(&[req.clone()]);
            assert_eq!(
                got.tokens, solo[0].tokens,
                "request {} diverged from its solo decode",
                req.id
            );
        }
    }

    #[test]
    fn stop_token_retires_early() {
        let e = engine();
        // Discover what the model generates unconstrained, then stop at the
        // second token.
        let free_req = GenRequest::new(1, vec![5, 6, 7], 6);
        let free = e.generate_batch(&[free_req]);
        assert_eq!(free[0].tokens.len(), 6);
        let stop = free[0].tokens[1];
        let stop_req = GenRequest::new(1, vec![5, 6, 7], 6).with_stop(stop);
        let stopped = e.generate_batch(&[stop_req]);
        // Output is the unconstrained prefix up to and including the FIRST
        // occurrence of the stop token (greedy decoding is deterministic,
        // so the prefix matches).
        let cut = free[0].tokens.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(stopped[0].tokens, free[0].tokens[..cut].to_vec());
        assert_eq!(*stopped[0].tokens.last().unwrap(), stop);
    }

    #[test]
    fn retired_slot_is_reused_for_new_request() {
        // Drive the prefill/decode primitives directly on a 1-slot pool:
        // after the first sequence retires and frees its slot, a second
        // request must get the same slot and still decode exactly like a
        // solo run.
        let e = engine();
        let mut pool = KvCachePool::new(e.config(), 1);
        let r1 = GenRequest::new(1, vec![5, 6, 7], 3);
        let r2 = GenRequest::new(2, vec![40, 41], 4);
        let mut s1 = e.prefill(&r1, &mut pool);
        loop {
            let mut active: Vec<&mut SeqState> = vec![&mut s1];
            if e.decode_step(&mut active, &mut pool) == 0 {
                break;
            }
        }
        assert!(s1.done);
        pool.free(s1.slot);
        let mut s2 = e.prefill(&r2, &mut pool);
        assert_eq!(s2.slot, s1.slot, "freed slot must be reused");
        while !s2.done {
            let mut active: Vec<&mut SeqState> = vec![&mut s2];
            e.decode_step(&mut active, &mut pool);
        }
        let solo = e.generate_batch(&[r2.clone()]);
        assert_eq!(s2.generated(), &solo[0].tokens[..]);
        assert_eq!(s1.generated(), &e.generate_batch(&[r1])[0].tokens[..]);
    }

    #[test]
    fn max_new_zero_is_done_without_forward() {
        let e = engine();
        let mut pool = KvCachePool::new(e.config(), 1);
        let st = e.prefill(&GenRequest::new(7, vec![5], 0), &mut pool);
        assert!(st.done);
        assert!(st.generated().is_empty());
        assert_eq!(pool.len(st.slot), 0);
    }

    #[test]
    fn max_new_zero_prefill_begin_is_complete_untouched() {
        let e = engine();
        let mut pool = KvCachePool::new(e.config(), 1);
        let pre = e.prefill_begin(&GenRequest::new(7, vec![5, 6], 0), &mut pool);
        assert!(pre.is_complete());
        assert_eq!(pre.remaining(), 0);
        let st = pre.into_state();
        assert!(st.done && st.generated().is_empty());
        assert_eq!(pool.len(st.slot), 0);
    }

    /// Drive one request through the chunked prefill primitives (`chunk`
    /// prompt tokens per tick) and then decode to completion.
    fn chunked_generate(e: &Engine, req: &GenRequest, chunk: usize) -> Vec<u32> {
        let mut pool = KvCachePool::with_dtype(e.config(), 1, e.kv_dtype());
        let mut pre = e.prefill_begin(req, &mut pool);
        while !pre.is_complete() {
            let mut active = vec![&mut pre];
            let stats = e.step_chunked(&mut active, &mut [], chunk, usize::MAX, &mut pool);
            assert!(stats.prefill_tokens > 0, "chunked prefill stalled");
            assert!(stats.prefill_tokens <= chunk, "chunk cap violated");
        }
        let mut st = pre.into_state();
        while !st.done {
            let mut active: Vec<&mut SeqState> = vec![&mut st];
            e.decode_step(&mut active, &mut pool);
        }
        st.generated().to_vec()
    }

    #[test]
    fn chunked_prefill_matches_oneshot_every_chunk_size() {
        // Any chunk schedule must reproduce the one-shot prefill's tokens
        // exactly — the correctness bar that lets the scheduler split long
        // prompts across ticks.
        let e = engine();
        let prompt = vec![5u32, 6, 7, 11, 13, 2, 9, 40, 41];
        let req = GenRequest::new(1, prompt, 6);
        let want = e.generate_batch(std::slice::from_ref(&req))[0].tokens.clone();
        for chunk in [1usize, 2, 3, 4, 16] {
            assert_eq!(chunked_generate(&e, &req, chunk), want, "chunk {chunk}");
        }
    }

    #[test]
    fn step_chunked_interleaves_prefill_with_decode() {
        // A prompt chunk and live decode steps share one batched tick; both
        // sequences must still match their solo references token for token.
        let e = engine();
        let mut pool = KvCachePool::new(e.config(), 2);
        let ra = GenRequest::new(1, vec![5, 6, 7], 4);
        let rb = GenRequest::new(2, vec![20, 21, 22, 23, 24, 25, 26, 27], 3);
        let mut sa = e.prefill(&ra, &mut pool);
        let mut pre_b = e.prefill_begin(&rb, &mut pool);
        while !pre_b.is_complete() {
            let mut pres = vec![&mut pre_b];
            let mut decs: Vec<&mut SeqState> = vec![&mut sa];
            let stats = e.step_chunked(&mut pres, &mut decs, 3, usize::MAX, &mut pool);
            assert!(stats.prefill_tokens > 0 && stats.prefill_tokens <= 3);
        }
        let mut sb = pre_b.into_state();
        loop {
            let mut decs: Vec<&mut SeqState> =
                [&mut sa, &mut sb].into_iter().filter(|s| !s.done).collect();
            if decs.is_empty() {
                break;
            }
            e.decode_step(&mut decs, &mut pool);
        }
        assert_eq!(sa.generated(), &e.generate_batch(&[ra])[0].tokens[..], "decode seq");
        assert_eq!(sb.generated(), &e.generate_batch(&[rb])[0].tokens[..], "chunked seq");
    }

    #[test]
    fn prefill_budget_caps_total_chunk_tokens_per_tick() {
        // Two prefills, per-sequence chunk 4 but a tick budget of 6: the
        // first feeds 4, the second only 2, and nothing completes early.
        let e = engine();
        let mut pool = KvCachePool::new(e.config(), 2);
        let ra = GenRequest::new(1, vec![5, 6, 7, 8, 9, 10], 2);
        let rb = GenRequest::new(2, vec![30, 31, 32, 33, 34, 35], 2);
        let mut pa = e.prefill_begin(&ra, &mut pool);
        let mut pb = e.prefill_begin(&rb, &mut pool);
        let mut pres = vec![&mut pa, &mut pb];
        let stats = e.step_chunked(&mut pres, &mut [], 4, 6, &mut pool);
        assert_eq!(stats.prefill_tokens, 6);
        assert_eq!(stats.first_tokens, 0);
        assert_eq!((pa.remaining(), pb.remaining()), (2, 4));
        // A budget of 0 feeds nothing at all.
        let mut pres = vec![&mut pa, &mut pb];
        let stats = e.step_chunked(&mut pres, &mut [], 4, 0, &mut pool);
        assert_eq!(stats.prefill_tokens, 0);
        // Unbounded ticks finish both; tokens match the one-shot batch.
        loop {
            let mut pres: Vec<&mut PrefillState> =
                [&mut pa, &mut pb].into_iter().filter(|p| !p.is_complete()).collect();
            if pres.is_empty() {
                break;
            }
            e.step_chunked(&mut pres, &mut [], usize::MAX, usize::MAX, &mut pool);
        }
        let (sa, sb) = (pa.into_state(), pb.into_state());
        let solo = e.generate_batch(&[ra, rb]);
        assert_eq!(sa.generated()[0], solo[0].tokens[0]);
        assert_eq!(sb.generated()[0], solo[1].tokens[0]);
    }
}
