//! Generation engine: batched greedy decoding over a (compressed) model.
//!
//! Serving is split into the standard prefill/decode phases: the prompt is
//! prefilled once through [`forward_cached`] (populating a [`KvCache`]),
//! then each generated token is a single-position incremental step — no
//! more quadratic full-sequence re-forward per token. Compressed models can
//! run kernel-backed ([`Engine::with_kernels`]): every linear matmul
//! dispatches to packed int4 / int4-2:4 kernels, which is where the paper's
//! Fig. 3/4 kernel speedups reach end-to-end token throughput
//! (measured by `benches/decode.rs`).

use crate::model::{
    forward_cached, CompressedWeights, KvCache, Linears, ModelConfig, Overrides, Weights,
};
use crate::tensor::Matrix;
use std::sync::Arc;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// A servable model: config + weights (+ compression overrides or packed
/// kernels).
pub struct Engine {
    pub name: String,
    cfg: ModelConfig,
    weights: Arc<Weights>,
    overrides: Option<Arc<Overrides>>,
    kernels: Option<Arc<CompressedWeights>>,
}

impl Engine {
    pub fn new(
        name: &str,
        cfg: ModelConfig,
        weights: Arc<Weights>,
        overrides: Option<Arc<Overrides>>,
    ) -> Self {
        Engine { name: name.to_string(), cfg, weights, overrides, kernels: None }
    }

    /// Kernel-backed engine: linear matmuls run on packed compressed
    /// kernels instead of dense f32 effective-weight overrides.
    pub fn with_kernels(
        name: &str,
        cfg: ModelConfig,
        weights: Arc<Weights>,
        kernels: Arc<CompressedWeights>,
    ) -> Self {
        Engine { name: name.to_string(), cfg, weights, overrides: None, kernels: Some(kernels) }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The linear-layer backend this engine serves with.
    fn linears(&self) -> Linears<'_> {
        if let Some(cw) = &self.kernels {
            Linears::Kernels(cw.as_ref())
        } else if let Some(ov) = &self.overrides {
            Linears::Overrides(ov.as_ref())
        } else {
            Linears::Dense
        }
    }

    /// Greedy-decode a batch of requests together. Prompts are left-padded
    /// with BOS(0) to a common length, prefilled once into a [`KvCache`],
    /// then decoding runs `max(max_new)` single-token steps with
    /// per-request result truncation to each request's own `max_new`.
    pub fn generate_batch(&self, reqs: &[GenRequest]) -> Vec<GenResult> {
        if reqs.is_empty() {
            return vec![];
        }
        let max_prompt = reqs.iter().map(|r| r.prompt.len()).max().unwrap().max(1);
        let max_new = reqs.iter().map(|r| r.max_new).max().unwrap_or(0);
        let mut seqs: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| {
                let mut s = vec![0u32; max_prompt - r.prompt.len()];
                s.extend_from_slice(&r.prompt);
                s
            })
            .collect();

        if max_new > 0 {
            let linears = self.linears();
            let mut cache = KvCache::new(&self.cfg, seqs.len());

            // Prefill the trailing `win` tokens of every sequence into the
            // cache and greedily append each sequence's next token. Used
            // once for the prompt and again by the overflow path below.
            let prefill = |cache: &mut KvCache, seqs: &mut Vec<Vec<u32>>, win: usize| {
                let toks: Vec<u32> = seqs
                    .iter()
                    .flat_map(|s| s[s.len() - win..].iter().copied())
                    .collect();
                let logits = forward_cached(&self.cfg, &self.weights, &toks, cache, &linears);
                for (bi, seq) in seqs.iter_mut().enumerate() {
                    seq.push(argmax(logits.row(bi * win + win - 1)) as u32);
                }
            };

            // ── Prefill: one pass over the (windowed) prompts ─────────
            prefill(&mut cache, &mut seqs, max_prompt.min(self.cfg.max_seq));

            // ── Decode: one incremental step per generated token ──────
            for _ in 1..max_new {
                if cache.len() == self.cfg.max_seq {
                    // Context overflow: re-prefill the full sliding window.
                    // This costs a prompt-sized pass per token — exactly the
                    // legacy full-reforward behavior (and its outputs), paid
                    // only in the rare generate-past-context regime.
                    cache.reset();
                    prefill(&mut cache, &mut seqs, self.cfg.max_seq);
                } else {
                    // Feed only the tokens appended last step.
                    let toks: Vec<u32> = seqs.iter().map(|s| *s.last().unwrap()).collect();
                    let logits =
                        forward_cached(&self.cfg, &self.weights, &toks, &mut cache, &linears);
                    for (bi, seq) in seqs.iter_mut().enumerate() {
                        seq.push(argmax(logits.row(bi)) as u32);
                    }
                }
            }
        }

        reqs.iter()
            .zip(seqs.iter())
            .map(|(r, s)| GenResult {
                id: r.id,
                tokens: s[max_prompt..max_prompt + r.max_new].to_vec(),
            })
            .collect()
    }

    /// Per-token logits for one sequence (used by the API's scoring mode).
    /// Runs as a fresh-cache prefill so compressed engines score through
    /// the same kernel path they decode with.
    pub fn score(&self, tokens: &[u32]) -> Matrix {
        let seq = tokens.len().min(self.cfg.max_seq);
        if seq == 0 {
            return Matrix::zeros(0, self.cfg.vocab);
        }
        let mut cache = KvCache::new(&self.cfg, 1);
        forward_cached(
            &self.cfg,
            &self.weights,
            &tokens[tokens.len() - seq..],
            &mut cache,
            &self.linears(),
        )
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, forward, init, Batch};
    use crate::rng::Pcg32;

    fn engine() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        Engine::new("test", cfg, Arc::new(w), None)
    }

    /// Legacy decode loop (full quadratic re-forward each step) — the
    /// reference the cached path must reproduce.
    fn legacy_generate(e: &Engine, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let cfg = e.config().clone();
        let mut seq = prompt.to_vec();
        for _ in 0..max_new {
            let cur = seq.len().min(cfg.max_seq);
            let batch = Batch::new(seq[seq.len() - cur..].to_vec(), 1, cur);
            let logits = forward(&cfg, &e.weights, &batch, None, None);
            seq.push(argmax(logits.row(cur - 1)) as u32);
        }
        seq[prompt.len()..].to_vec()
    }

    #[test]
    fn generates_requested_counts() {
        let e = engine();
        let reqs = vec![
            GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 4 },
            GenRequest { id: 2, prompt: vec![9], max_new: 4 },
        ];
        let out = e.generate_batch(&reqs);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[1].id, 2);
        assert!(out.iter().all(|r| r.tokens.iter().all(|&t| (t as usize) < 512)));
    }

    #[test]
    fn per_request_max_new_respected() {
        // Mixed stop counts: each request gets exactly its own max_new.
        // (The old `min(..).max(max(..))` expression was a confusing no-op
        // — always the max — so this behavior predates the cleanup; the
        // test pins it against the rewritten decode loop.)
        let e = engine();
        let reqs = vec![
            GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 2 },
            GenRequest { id: 2, prompt: vec![8, 9, 10], max_new: 6 },
        ];
        let out = e.generate_batch(&reqs);
        assert_eq!(out[0].tokens.len(), 2);
        assert_eq!(out[1].tokens.len(), 6);
        // The shorter request's tokens are a prefix of what it would have
        // produced alone.
        let solo = e.generate_batch(&[GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 6 }]);
        assert_eq!(solo[0].tokens[..2], out[0].tokens[..]);
    }

    #[test]
    fn cached_decode_matches_legacy_full_forward() {
        let e = engine();
        let prompt = vec![5u32, 6, 7, 11];
        let want = legacy_generate(&e, &prompt, 6);
        let got =
            e.generate_batch(&[GenRequest { id: 1, prompt: prompt.clone(), max_new: 6 }]);
        assert_eq!(got[0].tokens, want);
    }

    #[test]
    fn batched_equals_single() {
        // Greedy decoding must be batching-invariant when prompts share a
        // length (no padding effects).
        let e = engine();
        let r1 = GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 3 };
        let r2 = GenRequest { id: 2, prompt: vec![8, 9, 10], max_new: 3 };
        let both = e.generate_batch(&[r1.clone(), r2.clone()]);
        let solo1 = e.generate_batch(&[r1]);
        let solo2 = e.generate_batch(&[r2]);
        assert_eq!(both[0].tokens, solo1[0].tokens);
        assert_eq!(both[1].tokens, solo2[0].tokens);
    }

    #[test]
    fn long_generation_survives_context_overflow() {
        // Generate past max_seq: the sliding-window re-prefill must keep
        // going AND reproduce the legacy full-reforward outputs token for
        // token across the overflow boundary.
        let e = engine();
        let max_seq = e.config().max_seq;
        let prompt = vec![3u32, 4, 5];
        let max_new = max_seq + 5;
        let out = e.generate_batch(&[GenRequest { id: 1, prompt: prompt.clone(), max_new }]);
        assert_eq!(out[0].tokens.len(), max_new);
        assert_eq!(out[0].tokens, legacy_generate(&e, &prompt, max_new));
    }

    #[test]
    fn kernel_engine_matches_override_engine() {
        use crate::compress::CompressConfig;
        use crate::model::{compress_model, ActivationTap, CompressedWeights};
        use crate::sparse::SparsityPattern;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(2);
        let w = init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..64).map(|_| rng.below(cfg.vocab as u32)).collect();
        let batch = Batch::new(toks, 2, 32);
        let mut taps = ActivationTap::new();
        forward(&cfg, &w, &batch, Some(&mut taps), None);
        let cm = compress_model(&cfg, &w, &taps, &CompressConfig::slim(SparsityPattern::TWO_FOUR));
        let weights = Arc::new(w);
        let cw = Arc::new(CompressedWeights::from_model(&cm));
        let e_ov = Engine::new("ov", cfg.clone(), weights.clone(), Some(Arc::new(cm.overrides)));
        let e_kn = Engine::with_kernels("kn", cfg.clone(), weights, cw);
        // Kernel-path logits match the dense-override path.
        let score_ov = e_ov.score(&[5, 6, 7, 8]);
        let score_kn = e_kn.score(&[5, 6, 7, 8]);
        assert!(score_kn.rel_err(&score_ov) < 1e-4, "err {}", score_kn.rel_err(&score_ov));
        // And the kernel engine generates well-formed batches.
        let out = e_kn.generate_batch(&[GenRequest { id: 1, prompt: vec![5, 6], max_new: 4 }]);
        assert_eq!(out[0].tokens.len(), 4);
    }

    #[test]
    fn empty_batch_ok() {
        let e = engine();
        assert!(e.generate_batch(&[]).is_empty());
    }
}
