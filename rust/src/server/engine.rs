//! Generation engine: batched greedy decoding over a (compressed) model.

use crate::model::{forward, Batch, ModelConfig, Overrides, Weights};
use crate::tensor::Matrix;
use std::sync::Arc;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// A servable model: config + weights (+ compression overrides).
pub struct Engine {
    pub name: String,
    cfg: ModelConfig,
    weights: Arc<Weights>,
    overrides: Option<Arc<Overrides>>,
}

impl Engine {
    pub fn new(
        name: &str,
        cfg: ModelConfig,
        weights: Arc<Weights>,
        overrides: Option<Arc<Overrides>>,
    ) -> Self {
        Engine { name: name.to_string(), cfg, weights, overrides }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Greedy-decode a batch of requests together. Prompts are left-padded
    /// with BOS(0) to a common length; decoding runs `max(max_new)` steps
    /// with per-request early stop bookkeeping.
    pub fn generate_batch(&self, reqs: &[GenRequest]) -> Vec<GenResult> {
        if reqs.is_empty() {
            return vec![];
        }
        let max_prompt = reqs.iter().map(|r| r.prompt.len()).max().unwrap().max(1);
        let max_new = reqs.iter().map(|r| r.max_new).min().unwrap_or(0)
            .max(reqs.iter().map(|r| r.max_new).max().unwrap_or(0));
        let mut seqs: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| {
                let mut s = vec![0u32; max_prompt - r.prompt.len()];
                s.extend_from_slice(&r.prompt);
                s
            })
            .collect();

        for _ in 0..max_new {
            let cur_len = seqs[0].len().min(self.cfg.max_seq);
            let toks: Vec<u32> = seqs
                .iter()
                .flat_map(|s| s[s.len() - cur_len..].iter().copied())
                .collect();
            let batch = Batch::new(toks, seqs.len(), cur_len);
            let logits = forward(
                &self.cfg,
                &self.weights,
                &batch,
                None,
                self.overrides.as_deref(),
            );
            for (bi, seq) in seqs.iter_mut().enumerate() {
                let row = logits.row(bi * cur_len + cur_len - 1);
                let next = argmax(row);
                seq.push(next as u32);
            }
        }

        reqs.iter()
            .zip(seqs.iter())
            .map(|(r, s)| GenResult {
                id: r.id,
                tokens: s[max_prompt..max_prompt + r.max_new.min(max_new)].to_vec(),
            })
            .collect()
    }

    /// Per-token logits for one sequence (used by the API's scoring mode).
    pub fn score(&self, tokens: &[u32]) -> Matrix {
        let seq = tokens.len().min(self.cfg.max_seq);
        let batch = Batch::new(tokens[tokens.len() - seq..].to_vec(), 1, seq);
        forward(&self.cfg, &self.weights, &batch, None, self.overrides.as_deref())
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;

    fn engine() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        Engine::new("test", cfg, Arc::new(w), None)
    }

    #[test]
    fn generates_requested_counts() {
        let e = engine();
        let reqs = vec![
            GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 4 },
            GenRequest { id: 2, prompt: vec![9], max_new: 4 },
        ];
        let out = e.generate_batch(&reqs);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[1].id, 2);
        assert!(out.iter().all(|r| r.tokens.iter().all(|&t| (t as usize) < 512)));
    }

    #[test]
    fn batched_equals_single() {
        // Greedy decoding must be batching-invariant when prompts share a
        // length (no padding effects).
        let e = engine();
        let r1 = GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 3 };
        let r2 = GenRequest { id: 2, prompt: vec![8, 9, 10], max_new: 3 };
        let both = e.generate_batch(&[r1.clone(), r2.clone()]);
        let solo1 = e.generate_batch(&[r1]);
        let solo2 = e.generate_batch(&[r2]);
        assert_eq!(both[0].tokens, solo1[0].tokens);
        assert_eq!(both[1].tokens, solo2[0].tokens);
    }

    #[test]
    fn empty_batch_ok() {
        let e = engine();
        assert!(e.generate_batch(&[]).is_empty());
    }
}
