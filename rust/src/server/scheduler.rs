//! Continuous-batching scheduler: a token-budget step-loop over in-flight
//! sequences with per-sequence KV cache slots and chunked prefill.
//!
//! The fixed-batch worker (`Router::register`) forms a batch, runs it to
//! completion, and makes every request pay for the slowest one in its
//! batch. The scheduler removes the lockstep (vLLM-style), and — since
//! this revision — also removes the last head-of-line blocker: monolithic
//! prompt prefill. Every tick is ONE batched forward
//! ([`Engine::step_chunked`]) whose size is bounded by a **token budget**:
//!
//! * **Admit** — between ticks it drains queued requests
//!   ([`Batcher::take_admit`]) into free [`KvCachePool`] slots per the
//!   route's [`AdmitPolicy`] — strict FIFO, shortest-job-first on
//!   `max_new`, or per-client fair share over `GenRequest::client_id` /
//!   `priority`. Admission claims the slot and creates a resumable
//!   [`PrefillState`]; no forward pass runs yet, so admitting a long
//!   prompt is O(1).
//! * **Step** — the tick's forward interleaves work from both phases:
//!   every in-flight decode sequence advances one token, and every
//!   admitted-but-unprefilled prompt feeds its next chunk (≤
//!   `chunk_tokens` per sequence; the tick's prefill total is capped at
//!   `step_tokens − #decodes`). A 4×-long prompt therefore costs each
//!   tick at most one chunk of extra work instead of stalling every
//!   batchmate's decode for a whole monolithic prefill — TTFT for
//!   concurrent short requests stays flat (measured by the head-of-line
//!   scenario in `benches/serve.rs`). Chunking is invisible in the
//!   output: chunked prefill is token-for-token identical to one-shot
//!   prefill for every chunk size and KV dtype (bit-equal logits on f32
//!   — see `tests/property.rs`), so greedy results still equal solo
//!   decode exactly.
//! * **Retire** — a prefill that finishes its prompt emits its first
//!   token (that is when TTFT is recorded, and it is returned to the
//!   client in `GenResult::ttft_s`) and joins the decode batch; a
//!   sequence leaves the moment it hits its own `max_new` or stop token,
//!   and its ring slot returns to the pool free-list for the next
//!   admission.
//!
//! A route can also serve **speculatively** ([`Scheduler::new_spec`]): the
//! tick's decode side then drafts `SchedPolicy::draft_k` tokens per
//! sequence on a compressed draft engine and verifies them all in the ONE
//! batched target forward (see `server::spec` for the draft/verify/
//! rollback step), emitting 1..=`draft_k`+1 verified tokens per sequence
//! per tick — token-identical to the plain route, with the emitted tokens
//! counted against the same `step_tokens` budget.
//!
//! Two delivery upgrades ride the same tick structure. **Streaming**
//! ([`Batcher::submit_stream`]): after every tick the scheduler pushes
//! each flight's newly generated tokens to its client as
//! [`StreamEvent::Token`] frames — tokens leave the moment they exist
//! instead of at retirement — and the final [`StreamEvent::Done`] carries
//! the exact [`GenResult`] a plain submit would have returned. The
//! emission cadence lands in the route's inter-token-gap histogram for
//! every flight, streamed or not. **Sessions**
//! (`SchedPolicy::max_sessions`, `server::session`): a retiring session
//! turn parks its KV slot in the route's [`SessionTable`] instead of
//! freeing it, and the next turn resumes onto the cached rows
//! ([`Engine::prefill_resume`]) so only the conversation's *new* tokens
//! prefill. Parked slots are a cache, not a reservation: plain admissions
//! reclaim them LRU-first whenever the pool runs dry.
//!
//! The serving pool is **paged** (`model::KvCachePool`: fixed-size pages,
//! ref-counted frames, per-sequence page tables), which buys the loop two
//! more moves. **Prefix caching**: non-speculative routes hash each
//! admitted prompt's full prefix pages; a later request whose prompt
//! starts with an already-resident prefix maps those shared frames
//! (refcount bump, zero copies) and prefills only the tail — its TTFT is
//! one partial prefill instead of the whole prompt, and the pool's
//! hit/miss/saved-token counters land in the route metrics
//! (`slim_prefix_cache_*`). **Preemption**: when every slot is busy and a
//! strictly higher-priority request waits, the scheduler releases the
//! lowest-priority running sequence's pages ([`KvCachePool::free`] —
//! shared frames survive under their refcounts) and parks it as a
//! resumable entry; freed capacity admits the urgent
//! arrival immediately, and the victim re-enters through
//! [`Engine::prefill_reprise`] (a windowed re-prefill over prompt +
//! generated-so-far, chunked like any admission — token-identical, see
//! the forced-preemption tests). Only un-wrapped plain sequences are
//! eligible: a ring slot past `max_seq` keeps write-time rotary bases a
//! re-prefill would rebase, and speculative routes must keep their twin
//! draft pool in slot lockstep. `SchedPolicy::preempt_every` forces a
//! preemption every k ticks for tests and benches.
//!
//! Generation depth never stalls the loop (ring slots make decode O(1)
//! per token), and prompt *length* no longer stalls it either: per-tick
//! forward cost is bounded by `max(step_tokens, live decodes)` — live
//! decodes always advance, prompt chunks fill the remaining budget —
//! whatever mix of phases is in flight. When nothing is in flight the loop parks untimed on the
//! batcher condvar ([`Batcher::wait_pending`]) — an idle server burns no
//! CPU. Greedy decoding through per-sequence slots is batching-invariant,
//! so any arrival order, admission policy, and chunk schedule yields each
//! request's solo-decode tokens (tested below for dense and kernel-backed
//! engines, f32 and quantized KV).

use super::batcher::{AdmitPolicy, AdmitState, Batcher};
use super::engine::{Engine, GenRequest, GenResult, PrefillState, SeqState, StreamEvent};
use super::metrics::Metrics;
use super::obs::{EventKind, RouteObs};
use super::session::SessionTable;
use super::spec::{SpecEngine, SpecStepStats};
use crate::model::{KvCachePool, KvDtype};
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

/// Scheduler policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedPolicy {
    /// Concurrent sequence slots (the decode batch cap).
    pub max_slots: usize,
    /// Storage dtype for the serving KV cache pool: `None` (default)
    /// inherits the engine's own dtype ([`Engine::kv_dtype`]), so the
    /// scheduler and the engine's solo reference paths always agree;
    /// `Some(..)` overrides it for this route. int8 / fp8 hold ~4× fewer
    /// cache bytes per decode step, and greedy output stays
    /// batching-invariant (quantization is per sequence row).
    pub kv_dtype: Option<KvDtype>,
    /// Per-tick token budget: each tick's batched forward processes every
    /// live decode sequence (one token each — the tick's floor; keep
    /// `step_tokens ≥ max_slots` or prefills stall whenever the decode
    /// batch is full) plus at most `step_tokens − #decodes` prompt-chunk
    /// tokens. Bounds the tick's latency — and therefore every
    /// batchmate's per-token decode latency — whatever prompt lengths are
    /// in flight. Setting this AND `chunk_tokens` to `usize::MAX`
    /// restores monolithic prefill (the pre-chunking behavior, kept
    /// measurable by the serve bench's head-of-line scenario).
    pub step_tokens: usize,
    /// Prompt tokens any ONE prefill may feed per tick (its chunk size).
    /// Smaller chunks spread a long prompt across more ticks, trading its
    /// own TTFT for everyone else's.
    pub chunk_tokens: usize,
    /// Which queued requests to admit when slots are scarce (FIFO /
    /// shortest-job-first / per-client fair share).
    pub admit: AdmitPolicy,
    /// Speculative draft depth: tokens the compressed draft model proposes
    /// per sequence per tick on speculative routes
    /// ([`Scheduler::new_spec`] / `Router::register_speculative`; must be
    /// ≥ 1 there). 0 — the default — means the route decodes plainly and
    /// the field is inert. Each speculative tick emits 1..=`draft_k`+1
    /// verified tokens per sequence; emitted tokens count against
    /// `step_tokens` (each in-flight sequence reserves `draft_k + 1`
    /// budget), while the draft model's own forwards are off-budget extra
    /// work — they are the cheap side of the pair.
    pub draft_k: usize,
    /// Concurrent multi-turn sessions this route may keep open
    /// (`server::session`). 0 — the default — disables sessions. Between
    /// turns a session parks its KV cache slot so the next turn prefills
    /// only its new tokens; parked slots are reclaimed LRU-first whenever
    /// plain admissions find the pool dry, so sessions never shrink the
    /// route's effective capacity (an evicted session re-prefills from
    /// scratch on its next turn).
    pub max_sessions: usize,
    /// Forced-preemption cadence for tests and benches: every k-th tick,
    /// preempt one eligible in-flight sequence (release its pages, requeue
    /// it as a resumable prefill) even without slot pressure, rotating the
    /// victim. 0 — the default — disables forcing; priority-driven
    /// preemption under a full pool is always on for plain routes.
    pub preempt_every: usize,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_slots: 8,
            kv_dtype: None,
            step_tokens: 64,
            chunk_tokens: 32,
            admit: AdmitPolicy::Fifo,
            draft_k: 0,
            max_sessions: 0,
            preempt_every: 0,
        }
    }
}

/// One sequence in the decode phase: its state plus result plumbing.
struct InFlight {
    state: SeqState,
    result_slot: Sender<GenResult>,
    enqueued: Instant,
    /// Submit→first-token latency, set when the prefill completed
    /// (returned to the client in [`GenResult::ttft_s`]).
    ttft_s: Option<f64>,
    /// Draft tokens proposed for this sequence (speculative routes only).
    drafted: usize,
    /// Draft tokens the target confirmed (speculative routes only).
    accepted: usize,
    /// Streamed submission: each tick's newly generated tokens go here as
    /// [`StreamEvent::Token`] frames the moment they exist.
    stream: Option<Sender<StreamEvent>>,
    /// Generated tokens already pushed to `stream` (and already counted
    /// by the emission-cadence metrics).
    streamed: usize,
    /// Wall-clock of the previous emission event, for the per-sequence
    /// inter-token-gap histogram. `None` until the first token.
    last_emit: Option<Instant>,
    /// Session this turn belongs to, if any: retirement parks the slot in
    /// the route's [`SessionTable`] instead of freeing it.
    session: Option<u64>,
    /// Admission priority (`GenRequest::priority`), kept so a full pool
    /// can pick its lowest-priority flight as the preemption victim.
    priority: i32,
}

/// One admitted sequence still feeding its prompt, chunk by chunk.
struct Filling {
    pre: PrefillState,
    result_slot: Sender<GenResult>,
    enqueued: Instant,
    stream: Option<Sender<StreamEvent>>,
    session: Option<u64>,
    priority: i32,
    /// Set when this prefill is a preempted sequence re-feeding its window
    /// ([`Engine::prefill_reprise`]): promotion restores the carried
    /// delivery state instead of starting fresh (TTFT was already
    /// recorded; streamed clients must not see their tokens twice).
    carry: Option<ResumeCarry>,
}

/// Delivery state that survives a preemption: everything the original
/// [`InFlight`] had already told the client or the metrics.
struct ResumeCarry {
    ttft_s: Option<f64>,
    drafted: usize,
    accepted: usize,
    streamed: usize,
    last_emit: Option<Instant>,
}

/// A preempted sequence waiting for a free slot: its pages are released
/// (shared frames live on under their refcounts) and its full state —
/// prompt, generated tokens, sampler position — rides along, so resuming
/// is an ordinary windowed re-prefill that continues the exact token
/// stream.
struct Preempted {
    state: SeqState,
    result_slot: Sender<GenResult>,
    enqueued: Instant,
    priority: i32,
    stream: Option<Sender<StreamEvent>>,
    carry: ResumeCarry,
}

/// Drives an [`Engine`] continuously over a [`Batcher`] queue.
pub struct Scheduler {
    engine: Arc<Engine>,
    policy: SchedPolicy,
    /// Set on speculative routes ([`Scheduler::new_spec`]): the tick runs
    /// draft/verify/rollback through this pair instead of a plain
    /// `Engine::step_chunked`; `engine` is then the pair's dense target.
    spec: Option<SpecEngine>,
    /// Multi-turn session registry (`SchedPolicy::max_sessions`; inert
    /// when 0). Shared with the router front-end, which opens sessions and
    /// builds their prompts; the scheduler resumes, parks, evicts and
    /// reaps the underlying cache slots.
    sessions: Arc<SessionTable>,
}

impl Scheduler {
    pub fn new(engine: Arc<Engine>, policy: SchedPolicy) -> Self {
        assert!(policy.max_slots > 0, "scheduler needs at least one slot");
        assert!(policy.step_tokens > 0, "token budget must be positive");
        assert!(policy.chunk_tokens > 0, "chunk size must be positive");
        let sessions = Arc::new(SessionTable::new(policy.max_sessions));
        Scheduler { engine, policy, spec: None, sessions }
    }

    /// Speculative scheduler: `draft` (compressed) proposes
    /// `policy.draft_k` tokens per sequence per tick, `target` (dense)
    /// verifies them in the tick's one batched forward — output stays
    /// token-identical to a plain `target` route, only faster. The serving
    /// pool follows `target` (plus `policy.kv_dtype` overrides, as usual);
    /// the twin draft pool follows `draft`'s own dtype/layout.
    pub fn new_spec(target: Arc<Engine>, draft: Arc<Engine>, policy: SchedPolicy) -> Self {
        assert!(policy.draft_k >= 1, "speculative scheduler needs SchedPolicy::draft_k >= 1");
        let spec = SpecEngine::new(Arc::clone(&target), draft, policy.draft_k);
        Scheduler { spec: Some(spec), ..Self::new(target, policy) }
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// The KV dtype this scheduler's pool stores (policy override, or the
    /// engine's own dtype).
    pub fn kv_dtype(&self) -> KvDtype {
        self.policy.kv_dtype.unwrap_or_else(|| self.engine.kv_dtype())
    }

    /// This route's session registry (inert unless
    /// `SchedPolicy::max_sessions > 0`). The router clones the handle so
    /// its front-end threads can open/append/drop sessions while the
    /// scheduler thread moves the slots.
    pub fn sessions(&self) -> Arc<SessionTable> {
        Arc::clone(&self.sessions)
    }

    /// Run the step-loop until the batcher is closed and fully drained
    /// (queued requests are still served after `close`; in-flight
    /// sequences always run to completion). `obs` carries the route's
    /// metrics plus the shared flight recorder: every admission, prefill
    /// chunk, decode/verify step, and retirement lands in both.
    pub fn run(&self, batcher: &Batcher, obs: &RouteObs) {
        let metrics = &obs.metrics;
        let mut pool = KvCachePool::with_layout(
            self.engine.config(),
            self.policy.max_slots,
            self.kv_dtype(),
            self.engine.kv_layout(),
        );
        // Speculative routes keep a twin pool for the draft model's cache.
        // Slot allocation stays in lockstep with the serving pool (both
        // free-lists start identical and every alloc/free is paired), so
        // the same slot id addresses a sequence in both pools.
        let mut draft_pool: Option<KvCachePool> = self.spec.as_ref().map(|s| {
            KvCachePool::with_layout(
                s.draft().config(),
                self.policy.max_slots,
                s.draft().kv_dtype(),
                s.draft().kv_layout(),
            )
        });
        // Prefix caching shares prompt-prefix pages across requests via
        // refcount bumps in the serving pool. Speculative routes opt out:
        // their twin draft pool must allocate in slot lockstep, and shared
        // frames in one pool but not the other would break the pairing.
        if self.spec.is_none() {
            pool.set_prefix_cache(true);
        }
        let mut flights: Vec<InFlight> = Vec::new();
        let mut filling: Vec<Filling> = Vec::new();
        let mut preempted: VecDeque<Preempted> = VecDeque::new();
        let mut admit_state = AdmitState::default();
        let mut tick: u64 = 0;
        loop {
            // ── Admit ─────────────────────────────────────────────────
            if flights.is_empty()
                && filling.is_empty()
                && preempted.is_empty()
                && !batcher.wait_pending()
            {
                // Closed + drained + nothing in flight. Every non-session
                // retirement returned its pages; whatever is still mapped
                // belongs to parked session slots, and the refcount
                // bookkeeping must balance exactly (the leak check).
                assert!(pool.refs_balanced(), "kv page refcounts out of balance at shutdown");
                return;
            }
            // Slots surrendered by dropped sessions since the last tick:
            // only this thread may touch the pools, so drops are lazy.
            for slot in self.sessions.take_reaped() {
                pool.free(slot);
                if let Some(dp) = draft_pool.as_mut() {
                    dp.free(slot);
                }
            }
            // Capacity check counts live work only: parked session slots
            // are reclaimable on demand (resume or LRU eviction below), so
            // they never block admission.
            let mut free = self.policy.max_slots - flights.len() - filling.len();
            // Priority preemption: a full pool with a strictly more urgent
            // request waiting evicts its lowest-priority eligible flight —
            // interactive arrivals never wait behind bulk work.
            if free == 0 && self.spec.is_none() {
                if let Some(top) = batcher.peek_priority() {
                    let victim = flights
                        .iter()
                        .enumerate()
                        .filter(|(_, f)| self.preemptible(f) && f.priority < top)
                        .min_by_key(|&(_, f)| f.priority)
                        .map(|(i, _)| i);
                    if let Some(i) = victim {
                        let f = flights.swap_remove(i);
                        self.preempt(f, &mut pool, &mut preempted);
                        free = 1;
                    }
                }
            }
            let pendings = batcher.take_admit(free, self.policy.admit, &mut admit_state);
            if !pendings.is_empty() {
                // Backlog at admission time: what we just took plus what
                // still waits behind it.
                let depth = batcher.depth() + pendings.len();
                metrics.record_queue_depth(depth);
                for pending in pendings {
                    let wait_s = pending.wait_so_far().as_secs_f64();
                    metrics.record_queue_wait(wait_s);
                    // O(1): claims the slot, runs no forward — the prompt
                    // feeds in chunks inside the regular ticks below.
                    let pre = self.admit_one(&pending.req, &mut pool, draft_pool.as_mut());
                    obs.event(
                        EventKind::Admitted,
                        pre.state().id,
                        pre.state().slot as u32,
                        pending.req.prompt.len().min(u32::MAX as usize) as u32,
                        (wait_s * 1e6).min(u32::MAX as f64) as u32,
                        depth.min(u32::MAX as usize) as u32,
                    );
                    let session = pending.req.session;
                    if pre.is_complete() {
                        // max_new == 0: nothing to run, retire untouched.
                        let flight = InFlight {
                            state: pre.into_state(),
                            result_slot: pending.result_slot,
                            enqueued: pending.enqueued,
                            ttft_s: None,
                            drafted: 0,
                            accepted: 0,
                            stream: pending.stream,
                            streamed: 0,
                            last_emit: None,
                            session,
                            priority: pending.req.priority,
                        };
                        self.retire(flight, &mut pool, draft_pool.as_mut(), obs);
                    } else {
                        filling.push(Filling {
                            pre,
                            result_slot: pending.result_slot,
                            enqueued: pending.enqueued,
                            stream: pending.stream,
                            session,
                            priority: pending.req.priority,
                            carry: None,
                        });
                    }
                }
            }
            // Resume preempted sequences into whatever capacity admission
            // left over, oldest first: each re-enters as a chunked windowed
            // re-prefill over prompt + generated-so-far and picks up its
            // carried delivery state at promotion.
            let mut free = self.policy.max_slots - flights.len() - filling.len();
            while free > 0 {
                let Some(p) = preempted.pop_front() else { break };
                let pre = self.engine.prefill_reprise(p.state, &mut pool);
                filling.push(Filling {
                    pre,
                    result_slot: p.result_slot,
                    enqueued: p.enqueued,
                    stream: p.stream,
                    session: None,
                    priority: p.priority,
                    carry: Some(p.carry),
                });
                free -= 1;
            }
            if flights.is_empty() && filling.is_empty() {
                continue; // nothing admitted (e.g. only max_new=0 requests)
            }

            // ── Step: one budgeted batched forward ────────────────────
            // Live decodes always advance; prompt chunks fill whatever
            // budget remains. Each in-flight sequence reserves one budget
            // token — or `draft_k + 1` on speculative routes, where a tick
            // emits up to that many verified tokens per sequence (the
            // draft model's own forwards stay off-budget). When only
            // prefills are in flight the whole budget is theirs, so
            // progress is guaranteed either way.
            let per_flight = self.spec.as_ref().map_or(1, |s| s.draft_k() + 1);
            let budget = self.policy.step_tokens.saturating_sub(flights.len() * per_flight);
            metrics.record_step_occupancy(flights.len() + filling.len());
            metrics.record_kv_pages(pool.page_stats());
            // Flight-recorder pre-tick snapshot: per-prefill remaining
            // prompt and per-decode generated length, so post-tick deltas
            // become chunk/step events. Skipped entirely when the recorder
            // is a no-op sink.
            let rec_on = obs.recorder.enabled();
            let fill_before: Vec<usize> = if rec_on {
                filling.iter().map(|f| f.pre.remaining()).collect()
            } else {
                Vec::new()
            };
            let gen_before: Vec<usize> = if rec_on {
                flights.iter().map(|f| f.state.generated().len()).collect()
            } else {
                Vec::new()
            };
            let t0_us = if rec_on { obs.recorder.now_us() } else { 0 };
            let t0 = Instant::now();
            let stats = {
                let mut pres: Vec<&mut PrefillState> =
                    filling.iter_mut().map(|f| &mut f.pre).collect();
                let mut active: Vec<&mut SeqState> =
                    flights.iter_mut().map(|f| &mut f.state).collect();
                match (&self.spec, draft_pool.as_mut()) {
                    (Some(spec), Some(dp)) => spec.step_chunked(
                        &mut pres,
                        &mut active,
                        self.policy.chunk_tokens,
                        budget,
                        &mut pool,
                        dp,
                    ),
                    _ => {
                        let st = self.engine.step_chunked(
                            &mut pres,
                            &mut active,
                            self.policy.chunk_tokens,
                            budget,
                            &mut pool,
                        );
                        SpecStepStats {
                            prefill_tokens: st.prefill_tokens,
                            first_tokens: st.first_tokens,
                            decode_tokens: st.decode_tokens,
                            decode_seqs: st.decode_tokens,
                            ..Default::default()
                        }
                    }
                }
            };
            let elapsed = t0.elapsed().as_secs_f64();
            // One forward, one busy accounting: the decode side claims the
            // tick's elapsed time when any decode ran; otherwise the
            // prefill side does — including mid-prompt ticks that
            // completed nothing, which still ran a real forward (only
            // first tokens count toward generated-token throughput).
            if stats.decode_tokens > 0 {
                if self.spec.is_some() {
                    // Split the tick into draft (compressed twin) and
                    // verify (dense target) busy stages.
                    metrics.record_spec_decode_step(
                        stats.decode_tokens,
                        stats.decode_seqs,
                        elapsed,
                        stats.draft_s,
                    );
                } else {
                    metrics.record_decode_step(stats.decode_tokens, stats.decode_seqs, elapsed);
                }
                if stats.drafted > 0 {
                    metrics.record_spec_step(stats.drafted, stats.accepted);
                }
                if stats.first_tokens > 0 {
                    metrics.record_prefill(stats.first_tokens, 0.0);
                }
            } else if stats.prefill_tokens > 0 {
                metrics.record_prefill(stats.first_tokens, elapsed);
            }
            // Attribute speculation to its sequences: `active` was built
            // from `flights` in order, so per_seq indices line up.
            for &(j, d, a) in &stats.per_seq {
                flights[j].drafted += d;
                flights[j].accepted += a;
            }
            if rec_on {
                self.record_tick_events(
                    obs,
                    &filling,
                    &flights,
                    &fill_before,
                    &gen_before,
                    &stats,
                    t0_us,
                );
            }

            // ── Retire / promote ──────────────────────────────────────
            // Prefills that finished their prompt emitted their first
            // token this tick: record TTFT and move them to the decode
            // batch (or straight to retirement, e.g. max_new == 1).
            let mut i = 0;
            while i < filling.len() {
                if filling[i].pre.is_complete() {
                    let f = filling.swap_remove(i);
                    // A resumed (previously preempted) prefill restores its
                    // carried delivery state: TTFT was recorded when the
                    // sequence first promoted, streamed clients already
                    // hold its first `streamed` tokens.
                    let (ttft_s, drafted, accepted, streamed, last_emit) = match f.carry {
                        Some(c) => (c.ttft_s, c.drafted, c.accepted, c.streamed, c.last_emit),
                        None => {
                            let ttft = f.enqueued.elapsed().as_secs_f64();
                            metrics.record_ttft(ttft);
                            (Some(ttft), 0, 0, 0, None)
                        }
                    };
                    let flight = InFlight {
                        state: f.pre.into_state(),
                        result_slot: f.result_slot,
                        enqueued: f.enqueued,
                        ttft_s,
                        drafted,
                        accepted,
                        stream: f.stream,
                        streamed,
                        last_emit,
                        session: f.session,
                        priority: f.priority,
                    };
                    // Even a flight done at promotion (max_new == 1, or a
                    // stop on the first token) joins the decode batch for
                    // one beat: the emit pass below streams its token(s)
                    // before the retire scan reclaims it.
                    flights.push(flight);
                } else {
                    i += 1;
                }
            }
            // Push this tick's freshly generated tokens to every streamed
            // client and record the emission cadence (inter-token gaps).
            for flight in flights.iter_mut() {
                Self::emit_stream(flight, metrics);
            }
            let mut i = 0;
            while i < flights.len() {
                if flights[i].state.done {
                    let flight = flights.swap_remove(i);
                    self.retire(flight, &mut pool, draft_pool.as_mut(), obs);
                } else {
                    i += 1;
                }
            }
            // ── Forced preemption (tests / benches) ───────────────────
            // Runs after the retire scan so a finished flight is never
            // parked past its result delivery; the victim index rotates so
            // repeated forcing spreads across the batch.
            tick += 1;
            if self.policy.preempt_every > 0
                && self.spec.is_none()
                && tick % self.policy.preempt_every as u64 == 0
                && !flights.is_empty()
            {
                let start = ((tick / self.policy.preempt_every as u64) as usize) % flights.len();
                let victim = (0..flights.len())
                    .map(|d| (start + d) % flights.len())
                    .find(|&i| self.preemptible(&flights[i]));
                if let Some(i) = victim {
                    let f = flights.swap_remove(i);
                    self.preempt(f, &mut pool, &mut preempted);
                }
            }
        }
    }

    /// Whether a flight may be preempted and later resumed token-identically.
    /// Session turns are excluded (their slot custody belongs to the
    /// [`SessionTable`] lifecycle), and so are sequences whose history has
    /// outgrown the context window: a wrapped ring slot keeps each retained
    /// row's write-time position embedding, which the windowed re-prefill
    /// would rebase — resuming one would change its tokens. (Exactly
    /// `max_seq` is still fine: every retained row was written at base 0.)
    fn preemptible(&self, f: &InFlight) -> bool {
        f.session.is_none() && f.state.history().len() <= self.engine.config().max_seq
    }

    /// Release `f`'s pages back to the pool (shared frames survive under
    /// their refcounts) and park its sequence + delivery state for resume.
    /// Never called on speculative routes — the twin draft pool's slot
    /// must stay paired with the serving slot.
    fn preempt(&self, f: InFlight, pool: &mut KvCachePool, out: &mut VecDeque<Preempted>) {
        pool.free(f.state.slot);
        out.push_back(Preempted {
            state: f.state,
            result_slot: f.result_slot,
            enqueued: f.enqueued,
            priority: f.priority,
            stream: f.stream,
            carry: ResumeCarry {
                ttft_s: f.ttft_s,
                drafted: f.drafted,
                accepted: f.accepted,
                streamed: f.streamed,
                last_emit: f.last_emit,
            },
        });
    }

    /// Claim cache slot(s) for one admitted request and build its
    /// resumable prefill. Session turns resume onto their parked slot when
    /// the full conversation still fits the context window — prefilling
    /// only the uncached suffix ([`Engine::prefill_resume`]); otherwise
    /// (deep conversation, or the slot was evicted) they fall back to a
    /// fresh windowed prefill. Fresh prefills that find the pool dry evict
    /// the LRU parked session slot — parked capacity is a cache, never a
    /// reservation.
    fn admit_one(
        &self,
        req: &GenRequest,
        pool: &mut KvCachePool,
        mut draft_pool: Option<&mut KvCachePool>,
    ) -> PrefillState {
        if let Some(slot) = req.session.and_then(|sid| self.sessions.resume_slot(sid)) {
            if req.prompt.len() <= self.engine.config().max_seq {
                return self.engine.prefill_resume(req, pool, slot);
            }
            // The conversation outgrew the window: the parked prefix is no
            // longer a prefix of the windowed prompt, so start over.
            pool.free(slot);
            if let Some(dp) = draft_pool.as_deref_mut() {
                dp.free(slot);
            }
        }
        if pool.free_slots() == 0 {
            let evicted = self.sessions.evict_lru().expect("admission overran pool capacity");
            pool.free(evicted);
            if let Some(dp) = draft_pool.as_deref_mut() {
                dp.free(evicted);
            }
        }
        let pre = self.engine.prefill_begin(req, pool);
        if let Some(dp) = draft_pool {
            let ds = dp.alloc().expect("draft pool out of slots");
            assert_eq!(ds, pre.state().slot, "twin pools must allocate in lockstep");
        }
        pre
    }

    /// Push `flight`'s tokens generated since the last call to its stream
    /// (if any) and record the route's emission cadence: one
    /// inter-token-gap sample per (sequence, emitting tick) after the
    /// first — the gap before the first emission is TTFT, already its own
    /// histogram. Cadence is recorded for streamed and plain flights
    /// alike; a speculative tick emitting several tokens at once is ONE
    /// emission event (that burstiness is exactly what the histogram is
    /// for).
    fn emit_stream(flight: &mut InFlight, metrics: &Metrics) {
        let generated = flight.state.generated();
        if flight.streamed >= generated.len() {
            return;
        }
        if let Some(prev) = flight.last_emit {
            metrics.record_inter_token(prev.elapsed().as_secs_f64());
        }
        flight.last_emit = Some(Instant::now());
        if let Some(tx) = &flight.stream {
            for (index, &token) in generated.iter().enumerate().skip(flight.streamed) {
                let _ = tx.send(StreamEvent::Token { index, token });
            }
        }
        flight.streamed = generated.len();
    }

    /// Translate one tick's state deltas into flight-recorder events:
    /// a `PrefillChunk` span per prefill that fed tokens, a
    /// `DecodeStep`/`SpecVerify` span per decode sequence that emitted,
    /// and one engine-wide `SpecDraft` span when the tick drafted. All
    /// spans share the tick's `[t0_us, t0_us + dur]` window (the tick is
    /// ONE batched forward — per-sequence splits would be fiction).
    #[allow(clippy::too_many_arguments)]
    fn record_tick_events(
        &self,
        obs: &RouteObs,
        filling: &[Filling],
        flights: &[InFlight],
        fill_before: &[usize],
        gen_before: &[usize],
        stats: &SpecStepStats,
        t0_us: u64,
    ) {
        let dur_us = obs.recorder.now_us().saturating_sub(t0_us);
        for (f, &before) in filling.iter().zip(fill_before) {
            let fed = before.saturating_sub(f.pre.remaining());
            if fed > 0 {
                obs.span(
                    EventKind::PrefillChunk,
                    t0_us,
                    dur_us,
                    f.pre.state().id,
                    f.pre.state().slot as u32,
                    fed as u32,
                    f.pre.is_complete() as u32,
                    0,
                );
            }
        }
        for (j, (f, &before)) in flights.iter().zip(gen_before).enumerate() {
            let emitted = f.state.generated().len().saturating_sub(before);
            if emitted == 0 {
                continue;
            }
            // Fallback (non-speculating) sequences are absent from per_seq
            // and show as plain decode steps even on speculative routes.
            match stats.per_seq.iter().find(|&&(i, _, _)| i == j) {
                Some(&(_, d, a)) => obs.span(
                    EventKind::SpecVerify,
                    t0_us,
                    dur_us,
                    f.state.id,
                    f.state.slot as u32,
                    emitted as u32,
                    d as u32,
                    a as u32,
                ),
                None => obs.span(
                    EventKind::DecodeStep,
                    t0_us,
                    dur_us,
                    f.state.id,
                    f.state.slot as u32,
                    emitted as u32,
                    0,
                    0,
                ),
            }
        }
        if stats.drafted > 0 {
            obs.span(
                EventKind::SpecDraft,
                t0_us,
                (stats.draft_s * 1e6) as u64,
                0, // engine-wide lane, not one request
                0,
                stats.drafted as u32,
                0,
                0,
            );
        }
    }

    /// Reclaim the sequence's cache slot(s) and deliver its result. A
    /// session turn *parks* the slot in the [`SessionTable`] instead of
    /// freeing it — the next turn resumes onto the cached rows — unless
    /// the session was dropped mid-turn. On speculative routes the twin
    /// draft slot follows the serving slot's fate in the same breath
    /// (keeping the pools' free-lists in lockstep; a parked slot stays
    /// allocated in both pools) and the result carries the request's
    /// `(drafted, accepted)` speculation totals. Streamed flights get a
    /// final [`StreamEvent::Done`] after their last `Token` frame.
    fn retire(
        &self,
        flight: InFlight,
        pool: &mut KvCachePool,
        draft_pool: Option<&mut KvCachePool>,
        obs: &RouteObs,
    ) {
        let parked = flight
            .session
            .map(|sid| self.sessions.finish(sid, flight.state.generated(), flight.state.slot))
            .unwrap_or(false);
        let is_spec = draft_pool.is_some();
        if !parked {
            pool.free(flight.state.slot);
            if let Some(dp) = draft_pool {
                dp.free(flight.state.slot);
            }
        }
        let spec = is_spec.then_some((flight.drafted, flight.accepted));
        obs.metrics.record_request(flight.enqueued.elapsed().as_secs_f64());
        if let Some((d, a)) = spec {
            if d > 0 {
                obs.metrics.record_spec_request(d, a);
            }
        }
        obs.event(
            EventKind::Retired,
            flight.state.id,
            flight.state.slot as u32,
            flight.state.generated().len().min(u32::MAX as usize) as u32,
            flight.drafted.min(u32::MAX as usize) as u32,
            flight.accepted.min(u32::MAX as usize) as u32,
        );
        let result = GenResult {
            id: flight.state.id,
            tokens: flight.state.generated().to_vec(),
            ttft_s: flight.ttft_s,
            spec,
        };
        if let Some(tx) = &flight.stream {
            let _ = tx.send(StreamEvent::Done(result.clone()));
        }
        let _ = flight.result_slot.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::LinearOp;
    use crate::model::{by_name, init, CompressedWeights};
    use crate::quant::slim_quant;
    use crate::rng::Pcg32;
    use crate::server::batcher::BatchPolicy;
    use crate::server::engine::GenRequest;
    use std::time::Duration;

    fn dense_engine(seed: u64) -> Arc<Engine> {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(seed);
        let w = init(&cfg, &mut rng);
        Arc::new(Engine::new("dense", cfg, Arc::new(w), None))
    }

    fn kernel_engine(seed: u64) -> Arc<Engine> {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(seed);
        let w = init(&cfg, &mut rng);
        let mut cw = CompressedWeights::new();
        for (name, _d_in, _d_out) in cfg.linear_layers() {
            let q = slim_quant::quantize(w.expect(&name), 4);
            cw.insert(&name, LinearOp::int4(&q, None));
        }
        Arc::new(Engine::with_kernels("kn", cfg, Arc::new(w), Arc::new(cw)))
    }

    /// Run `reqs` through a live scheduler (staggered arrivals) under
    /// `policy` and return each request's tokens, in request order. The
    /// serving pool inherits the engine's own KV dtype unless the policy
    /// overrides it, so solo `generate_batch` runs are the exact
    /// reference.
    fn serve_policy(
        engine: Arc<Engine>,
        reqs: &[GenRequest],
        policy: SchedPolicy,
        stagger: &[u64],
    ) -> Vec<Vec<u32>> {
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone("sched-test");
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            let e = engine.clone();
            std::thread::spawn(move || Scheduler::new(e, policy).run(&b, &o))
        };
        let mut rxs = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if let Some(&ms) = stagger.get(i) {
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
            rxs.push(batcher.submit(r.clone()));
        }
        let outs: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().tokens)
            .collect();
        batcher.close();
        worker.join().unwrap();
        assert!(obs.metrics.requests() >= reqs.len() as u64);
        outs
    }

    fn serve(
        engine: Arc<Engine>,
        reqs: &[GenRequest],
        max_slots: usize,
        stagger: &[u64],
    ) -> Vec<Vec<u32>> {
        let policy = SchedPolicy { max_slots, ..Default::default() };
        serve_policy(engine, reqs, policy, stagger)
    }

    /// Acceptance property: for any arrival order of mixed-length requests
    /// and any admission/chunking policy, the continuous scheduler's
    /// greedy tokens equal each request's solo `generate_batch` tokens.
    fn solo_equivalence_policy(engine: Arc<Engine>, seed: u64, policy: SchedPolicy) {
        let mut rng = Pcg32::seeded(seed);
        let n = 6u64;
        let reqs: Vec<GenRequest> = (0..n)
            .map(|i| {
                let plen = 1 + rng.below(10) as usize;
                let prompt = (0..plen).map(|_| 2 + rng.below(120)).collect();
                GenRequest::new(i, prompt, 1 + rng.below(6) as usize)
                    .with_client(rng.below(3) as u64)
                    .with_priority(rng.below(3) as i32 - 1)
            })
            .collect();
        let stagger: Vec<u64> = (0..n).map(|_| rng.below(3) as u64).collect();
        let outs = serve_policy(engine.clone(), &reqs, policy, &stagger);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            let solo = engine.generate_batch(&[req.clone()]);
            assert_eq!(
                got, &solo[0].tokens,
                "request {} (prompt len {}, max_new {}) diverged under continuous batching",
                req.id,
                req.prompt.len(),
                req.max_new
            );
        }
    }

    fn solo_equivalence(engine: Arc<Engine>, seed: u64) {
        let policy = SchedPolicy { max_slots: 3, ..Default::default() };
        solo_equivalence_policy(engine, seed, policy);
    }

    #[test]
    fn continuous_equals_solo_dense() {
        for seed in [1u64, 2, 3] {
            solo_equivalence(dense_engine(7), seed);
        }
    }

    #[test]
    fn continuous_equals_solo_kernels() {
        solo_equivalence(kernel_engine(8), 4);
    }

    /// Solo equivalence under every admission policy, with chunking tight
    /// enough (chunk 3, budget 4) that prompts split across several ticks
    /// and prefill chunks interleave with live decode steps — admission
    /// order and chunk schedules must never change anyone's tokens.
    #[test]
    fn continuous_equals_solo_under_each_admit_policy() {
        for admit in [AdmitPolicy::Fifo, AdmitPolicy::Sjf, AdmitPolicy::FairShare] {
            let policy = SchedPolicy {
                max_slots: 3,
                chunk_tokens: 3,
                step_tokens: 4,
                admit,
                ..Default::default()
            };
            solo_equivalence_policy(dense_engine(7), 5, policy);
        }
    }

    /// Solo-equivalence property with a compressed serving KV cache: the
    /// scheduler pool and the solo reference both store f16/int8/fp8 K/V,
    /// and per-row encode-on-write keeps greedy decode batching-invariant,
    /// so any arrival order still reproduces each request's solo tokens
    /// exactly — chunked prefill included (encoding is per row, so
    /// chunking cannot perturb the stored codes).
    #[test]
    fn continuous_equals_solo_quantized_kv() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(13);
        let w = init(&cfg, &mut rng);
        for dtype in [KvDtype::F16, KvDtype::Int8, KvDtype::Fp8E4M3] {
            let engine = Arc::new(
                Engine::new("dense-qkv", cfg.clone(), Arc::new(w.clone()), None)
                    .with_kv_dtype(dtype),
            );
            let policy = SchedPolicy {
                max_slots: 3,
                chunk_tokens: 4,
                step_tokens: 6,
                ..Default::default()
            };
            solo_equivalence_policy(engine, 5, policy);
        }
    }

    /// Long generations wrap their ring slots inside the step-loop: a
    /// request decoding past 2× the context length must still match its
    /// solo reference exactly, batched with short requests, and its
    /// wrapped slot must recycle cleanly for later admissions.
    #[test]
    fn wrapped_slots_decode_and_recycle_through_scheduler() {
        let cfg = crate::model::ModelConfig {
            name: "ring-sched".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "scheduler ring test".to_string(),
        };
        let mut rng = Pcg32::seeded(17);
        let w = init(&cfg, &mut rng);
        let engine = Arc::new(Engine::new("ring", cfg.clone(), Arc::new(w), None));
        let long_new = 2 * cfg.max_seq + 3; // wraps the slot twice
        let reqs = vec![
            GenRequest::new(0, vec![5, 6, 7], long_new),
            GenRequest::new(1, vec![9], 2),
            GenRequest::new(2, vec![11, 12], 3),
            GenRequest::new(3, vec![13], long_new),
        ];
        // 2 slots, 4 requests: the long sequences' wrapped slots must be
        // reused by the later admissions. Chunk 2 also exercises chunked
        // prefill against the tiny context window.
        let policy = SchedPolicy {
            max_slots: 2,
            chunk_tokens: 2,
            step_tokens: 3,
            ..Default::default()
        };
        let outs = serve_policy(engine.clone(), &reqs, policy, &[]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.len(), req.max_new, "request {} length", req.id);
            let solo = engine.generate_batch(std::slice::from_ref(req));
            assert_eq!(got, &solo[0].tokens, "request {} diverged", req.id);
        }
    }

    #[test]
    fn slots_recycle_through_more_requests_than_slots() {
        // 2 slots, 6 requests: completion requires retired slots to be
        // reused by newly admitted requests.
        let engine = dense_engine(9);
        let reqs: Vec<GenRequest> = (0..6u64)
            .map(|i| GenRequest::new(i, vec![3 + i as u32], 2 + (i as usize % 3)))
            .collect();
        let outs = serve(engine.clone(), &reqs, 2, &[]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.len(), req.max_new);
            assert_eq!(got, &engine.generate_batch(&[req.clone()])[0].tokens);
        }
    }

    #[test]
    fn stop_token_frees_slot_early() {
        let engine = dense_engine(10);
        // Find the unconstrained second token, then use it as the stop.
        let probe = engine.generate_batch(&[GenRequest::new(0, vec![5, 6, 7], 8)]);
        let stop = probe[0].tokens[1];
        let reqs = vec![
            GenRequest::new(1, vec![5, 6, 7], 8).with_stop(stop),
            GenRequest::new(2, vec![9, 10], 3),
            GenRequest::new(3, vec![11], 3),
        ];
        // One slot: the stopped sequence must retire (freeing its slot)
        // before the later requests can run at all.
        let outs = serve(engine.clone(), &reqs, 1, &[]);
        let cut = probe[0].tokens.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(outs[0], probe[0].tokens[..cut].to_vec());
        for (req, got) in reqs.iter().zip(outs.iter()).skip(1) {
            assert_eq!(got, &engine.generate_batch(&[req.clone()])[0].tokens);
        }
    }

    #[test]
    fn close_still_drains_queued_requests() {
        let engine = dense_engine(11);
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone("drain-test");
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            rxs.push(batcher.submit(GenRequest::new(i, vec![4 + i as u32], 2)));
        }
        batcher.close(); // close BEFORE the scheduler even starts
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            let e = engine.clone();
            std::thread::spawn(move || {
                Scheduler::new(e, SchedPolicy { max_slots: 2, ..Default::default() }).run(&b, &o)
            })
        };
        for rx in rxs {
            let out = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(out.tokens.len(), 2);
            // The scheduler reports each request's server-side TTFT.
            assert!(out.ttft_s.unwrap() > 0.0);
        }
        worker.join().unwrap();
        let metrics = &obs.metrics;
        assert_eq!(metrics.requests(), 3);
        assert!(metrics.ttft_pct(50.0) > 0.0);
        // Queue wait (enqueue→admit) is recorded for every admission.
        assert!(metrics.queue_wait_pct(50.0) > 0.0);
        assert!(metrics.tokens() >= 6);
        // Occupancy and stage attribution land as the ticks run.
        assert!(metrics.mean_step_occupancy() > 0.0);
        assert!(metrics.stage_busy_s(crate::server::Stage::Prefill) > 0.0);
        // The flight recorder saw every lifecycle stage: one admission and
        // one retirement per request, prefill chunks and decode steps in
        // between.
        let events = obs.recorder.snapshot(None);
        let count = |k: EventKind| events.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(EventKind::Admitted), 3);
        assert_eq!(count(EventKind::Retired), 3);
        assert!(count(EventKind::PrefillChunk) >= 3);
        assert!(count(EventKind::DecodeStep) >= 3);
    }

    /// Run `reqs` through a live SPECULATIVE scheduler and return the full
    /// results (tokens + per-request speculation totals) plus the route's
    /// metrics.
    fn serve_spec(
        target: Arc<Engine>,
        draft: Arc<Engine>,
        reqs: &[GenRequest],
        policy: SchedPolicy,
    ) -> (Vec<GenResult>, RouteObs) {
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone("spec-test");
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            std::thread::spawn(move || Scheduler::new_spec(target, draft, policy).run(&b, &o))
        };
        let rxs: Vec<_> = reqs.iter().map(|r| batcher.submit(r.clone())).collect();
        let outs: Vec<GenResult> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap())
            .collect();
        batcher.close();
        worker.join().unwrap();
        (outs, obs)
    }

    /// The speculative route's tokens equal each request's solo decode on
    /// the TARGET engine (the draft can never change output, only speed),
    /// with per-request and per-route acceptance recorded.
    #[test]
    fn speculative_route_equals_solo_target() {
        let target = dense_engine(7);
        let draft = kernel_engine(7); // same base weights, compressed
        let reqs = vec![
            GenRequest::new(0, vec![5, 6, 7], 6),
            GenRequest::new(1, vec![9], 4),
            GenRequest::new(2, vec![11, 12, 13, 14, 15], 5),
            GenRequest::new(3, vec![40], 1), // remaining == 1: never drafts
        ];
        let policy =
            SchedPolicy { max_slots: 3, draft_k: 4, chunk_tokens: 3, ..Default::default() };
        let (outs, obs) = serve_spec(target.clone(), draft, &reqs, policy);
        let metrics = &obs.metrics;
        for (req, got) in reqs.iter().zip(outs.iter()) {
            let solo = target.generate_batch(std::slice::from_ref(req));
            assert_eq!(got.tokens, solo[0].tokens, "request {} diverged", req.id);
            let (d, a) = got.spec.expect("speculative route must report totals");
            assert!(a <= d, "request {}: accepted {a} > drafted {d}", req.id);
            if req.max_new >= 2 {
                assert!(d > 0, "request {} never drafted", req.id);
            } else {
                assert_eq!((d, a), (0, 0));
            }
        }
        assert!(metrics.spec_drafted() > 0);
        assert!(metrics.spec_accepted() <= metrics.spec_drafted());
        let rate = metrics.spec_acceptance_rate();
        assert!((0.0..=1.0).contains(&rate), "acceptance rate {rate}");
        assert!(metrics.summary().contains("spec_accept"));
        // Busy time splits into draft + verify stages on speculative ticks.
        assert!(metrics.stage_busy_s(crate::server::Stage::SpecDraft) > 0.0);
        assert!(metrics.stage_busy_s(crate::server::Stage::SpecVerify) > 0.0);
        // Verify steps and draft phases appear in the flight recorder.
        let events = obs.recorder.snapshot(None);
        assert!(events.iter().any(|e| e.kind == EventKind::SpecVerify));
        assert!(events.iter().any(|e| e.kind == EventKind::SpecDraft));
    }

    /// Identical twin (draft == target weights): every draft is confirmed,
    /// so route-level acceptance is 100%.
    #[test]
    fn speculative_identical_twin_accepts_all() {
        let target = dense_engine(7);
        let draft = dense_engine(7);
        let reqs = vec![GenRequest::new(0, vec![5, 6, 7], 8), GenRequest::new(1, vec![9], 6)];
        let policy = SchedPolicy { max_slots: 2, draft_k: 3, ..Default::default() };
        let (outs, obs) = serve_spec(target.clone(), draft, &reqs, policy);
        let metrics = &obs.metrics;
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.tokens, target.generate_batch(&[req.clone()])[0].tokens);
            let (d, a) = got.spec.unwrap();
            assert_eq!(d, a, "request {}: identical twin must accept all", req.id);
        }
        assert_eq!(metrics.spec_drafted(), metrics.spec_accepted());
        assert!((metrics.spec_acceptance_rate() - 1.0).abs() < 1e-12);
    }

    /// Speculative serving with wrapped ring slots and slot recycling: the
    /// fallback path takes over past the context length and retired twin
    /// slots readmit cleanly (the draft pool frees in lockstep).
    #[test]
    fn speculative_wrapped_slots_recycle() {
        let cfg = crate::model::ModelConfig {
            name: "ring-spec-sched".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "speculative scheduler ring test".to_string(),
        };
        let mut rng = Pcg32::seeded(19);
        let w = Arc::new(init(&cfg, &mut rng));
        let target = Arc::new(Engine::new("t", cfg.clone(), w.clone(), None));
        let draft = Arc::new(Engine::new("d", cfg.clone(), w, None));
        let long_new = 2 * cfg.max_seq + 3;
        let reqs = vec![
            GenRequest::new(0, vec![5, 6, 7], long_new),
            GenRequest::new(1, vec![9], 2),
            GenRequest::new(2, vec![11, 12], 3),
            GenRequest::new(3, vec![13], long_new),
        ];
        let policy = SchedPolicy {
            max_slots: 2,
            draft_k: 3,
            chunk_tokens: 2,
            step_tokens: 16,
            ..Default::default()
        };
        let (outs, _) = serve_spec(target.clone(), draft, &reqs, policy);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.tokens.len(), req.max_new, "request {} length", req.id);
            let solo = target.generate_batch(std::slice::from_ref(req));
            assert_eq!(got.tokens, solo[0].tokens, "request {} diverged", req.id);
        }
    }

    /// One long prompt chunk-feeding while short requests decode: every
    /// request must still match its solo reference token for token — the
    /// interleaved tick must not perturb anyone. (Latency effects are the
    /// serve bench's head-of-line scenario; this pins correctness.)
    #[test]
    fn long_prompt_interleaves_with_decodes_under_budget() {
        let engine = dense_engine(12);
        let long_prompt: Vec<u32> = (0..40).map(|i| 2 + (i % 60) as u32).collect();
        let reqs = vec![
            GenRequest::new(0, vec![5, 6], 6),
            GenRequest::new(1, long_prompt, 2),
            GenRequest::new(2, vec![9], 2),
        ];
        let policy = SchedPolicy {
            max_slots: 3,
            chunk_tokens: 4,
            step_tokens: 6,
            ..Default::default()
        };
        // Short request first so it is mid-decode while the long prompt
        // chunk-feeds; all three must still match their solo references.
        let outs = serve_policy(engine.clone(), &reqs, policy, &[0, 1, 1]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got, &engine.generate_batch(&[req.clone()])[0].tokens, "req {}", req.id);
        }
    }

    type Spawned = (
        Arc<Batcher>,
        RouteObs,
        Arc<crate::server::session::SessionTable>,
        std::thread::JoinHandle<()>,
    );

    /// Spawn a scheduler over a fresh batcher; returns the pieces a test
    /// needs to drive it directly (batcher, obs, session handle, worker).
    fn spawn_sched(engine: Arc<Engine>, policy: SchedPolicy, name: &str) -> Spawned {
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone(name);
        let sched = Arc::new(Scheduler::new(engine, policy));
        let sessions = sched.sessions();
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            std::thread::spawn(move || sched.run(&b, &o))
        };
        (batcher, obs, sessions, worker)
    }

    /// Drain one stream to completion, asserting frame order: `index` must
    /// count up from 0 and the concatenated tokens must equal `Done`'s.
    fn drain_stream(rx: std::sync::mpsc::Receiver<StreamEvent>) -> GenResult {
        let mut tokens: Vec<u32> = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(60)).expect("stream ended without Done") {
                StreamEvent::Token { index, token } => {
                    assert_eq!(index, tokens.len(), "token frames must arrive in order");
                    tokens.push(token);
                }
                StreamEvent::Done(res) => {
                    assert_eq!(tokens, res.tokens, "streamed frames must concatenate to result");
                    return res;
                }
            }
        }
    }

    /// Tentpole acceptance: a streamed request's token frames arrive in
    /// order, concatenate to exactly the non-streamed result, and match
    /// the solo reference; emission cadence lands in the inter-token-gap
    /// histogram.
    #[test]
    fn streamed_frames_equal_plain_result() {
        let engine = dense_engine(21);
        let policy = SchedPolicy { max_slots: 2, ..Default::default() };
        let (batcher, obs, _sessions, worker) = spawn_sched(engine.clone(), policy, "stream-t");
        let req = GenRequest::new(0, vec![5, 6, 7], 6);
        let plain = batcher.submit(req.clone());
        let streamed = batcher.submit_stream(GenRequest { id: 1, ..req.clone() });
        let plain_res = plain.recv_timeout(Duration::from_secs(60)).unwrap();
        let stream_res = drain_stream(streamed);
        assert_eq!(stream_res.tokens, plain_res.tokens);
        assert_eq!(stream_res.tokens, engine.generate_batch(&[req])[0].tokens);
        assert!(stream_res.ttft_s.unwrap() > 0.0);
        batcher.close();
        worker.join().unwrap();
        // 6 tokens each = 5 post-first emissions per sequence, recorded
        // for streamed and plain flights alike.
        let gaps = obs
            .metrics
            .histograms()
            .iter()
            .find(|(name, _)| *name == "inter_token_seconds")
            .map(|(_, h)| h.count())
            .unwrap();
        assert!(gaps >= 10, "expected >= 10 inter-token gap samples, got {gaps}");
        assert!(obs.metrics.inter_token_pct(50.0) >= 0.0);
    }

    /// Sampled requests stream identically too: same seed ⇒ the streamed
    /// frames equal the plain submit's tokens.
    #[test]
    fn streamed_sampling_matches_plain() {
        let engine = dense_engine(22);
        let sample =
            crate::model::SampleParams { temperature: 0.9, top_k: 12, top_p: 0.95, seed: 7 };
        let policy = SchedPolicy { max_slots: 2, ..Default::default() };
        let (batcher, _obs, _sessions, worker) = spawn_sched(engine.clone(), policy, "stream-s");
        let req = GenRequest::new(0, vec![9, 10], 8).with_sample(sample);
        let plain = batcher.submit(req.clone()).recv_timeout(Duration::from_secs(60)).unwrap();
        let streamed = drain_stream(batcher.submit_stream(GenRequest { id: 1, ..req }));
        assert_eq!(streamed.tokens, plain.tokens);
        batcher.close();
        worker.join().unwrap();
    }

    /// Session turns resume their parked slot (prefilling only the new
    /// tokens) and each turn's output equals a fresh request over the same
    /// full conversation prompt.
    #[test]
    fn session_turns_resume_and_match_solo() {
        let engine = dense_engine(23);
        let policy = SchedPolicy { max_slots: 2, max_sessions: 2, ..Default::default() };
        let (batcher, _obs, sessions, worker) = spawn_sched(engine.clone(), policy, "sess-t");
        let sid = sessions.open().unwrap();
        let mut expected_len = 0;
        for (turn, new_tokens) in [vec![5u32, 6], vec![9], vec![11, 12]].into_iter().enumerate() {
            let prompt = sessions.append_begin(sid, &new_tokens).unwrap();
            let req = GenRequest::new(turn as u64, prompt.clone(), 3).with_session(sid);
            let res = batcher.submit(req).recv_timeout(Duration::from_secs(60)).unwrap();
            let solo = engine.generate_batch(&[GenRequest::new(99, prompt.clone(), 3)]);
            assert_eq!(res.tokens, solo[0].tokens, "turn {turn} diverged on resume");
            expected_len = prompt.len() + res.tokens.len();
            assert_eq!(sessions.history_len(sid), Some(expected_len));
        }
        assert!(expected_len > 0);
        sessions.drop_session(sid).unwrap();
        batcher.close();
        worker.join().unwrap();
    }

    /// Parked slots are a cache, not a reservation: with every slot parked
    /// by idle sessions, a burst of plain requests still serves (evicting
    /// LRU slots), and the evicted session's next turn still matches solo
    /// via the full re-prefill fallback.
    #[test]
    fn parked_slots_evict_for_fresh_admissions() {
        let engine = dense_engine(25);
        let policy = SchedPolicy { max_slots: 2, max_sessions: 2, ..Default::default() };
        let (batcher, _obs, sessions, worker) = spawn_sched(engine.clone(), policy, "sess-evict");
        let sids = [sessions.open().unwrap(), sessions.open().unwrap()];
        for (i, &sid) in sids.iter().enumerate() {
            let prompt = sessions.append_begin(sid, &[4 + i as u32]).unwrap();
            let req = GenRequest::new(i as u64, prompt, 2).with_session(sid);
            let _ = batcher.submit(req).recv_timeout(Duration::from_secs(60)).unwrap();
        }
        // Both slots are now parked. Plain requests must still serve.
        let reqs: Vec<GenRequest> =
            (0..3u64).map(|i| GenRequest::new(10 + i, vec![20 + i as u32], 2)).collect();
        let rxs: Vec<_> = reqs.iter().map(|r| batcher.submit(r.clone())).collect();
        for (req, rx) in reqs.iter().zip(rxs) {
            let res = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(res.tokens, engine.generate_batch(&[req.clone()])[0].tokens);
        }
        // The evicted sessions live on: their next turns re-prefill from
        // scratch and still match a fresh run over the full conversation.
        for (i, &sid) in sids.iter().enumerate() {
            let prompt = sessions.append_begin(sid, &[40 + i as u32]).unwrap();
            let req = GenRequest::new(30 + i as u64, prompt.clone(), 2).with_session(sid);
            let res = batcher.submit(req).recv_timeout(Duration::from_secs(60)).unwrap();
            let solo = engine.generate_batch(&[GenRequest::new(99, prompt, 2)]);
            assert_eq!(res.tokens, solo[0].tokens, "evicted session {i} diverged");
        }
        batcher.close();
        worker.join().unwrap();
    }

    /// A conversation that outgrows the context window falls back to a
    /// fresh *windowed* prefill — same tokens as a fresh request over the
    /// full history, turn after turn.
    #[test]
    fn deep_session_falls_back_to_windowed_prefill() {
        let cfg = crate::model::ModelConfig {
            name: "ring-sess".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "session window-overflow test".to_string(),
        };
        let mut rng = Pcg32::seeded(27);
        let w = init(&cfg, &mut rng);
        let engine = Arc::new(Engine::new("ring-sess", cfg, Arc::new(w), None));
        let policy = SchedPolicy { max_slots: 2, max_sessions: 1, ..Default::default() };
        let (batcher, _obs, sessions, worker) = spawn_sched(engine.clone(), policy, "sess-deep");
        let sid = sessions.open().unwrap();
        // 4 turns × (2 new + 2 generated) tokens: the history passes
        // max_seq = 8 by turn 2 and keeps growing.
        for turn in 0..4u64 {
            let new = [5 + turn as u32, 6 + turn as u32];
            let prompt = sessions.append_begin(sid, &new).unwrap();
            let req = GenRequest::new(turn, prompt.clone(), 2).with_session(sid);
            let res = batcher.submit(req).recv_timeout(Duration::from_secs(60)).unwrap();
            let solo = engine.generate_batch(&[GenRequest::new(99, prompt, 2)]);
            assert_eq!(res.tokens, solo[0].tokens, "turn {turn} diverged past the window");
        }
        batcher.close();
        worker.join().unwrap();
    }

    /// Speculative routes serve sessions too: the twin draft slot parks
    /// and resumes in lockstep with the serving slot, and every turn still
    /// matches the TARGET's solo output over the full conversation.
    #[test]
    fn speculative_sessions_park_twin_slots() {
        let target = dense_engine(7);
        let draft = kernel_engine(7);
        let policy = SchedPolicy {
            max_slots: 2,
            draft_k: 3,
            max_sessions: 2,
            ..Default::default()
        };
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone("spec-sess");
        let sched = Arc::new(Scheduler::new_spec(target.clone(), draft, policy));
        let sessions = sched.sessions();
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            std::thread::spawn(move || sched.run(&b, &o))
        };
        let sid = sessions.open().unwrap();
        for (turn, new_tokens) in [vec![5u32, 6, 7], vec![9, 10]].into_iter().enumerate() {
            let prompt = sessions.append_begin(sid, &new_tokens).unwrap();
            let req = GenRequest::new(turn as u64, prompt.clone(), 4).with_session(sid);
            let res = batcher.submit(req).recv_timeout(Duration::from_secs(60)).unwrap();
            let solo = target.generate_batch(&[GenRequest::new(99, prompt, 4)]);
            assert_eq!(res.tokens, solo[0].tokens, "spec session turn {turn} diverged");
        }
        batcher.close();
        worker.join().unwrap();
    }

    /// Tentpole acceptance: forcing a preemption every k ticks (victim
    /// rotating across the batch) must never change anyone's tokens — each
    /// parked sequence resumes through a chunked windowed re-prefill that
    /// is bit-identical to having never been preempted. The shutdown
    /// refcount-balance assert inside `run` doubles as the leak check.
    #[test]
    fn forced_preemption_preserves_solo_equivalence() {
        for k in [1usize, 2, 3] {
            let policy = SchedPolicy {
                max_slots: 3,
                chunk_tokens: 3,
                step_tokens: 4,
                preempt_every: k,
                ..Default::default()
            };
            solo_equivalence_policy(dense_engine(7), 5, policy);
        }
    }

    /// Forced preemption with quantized serving KV: release/re-prefill
    /// round-trips the window through the f16/int8/fp8 encoders exactly as
    /// solo decode would, so tokens still match the solo reference.
    #[test]
    fn forced_preemption_quantized_kv() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(31);
        let w = init(&cfg, &mut rng);
        for dtype in [KvDtype::F16, KvDtype::Int8, KvDtype::Fp8E4M3] {
            let engine = Arc::new(
                Engine::new("qkv-pre", cfg.clone(), Arc::new(w.clone()), None)
                    .with_kv_dtype(dtype),
            );
            let policy = SchedPolicy {
                max_slots: 3,
                chunk_tokens: 4,
                step_tokens: 6,
                preempt_every: 2,
                ..Default::default()
            };
            solo_equivalence_policy(engine, 5, policy);
        }
    }

    /// Sequences whose history outgrew the ring window are preemption-
    /// INELIGIBLE (their retained rows keep write-time position bases a
    /// re-prefill would rebase): under forced preemption, wrapped long
    /// sequences run untouched while short batchmates preempt and resume,
    /// and everyone still matches solo.
    #[test]
    fn forced_preemption_skips_wrapped_slots() {
        let cfg = crate::model::ModelConfig {
            name: "ring-preempt".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "preemption eligibility test".to_string(),
        };
        let mut rng = Pcg32::seeded(37);
        let w = init(&cfg, &mut rng);
        let engine = Arc::new(Engine::new("ring-pre", cfg.clone(), Arc::new(w), None));
        let long_new = 2 * cfg.max_seq + 3;
        let reqs = vec![
            GenRequest::new(0, vec![5, 6, 7], long_new),
            GenRequest::new(1, vec![9], 2),
            GenRequest::new(2, vec![11, 12], 3),
            GenRequest::new(3, vec![13], long_new),
        ];
        let policy = SchedPolicy {
            max_slots: 2,
            chunk_tokens: 2,
            step_tokens: 3,
            preempt_every: 2,
            ..Default::default()
        };
        let outs = serve_policy(engine.clone(), &reqs, policy, &[]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.len(), req.max_new, "request {} length", req.id);
            let solo = engine.generate_batch(std::slice::from_ref(req));
            assert_eq!(got, &solo[0].tokens, "request {} diverged", req.id);
        }
    }

    /// A full pool preempts its lowest-priority flight the moment a
    /// strictly higher-priority request waits: with ONE slot, the bulk
    /// sequence parks mid-decode, the interactive request runs to
    /// completion first, and the bulk sequence resumes — both
    /// token-identical to their solo runs. (Both requests are queued
    /// before the loop starts, so the preemption is deterministic, not a
    /// timing accident.)
    #[test]
    fn priority_preemption_interactive_overtakes_bulk() {
        let engine = dense_engine(33);
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let obs = RouteObs::standalone("preempt-prio");
        let bulk = GenRequest::new(0, vec![5, 6, 7], 24).with_priority(0);
        let inter = GenRequest::new(1, vec![9, 10], 3).with_priority(1);
        let rx_bulk = batcher.submit(bulk.clone());
        let rx_inter = batcher.submit(inter.clone());
        batcher.close();
        let worker = {
            let b = batcher.clone();
            let o = obs.clone();
            let e = engine.clone();
            std::thread::spawn(move || {
                Scheduler::new(e, SchedPolicy { max_slots: 1, ..Default::default() }).run(&b, &o)
            })
        };
        let bulk_out = rx_bulk.recv_timeout(Duration::from_secs(60)).unwrap();
        let inter_out = rx_inter.recv_timeout(Duration::from_secs(60)).unwrap();
        worker.join().unwrap();
        assert_eq!(bulk_out.tokens, engine.generate_batch(&[bulk])[0].tokens);
        assert_eq!(inter_out.tokens, engine.generate_batch(&[inter])[0].tokens);
    }

    /// Prefix caching: a second request with an identical prompt revives
    /// the first one's registered prefix pages instead of re-prefilling
    /// them — same greedy tokens (shared pages are the same bytes), pool
    /// hit counters up, and the skipped prefill tokens counted.
    #[test]
    fn shared_prefix_reuses_pages_and_matches_solo() {
        let engine = dense_engine(35);
        let policy = SchedPolicy { max_slots: 2, ..Default::default() };
        let (batcher, obs, _sessions, worker) = spawn_sched(engine.clone(), policy, "prefix-t");
        // 36 tokens = 2 full 16-row pages (hashed + shareable) + a tail.
        let prompt: Vec<u32> = (0..36u32).map(|i| 2 + (i % 60)).collect();
        let a = GenRequest::new(0, prompt.clone(), 4);
        let first = batcher.submit(a.clone()).recv_timeout(Duration::from_secs(60)).unwrap();
        let b = GenRequest::new(1, prompt, 4);
        let second = batcher.submit(b).recv_timeout(Duration::from_secs(60)).unwrap();
        batcher.close();
        worker.join().unwrap();
        assert_eq!(first.tokens, second.tokens, "prefix hit changed tokens");
        assert_eq!(first.tokens, engine.generate_batch(&[a])[0].tokens);
        let pages = obs.metrics.kv_pages();
        assert!(pages.prefix_hits >= 1, "no prefix hit recorded: {pages:?}");
        // Two full pages revived on the hit: 32 prompt tokens never
        // re-prefilled.
        assert!(pages.prefix_saved_tokens >= 32, "saved {}", pages.prefix_saved_tokens);
        assert!(pages.pages_total > 0 && pages.pages_used <= pages.pages_total);
    }
}
