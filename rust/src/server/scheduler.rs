//! Continuous-batching scheduler: a step-loop over in-flight sequences
//! with per-sequence KV cache slots.
//!
//! The fixed-batch worker (`Router::register`) forms a batch, runs it to
//! completion, and makes every request pay for the slowest one in its
//! batch: late arrivals wait for the whole batch to drain, and short
//! requests ride along to the batch's largest `max_new`. The scheduler
//! removes the lockstep (vLLM-style):
//!
//! * **Admit** — between decode steps it drains queued requests
//!   ([`Batcher::try_take`]) into free [`KvCachePool`] slots and prefills
//!   each one individually ([`Engine::prefill`]) — no left-padding, and a
//!   new request waits one decode step, not one batch.
//! * **Step** — every in-flight sequence advances one token in a single
//!   batched forward ([`Engine::decode_step`]), whatever its depth; the
//!   compressed kernels stay saturated across request churn, which is what
//!   the paper's small-batch decode speedups (§4, Fig. 3/4) need to
//!   survive at scale.
//! * **Retire** — a sequence leaves the moment it hits its own `max_new`
//!   or stop token; its result is sent and its slot returns to the pool
//!   free-list for the next admission. Slots are ring buffers
//!   (`model::KvCachePool`), so a sequence that decoded past the context
//!   length — wrapping its slot — retires and recycles exactly like a
//!   short one: reallocation resets the slot's logical length, and the
//!   next occupant's writes simply overwrite the wrapped stripes.
//!
//! Generation depth never stalls the loop: a sequence past `max_seq`
//! costs the same one-token forward as any other (the ring overwrites its
//! oldest cached position in place), so one very long generation no
//! longer degrades every batchmate's step latency the way the old
//! sliding-window re-prefill did.
//!
//! When nothing is in flight the loop parks untimed on the batcher condvar
//! ([`Batcher::wait_pending`]) — an idle server burns no CPU. Greedy
//! decoding through per-sequence slots is batching-invariant, so any
//! arrival order yields each request's solo-decode tokens (tested below
//! for dense and kernel-backed engines).

use super::batcher::Batcher;
use super::engine::{Engine, GenResult, SeqState};
use super::metrics::Metrics;
use crate::model::{KvCachePool, KvDtype};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

/// Scheduler policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedPolicy {
    /// Concurrent sequence slots (the decode batch cap).
    pub max_slots: usize,
    /// Storage dtype for the serving KV cache pool: `None` (default)
    /// inherits the engine's own dtype ([`Engine::kv_dtype`]), so the
    /// scheduler and the engine's solo reference paths always agree;
    /// `Some(..)` overrides it for this route. int8 / fp8 hold ~4× fewer
    /// cache bytes per decode step, and greedy output stays
    /// batching-invariant (quantization is per sequence row).
    pub kv_dtype: Option<KvDtype>,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy { max_slots: 8, kv_dtype: None }
    }
}

/// One admitted request: its decode state plus result/latency plumbing.
struct InFlight {
    state: SeqState,
    result_slot: Sender<GenResult>,
    enqueued: Instant,
}

/// Drives an [`Engine`] continuously over a [`Batcher`] queue.
pub struct Scheduler {
    engine: Arc<Engine>,
    policy: SchedPolicy,
}

impl Scheduler {
    pub fn new(engine: Arc<Engine>, policy: SchedPolicy) -> Self {
        assert!(policy.max_slots > 0, "scheduler needs at least one slot");
        Scheduler { engine, policy }
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// The KV dtype this scheduler's pool stores (policy override, or the
    /// engine's own dtype).
    pub fn kv_dtype(&self) -> KvDtype {
        self.policy.kv_dtype.unwrap_or_else(|| self.engine.kv_dtype())
    }

    /// Run the step-loop until the batcher is closed and fully drained
    /// (queued requests are still served after `close`; in-flight
    /// sequences always run to completion).
    pub fn run(&self, batcher: &Batcher, metrics: &Metrics) {
        let mut pool = KvCachePool::with_layout(
            self.engine.config(),
            self.policy.max_slots,
            self.kv_dtype(),
            self.engine.kv_layout(),
        );
        let mut flights: Vec<InFlight> = Vec::new();
        loop {
            // ── Admit ─────────────────────────────────────────────────
            if flights.is_empty() && !batcher.wait_pending() {
                return; // closed + drained + nothing in flight
            }
            let free = self.policy.max_slots - flights.len();
            let pendings = batcher.try_take(free);
            if !pendings.is_empty() {
                // Backlog at admission time: what we just took plus what
                // still waits behind it.
                metrics.record_queue_depth(batcher.depth() + pendings.len());
                // All admitted prompts prefill in ONE batched forward.
                let reqs: Vec<_> = pendings.iter().map(|p| p.req.clone()).collect();
                let t0 = Instant::now();
                let states = self.engine.prefill_batch(&reqs, &mut pool);
                let prefilled = reqs.iter().filter(|r| r.max_new > 0).count();
                if prefilled > 0 {
                    metrics.record_prefill(prefilled, t0.elapsed().as_secs_f64());
                }
                for (state, pending) in states.into_iter().zip(pendings) {
                    if pending.req.max_new > 0 {
                        metrics.record_ttft(pending.enqueued.elapsed().as_secs_f64());
                    }
                    let flight = InFlight {
                        state,
                        result_slot: pending.result_slot,
                        enqueued: pending.enqueued,
                    };
                    if flight.state.done {
                        Self::retire(flight, &mut pool, metrics);
                    } else {
                        flights.push(flight);
                    }
                }
            }
            if flights.is_empty() {
                continue; // nothing admitted (e.g. only max_new=0 requests)
            }

            // ── Step ──────────────────────────────────────────────────
            let t0 = Instant::now();
            let made = {
                let mut active: Vec<&mut SeqState> =
                    flights.iter_mut().map(|f| &mut f.state).collect();
                self.engine.decode_step(&mut active, &mut pool)
            };
            if made > 0 {
                metrics.record_decode_step(made, t0.elapsed().as_secs_f64());
            }

            // ── Retire ────────────────────────────────────────────────
            let mut i = 0;
            while i < flights.len() {
                if flights[i].state.done {
                    let flight = flights.swap_remove(i);
                    Self::retire(flight, &mut pool, metrics);
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Free the sequence's cache slot and deliver its result.
    fn retire(flight: InFlight, pool: &mut KvCachePool, metrics: &Metrics) {
        pool.free(flight.state.slot);
        metrics.record_request(flight.enqueued.elapsed().as_secs_f64());
        let _ = flight.result_slot.send(GenResult {
            id: flight.state.id,
            tokens: flight.state.generated().to_vec(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::LinearOp;
    use crate::model::{by_name, init, CompressedWeights};
    use crate::quant::slim_quant;
    use crate::rng::Pcg32;
    use crate::server::batcher::BatchPolicy;
    use crate::server::engine::GenRequest;
    use std::time::Duration;

    fn dense_engine(seed: u64) -> Arc<Engine> {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(seed);
        let w = init(&cfg, &mut rng);
        Arc::new(Engine::new("dense", cfg, Arc::new(w), None))
    }

    fn kernel_engine(seed: u64) -> Arc<Engine> {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(seed);
        let w = init(&cfg, &mut rng);
        let mut cw = CompressedWeights::new();
        for (name, _d_in, _d_out) in cfg.linear_layers() {
            let q = slim_quant::quantize(w.expect(&name), 4);
            cw.insert(&name, LinearOp::int4(&q, None));
        }
        Arc::new(Engine::with_kernels("kn", cfg, Arc::new(w), Arc::new(cw)))
    }

    /// Run `reqs` through a live scheduler (staggered arrivals) and return
    /// each request's tokens, in request order. The serving pool inherits
    /// the engine's own KV dtype (policy `kv_dtype: None`), so solo
    /// `generate_batch` runs are the exact reference.
    fn serve(
        engine: Arc<Engine>,
        reqs: &[GenRequest],
        max_slots: usize,
        stagger: &[u64],
    ) -> Vec<Vec<u32>> {
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let metrics = Arc::new(Metrics::new());
        let worker = {
            let b = batcher.clone();
            let m = metrics.clone();
            let e = engine.clone();
            let policy = SchedPolicy { max_slots, kv_dtype: None };
            std::thread::spawn(move || Scheduler::new(e, policy).run(&b, &m))
        };
        let mut rxs = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if let Some(&ms) = stagger.get(i) {
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
            rxs.push(batcher.submit(r.clone()));
        }
        let outs: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().tokens)
            .collect();
        batcher.close();
        worker.join().unwrap();
        assert!(metrics.requests() >= reqs.len() as u64);
        outs
    }

    /// Acceptance property: for any arrival order of mixed-length requests,
    /// the continuous scheduler's greedy tokens equal each request's solo
    /// `generate_batch` tokens.
    fn solo_equivalence(engine: Arc<Engine>, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let n = 6u64;
        let reqs: Vec<GenRequest> = (0..n)
            .map(|i| {
                let plen = 1 + rng.below(10) as usize;
                GenRequest {
                    id: i,
                    prompt: (0..plen).map(|_| 2 + rng.below(120)).collect(),
                    max_new: 1 + rng.below(6) as usize,
                    stop: None,
                }
            })
            .collect();
        let stagger: Vec<u64> = (0..n).map(|_| rng.below(3) as u64).collect();
        let outs = serve(engine.clone(), &reqs, 3, &stagger);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            let solo = engine.generate_batch(&[req.clone()]);
            assert_eq!(
                got, &solo[0].tokens,
                "request {} (prompt len {}, max_new {}) diverged under continuous batching",
                req.id,
                req.prompt.len(),
                req.max_new
            );
        }
    }

    #[test]
    fn continuous_equals_solo_dense() {
        for seed in [1u64, 2, 3] {
            solo_equivalence(dense_engine(7), seed);
        }
    }

    #[test]
    fn continuous_equals_solo_kernels() {
        solo_equivalence(kernel_engine(8), 4);
    }

    /// Solo-equivalence property with a QUANTIZED serving KV cache: the
    /// scheduler pool and the solo reference both store int8 K/V, and
    /// per-row quantization keeps greedy decode batching-invariant, so any
    /// arrival order still reproduces each request's solo tokens exactly.
    #[test]
    fn continuous_equals_solo_quantized_kv() {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(13);
        let w = init(&cfg, &mut rng);
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let engine = Arc::new(
                Engine::new("dense-qkv", cfg.clone(), Arc::new(w.clone()), None)
                    .with_kv_dtype(dtype),
            );
            solo_equivalence(engine, 5);
        }
    }

    /// Long generations wrap their ring slots inside the step-loop: a
    /// request decoding past 2× the context length must still match its
    /// solo reference exactly, batched with short requests, and its
    /// wrapped slot must recycle cleanly for later admissions.
    #[test]
    fn wrapped_slots_decode_and_recycle_through_scheduler() {
        let cfg = crate::model::ModelConfig {
            name: "ring-sched".to_string(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff_ratio: 2,
            vocab: 96,
            max_seq: 8,
            stands_for: "scheduler ring test".to_string(),
        };
        let mut rng = Pcg32::seeded(17);
        let w = init(&cfg, &mut rng);
        let engine = Arc::new(Engine::new("ring", cfg.clone(), Arc::new(w), None));
        let long_new = 2 * cfg.max_seq + 3; // wraps the slot twice
        let reqs = vec![
            GenRequest { id: 0, prompt: vec![5, 6, 7], max_new: long_new, stop: None },
            GenRequest { id: 1, prompt: vec![9], max_new: 2, stop: None },
            GenRequest { id: 2, prompt: vec![11, 12], max_new: 3, stop: None },
            GenRequest { id: 3, prompt: vec![13], max_new: long_new, stop: None },
        ];
        // 2 slots, 4 requests: the long sequences' wrapped slots must be
        // reused by the later admissions.
        let outs = serve(engine.clone(), &reqs, 2, &[]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.len(), req.max_new, "request {} length", req.id);
            let solo = engine.generate_batch(std::slice::from_ref(req));
            assert_eq!(got, &solo[0].tokens, "request {} diverged", req.id);
        }
    }

    #[test]
    fn slots_recycle_through_more_requests_than_slots() {
        // 2 slots, 6 requests: completion requires retired slots to be
        // reused by newly admitted requests.
        let engine = dense_engine(9);
        let reqs: Vec<GenRequest> = (0..6u64)
            .map(|i| GenRequest {
                id: i,
                prompt: vec![3 + i as u32],
                max_new: 2 + (i as usize % 3),
                stop: None,
            })
            .collect();
        let outs = serve(engine.clone(), &reqs, 2, &[]);
        for (req, got) in reqs.iter().zip(outs.iter()) {
            assert_eq!(got.len(), req.max_new);
            assert_eq!(got, &engine.generate_batch(&[req.clone()])[0].tokens);
        }
    }

    #[test]
    fn stop_token_frees_slot_early() {
        let engine = dense_engine(10);
        // Find the unconstrained second token, then use it as the stop.
        let probe = engine.generate_batch(&[GenRequest {
            id: 0,
            prompt: vec![5, 6, 7],
            max_new: 8,
            stop: None,
        }]);
        let stop = probe[0].tokens[1];
        let reqs = vec![
            GenRequest { id: 1, prompt: vec![5, 6, 7], max_new: 8, stop: Some(stop) },
            GenRequest { id: 2, prompt: vec![9, 10], max_new: 3, stop: None },
            GenRequest { id: 3, prompt: vec![11], max_new: 3, stop: None },
        ];
        // One slot: the stopped sequence must retire (freeing its slot)
        // before the later requests can run at all.
        let outs = serve(engine.clone(), &reqs, 1, &[]);
        let cut = probe[0].tokens.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(outs[0], probe[0].tokens[..cut].to_vec());
        for (req, got) in reqs.iter().zip(outs.iter()).skip(1) {
            assert_eq!(got, &engine.generate_batch(&[req.clone()])[0].tokens);
        }
    }

    #[test]
    fn close_still_drains_queued_requests() {
        let engine = dense_engine(11);
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let metrics = Arc::new(Metrics::new());
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            rxs.push(batcher.submit(GenRequest {
                id: i,
                prompt: vec![4 + i as u32],
                max_new: 2,
                stop: None,
            }));
        }
        batcher.close(); // close BEFORE the scheduler even starts
        let worker = {
            let b = batcher.clone();
            let m = metrics.clone();
            let e = engine.clone();
            std::thread::spawn(move || {
                Scheduler::new(e, SchedPolicy { max_slots: 2, ..Default::default() }).run(&b, &m)
            })
        };
        for rx in rxs {
            let out = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(out.tokens.len(), 2);
        }
        worker.join().unwrap();
        assert_eq!(metrics.requests(), 3);
        assert!(metrics.ttft_pct(50.0) > 0.0);
        assert!(metrics.tokens() >= 6);
    }
}
