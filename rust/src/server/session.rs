//! Stateful multi-turn sessions: KV cache slots stay resident between
//! turns so turn N+1 prefills only its *new* tokens.
//!
//! A [`SessionTable`] tracks per-session token history and, between turns,
//! the **parked** KV cache slot the previous turn left behind. The
//! scheduler owns the actual pools; the table only brokers slot custody:
//!
//! * `open` creates a session (bounded by `max_sessions`).
//! * `append_begin` stakes a turn: it appends the client's new tokens to
//!   the history, marks the session busy (one turn in flight at a time),
//!   and returns the full prompt (history so far) for the request.
//! * `resume_slot` hands the parked slot — holding `history.len() − 1`
//!   cached rows from the previous turn — back to the scheduler, which
//!   resumes prefill from row `cached` instead of row 0
//!   (`Engine::prefill_resume`).
//! * `finish` returns the slot at retirement: the table parks it (and
//!   folds the generated tokens into history) unless the session was
//!   dropped mid-turn, in which case the caller frees it.
//! * `evict_lru` reclaims the least-recently-used *idle* parked slot when
//!   the pool runs dry — the session survives (history intact) and its
//!   next turn simply pays a full re-prefill.
//! * `drop_session` ends a session; a slot parked by a dropped session
//!   lands on the reap list ([`SessionTable::take_reaped`]) because only
//!   the scheduler thread may touch the pools.
//!
//! Recency uses a logical clock (bumped on every touch), not wall time —
//! deterministic and free of `Instant` plumbing.

use std::collections::HashMap;
use std::sync::Mutex;

/// Why a session operation failed. Typed (not stringly) so the protocol
/// layer can map each case to a stable wire error code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionError {
    /// The route was not configured with sessions
    /// (`SchedPolicy::max_sessions == 0`, or a fixed-batch route).
    Disabled,
    /// No live session with this id (never opened, or already dropped).
    Unknown(u64),
    /// The session already has a turn in flight.
    Busy(u64),
    /// `open` would exceed the table's `max_sessions` cap.
    TableFull(usize),
    /// The request itself was malformed (empty append, bad token, ...).
    Invalid(String),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Disabled => write!(f, "model does not serve sessions"),
            SessionError::Unknown(id) => write!(f, "unknown session {id}"),
            SessionError::Busy(id) => write!(f, "session {id} already has a turn in flight"),
            SessionError::TableFull(max) => write!(f, "session table full (max {max})"),
            SessionError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SessionError {}

struct Session {
    /// Every token of the conversation: prompts and generations, in order.
    history: Vec<u32>,
    /// KV cache slot parked between turns, caching `history.len() − 1`
    /// rows (the last emitted token is never fed). `None` while a turn is
    /// in flight, after LRU eviction, or before the first turn finishes.
    parked_slot: Option<usize>,
    /// Logical-clock stamp of the last touch (LRU order).
    last_used: u64,
    /// A turn is in flight: appends are rejected until it retires.
    busy: bool,
    /// Dropped mid-turn: `finish` reaps it instead of parking.
    dropped: bool,
}

#[derive(Default)]
struct Inner {
    next_id: u64,
    sessions: HashMap<u64, Session>,
    /// Logical LRU clock; bumped on every touch.
    clock: u64,
    /// Slots surrendered by dropped sessions, awaiting the scheduler tick
    /// (only the scheduler thread may free pool slots).
    reap: Vec<usize>,
}

/// Thread-safe session registry for one route. Created by the scheduler
/// (which owns the KV pools) and shared with the router front-end.
pub struct SessionTable {
    inner: Mutex<Inner>,
    max_sessions: usize,
}

impl SessionTable {
    pub fn new(max_sessions: usize) -> Self {
        SessionTable { inner: Mutex::new(Inner { next_id: 1, ..Default::default() }), max_sessions }
    }

    /// Whether this route serves sessions at all.
    pub fn enabled(&self) -> bool {
        self.max_sessions > 0
    }

    pub fn max_sessions(&self) -> usize {
        self.max_sessions
    }

    /// Live (open, not dropped) session count.
    pub fn live(&self) -> usize {
        self.inner.lock().unwrap().sessions.values().filter(|s| !s.dropped).count()
    }

    /// Open a new session and return its id.
    pub fn open(&self) -> Result<u64, SessionError> {
        if !self.enabled() {
            return Err(SessionError::Disabled);
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.sessions.values().filter(|s| !s.dropped).count() >= self.max_sessions {
            return Err(SessionError::TableFull(self.max_sessions));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.clock += 1;
        let stamp = inner.clock;
        inner.sessions.insert(
            id,
            Session {
                history: Vec::new(),
                parked_slot: None,
                last_used: stamp,
                busy: false,
                dropped: false,
            },
        );
        Ok(id)
    }

    /// Begin a turn: append `new_tokens` to the session history, mark the
    /// session busy, and return the full prompt (the whole history). The
    /// turn MUST be completed with [`SessionTable::finish`] once the
    /// request retires, or the session stays busy forever.
    pub fn append_begin(&self, id: u64, new_tokens: &[u32]) -> Result<Vec<u32>, SessionError> {
        if new_tokens.is_empty() {
            return Err(SessionError::Invalid("session append needs at least one token".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        let sess = match inner.sessions.get_mut(&id) {
            Some(s) if !s.dropped => s,
            _ => return Err(SessionError::Unknown(id)),
        };
        if sess.busy {
            return Err(SessionError::Busy(id));
        }
        sess.busy = true;
        sess.last_used = stamp;
        sess.history.extend_from_slice(new_tokens);
        Ok(sess.history.clone())
    }

    /// Take the session's parked slot for resumption, if one survived
    /// since the last turn. Called by the scheduler at admission; the slot
    /// holds the previous turn's cached rows.
    pub fn resume_slot(&self, id: u64) -> Option<usize> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        let sess = inner.sessions.get_mut(&id)?;
        sess.last_used = stamp;
        sess.parked_slot.take()
    }

    /// Complete a turn: fold the generated tokens into the history and
    /// park `slot` for the next turn. Returns `true` if the table took
    /// custody of the slot; `false` means the session was dropped mid-turn
    /// (its entry is reaped here) and the caller must free the slot.
    pub fn finish(&self, id: u64, generated: &[u32], slot: usize) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        let Some(sess) = inner.sessions.get_mut(&id) else {
            return false;
        };
        if sess.dropped {
            inner.sessions.remove(&id);
            return false;
        }
        sess.busy = false;
        sess.last_used = stamp;
        sess.history.extend_from_slice(generated);
        sess.parked_slot = Some(slot);
        true
    }

    /// Drop a session. Idle sessions release their parked slot onto the
    /// reap list (freed by the scheduler next tick); a session with a turn
    /// in flight is marked dropped and reaped when that turn finishes.
    pub fn drop_session(&self, id: u64) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let sess = match inner.sessions.get_mut(&id) {
            Some(s) if !s.dropped => s,
            _ => return Err(SessionError::Unknown(id)),
        };
        if sess.busy {
            sess.dropped = true;
            return Ok(());
        }
        let parked = sess.parked_slot.take();
        inner.sessions.remove(&id);
        if let Some(slot) = parked {
            inner.reap.push(slot);
        }
        Ok(())
    }

    /// Slots surrendered by dropped sessions since the last call. The
    /// scheduler drains this each tick and frees them in its pools.
    pub fn take_reaped(&self) -> Vec<usize> {
        std::mem::take(&mut self.inner.lock().unwrap().reap)
    }

    /// Reclaim the least-recently-used parked slot, or `None` if no
    /// session is parked. The evicted session stays live with its history
    /// intact — its next turn (even one already queued: a busy session's
    /// slot is parked until admission actually resumes it) re-prefills
    /// from scratch. The slot goes straight back to the caller (the
    /// scheduler, mid-admission), not the reap list.
    pub fn evict_lru(&self) -> Option<usize> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner
            .sessions
            .iter()
            .filter(|(_, s)| s.parked_slot.is_some())
            .min_by_key(|(_, s)| s.last_used)
            .map(|(&id, _)| id)?;
        inner.sessions.get_mut(&id).unwrap().parked_slot.take()
    }

    /// Token count of the session's history (for tests / introspection).
    pub fn history_len(&self, id: u64) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        inner.sessions.get(&id).filter(|s| !s.dropped).map(|s| s.history.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_append_finish_roundtrip() {
        let t = SessionTable::new(4);
        assert!(t.enabled());
        let id = t.open().unwrap();
        let prompt = t.append_begin(id, &[1, 2, 3]).unwrap();
        assert_eq!(prompt, vec![1, 2, 3]);
        // First turn: nothing parked yet.
        assert_eq!(t.resume_slot(id), None);
        assert!(t.finish(id, &[7, 8], 5));
        assert_eq!(t.history_len(id), Some(5));
        // Second turn resumes the parked slot and sees the full history.
        let prompt = t.append_begin(id, &[9]).unwrap();
        assert_eq!(prompt, vec![1, 2, 3, 7, 8, 9]);
        assert_eq!(t.resume_slot(id), Some(5));
    }

    #[test]
    fn busy_and_unknown_are_rejected() {
        let t = SessionTable::new(2);
        assert_eq!(t.append_begin(99, &[1]), Err(SessionError::Unknown(99)));
        let id = t.open().unwrap();
        assert!(matches!(t.append_begin(id, &[]).unwrap_err(), SessionError::Invalid(_)));
        t.append_begin(id, &[1]).unwrap();
        assert_eq!(t.append_begin(id, &[2]), Err(SessionError::Busy(id)));
        assert!(t.finish(id, &[3], 0));
        assert!(t.append_begin(id, &[2]).is_ok());
    }

    #[test]
    fn table_caps_and_disabled() {
        let t = SessionTable::new(0);
        assert!(!t.enabled());
        assert_eq!(t.open(), Err(SessionError::Disabled));
        let t = SessionTable::new(2);
        let a = t.open().unwrap();
        let _b = t.open().unwrap();
        assert_eq!(t.open(), Err(SessionError::TableFull(2)));
        // Dropping one frees a seat.
        t.drop_session(a).unwrap();
        assert!(t.open().is_ok());
        assert_eq!(t.live(), 2);
    }

    #[test]
    fn drop_reaps_parked_slot_lazily() {
        let t = SessionTable::new(4);
        let id = t.open().unwrap();
        t.append_begin(id, &[1]).unwrap();
        assert!(t.finish(id, &[2], 3));
        t.drop_session(id).unwrap();
        assert_eq!(t.take_reaped(), vec![3]);
        assert!(t.take_reaped().is_empty());
        assert_eq!(t.drop_session(id), Err(SessionError::Unknown(id)));
    }

    #[test]
    fn drop_mid_turn_defers_to_finish() {
        let t = SessionTable::new(4);
        let id = t.open().unwrap();
        t.append_begin(id, &[1]).unwrap();
        t.drop_session(id).unwrap(); // turn in flight: deferred
        assert!(t.take_reaped().is_empty());
        // finish refuses custody: the scheduler frees the slot directly.
        assert!(!t.finish(id, &[2], 7));
        assert_eq!(t.history_len(id), None);
    }

    #[test]
    fn evict_lru_takes_oldest_idle_slot() {
        let t = SessionTable::new(4);
        let a = t.open().unwrap();
        let b = t.open().unwrap();
        for (id, slot) in [(a, 0), (b, 1)] {
            t.append_begin(id, &[1]).unwrap();
            assert!(t.finish(id, &[2], slot));
        }
        // Touch a: b becomes the LRU.
        let _ = t.append_begin(a, &[5]).unwrap();
        assert!(t.finish(a, &[6], 0));
        assert_eq!(t.evict_lru(), Some(1));
        // b survives eviction with history intact — next turn re-prefills.
        assert_eq!(t.history_len(b), Some(3));
        assert_eq!(t.resume_slot(b), None);
        assert_eq!(t.evict_lru(), Some(0));
        assert_eq!(t.evict_lru(), None);
    }
}
