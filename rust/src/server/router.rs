//! Request router: model registry + per-model batcher + worker threads.
//!
//! The top of the L3 serving stack. Each registered engine gets its own
//! [`Batcher`] and a worker thread that drains batches through
//! [`Engine::generate_batch`]. The router dispatches by model name and
//! records per-request latency in [`Metrics`].

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{Engine, GenRequest, GenResult};
use super::metrics::Metrics;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

struct Route {
    batcher: Arc<Batcher>,
    _worker: std::thread::JoinHandle<()>,
}

/// Routes generation requests to named engines.
pub struct Router {
    routes: HashMap<String, Route>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Router {
    pub fn new() -> Self {
        Router {
            routes: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Register an engine under its name, spawning its worker.
    pub fn register(&mut self, engine: Engine, policy: BatchPolicy) {
        let name = engine.name.clone();
        let batcher = Arc::new(Batcher::new(policy));
        let metrics = self.metrics.clone();
        let worker_batcher = batcher.clone();
        let worker = std::thread::spawn(move || {
            while let Some((reqs, slots)) = worker_batcher.next_batch() {
                let t0 = Instant::now();
                let results = engine.generate_batch(&reqs);
                let elapsed = t0.elapsed().as_secs_f64();
                let new_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
                metrics.record_batch(reqs.len(), new_tokens, elapsed);
                for (res, slot) in results.into_iter().zip(slots) {
                    let _ = slot.send(res);
                }
            }
        });
        self.routes.insert(name, Route { batcher, _worker: worker });
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Submit a request; blocks until the result arrives.
    pub fn generate(&self, model: &str, prompt: Vec<u32>, max_new: usize) -> Result<GenResult> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let rx = route.batcher.submit(GenRequest { id, prompt, max_new });
        let result = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .map_err(|_| anyhow!("generation timed out"))?;
        self.metrics.record_request(t0.elapsed().as_secs_f64());
        Ok(result)
    }

    /// Non-blocking submit returning the receiver (for concurrent clients).
    pub fn submit(
        &self,
        model: &str,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Result<std::sync::mpsc::Receiver<GenResult>> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(route.batcher.submit(GenRequest { id, prompt, max_new }))
    }

    /// Shut down all workers.
    pub fn shutdown(&self) {
        for route in self.routes.values() {
            route.batcher.close();
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;

    fn router() -> Router {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let engine = Engine::new("sim-125m", cfg, Arc::new(w), None);
        let mut r = Router::new();
        r.register(engine, BatchPolicy::default());
        r
    }

    #[test]
    fn routes_and_generates() {
        let r = router();
        let out = r.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert!(r.metrics.requests() >= 1);
    }

    #[test]
    fn unknown_model_is_error() {
        let r = router();
        assert!(r.generate("gpt-9", vec![1], 1).is_err());
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let r = Arc::new(router());
        let mut handles = Vec::new();
        for i in 0..12u32 {
            let r2 = r.clone();
            handles.push(std::thread::spawn(move || {
                r2.generate("sim-125m", vec![i % 64 + 8], 2).unwrap()
            }));
        }
        let mut ok = 0;
        for h in handles {
            let res = h.join().unwrap();
            assert_eq!(res.tokens.len(), 2);
            ok += 1;
        }
        assert_eq!(ok, 12);
        // Batching should have coalesced at least some requests.
        assert!(r.metrics.batches() <= 12);
    }
}
