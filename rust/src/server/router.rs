//! Request router: model registry + per-model queue + worker threads.
//!
//! The top of the L3 serving stack. Each registered engine gets its own
//! [`Batcher`] queue and a worker thread, in one of two serving modes:
//!
//! * [`Router::register_continuous`] — a [`Scheduler`] token-budget
//!   step-loop with per-sequence KV cache slots: requests are admitted
//!   into the running decode batch per the route's admission policy
//!   (`SchedPolicy::admit`), long prompts prefill in chunks interleaved
//!   with decode steps, and sequences retire individually (the default
//!   for new deploys).
//! * [`Router::register_speculative`] — the same continuous step-loop,
//!   but the scheduler drives a [`super::spec::SpecEngine`]: the
//!   SLiM-compressed draft engine proposes up to `SchedPolicy::draft_k`
//!   tokens per sequence per tick and the dense target verifies them in
//!   one batched forward. Output stays token-identical to the plain
//!   continuous route over the target engine; only tokens-per-step
//!   changes.
//! * [`Router::register`] — the legacy fixed-batch worker: batches drain
//!   through [`Engine::generate_batch`] to completion before the next
//!   batch forms (kept for comparison benches and compatibility).
//!
//! The router dispatches by model name; [`Router::submit_with`] /
//! [`Router::generate_with`] carry the full [`RequestOpts`] (stop token,
//! admission `priority`, `client_id`, sampling knobs) down to the route's
//! queue. [`Router::submit_stream_with`] delivers the same generation as
//! incremental [`StreamEvent`] frames — native per-tick emission on
//! continuous/speculative routes, emulated at batch completion on fixed
//! routes, identical token content either way. Routes registered with
//! `SchedPolicy::max_sessions > 0` also serve stateful multi-turn
//! sessions ([`Router::session_open`] / [`Router::session_append`] /
//! [`Router::session_drop`]): the route's `server::session::SessionTable`
//! keeps each conversation's KV slot parked between turns so turn N+1
//! prefills only its new tokens. Each
//! route owns a [`Metrics`] instance in the router's
//! [`Registry`](super::obs::Registry) (`Router::registry`), and every
//! route's queue + worker log lifecycle events into one shared
//! [`FlightRecorder`](super::obs::FlightRecorder) (`Router::recorder`), so
//! a trace shows cross-route interleaving.

use super::batcher::{AdmitPolicy, BatchPolicy, Batcher};
use super::engine::{Engine, GenRequest, GenResult, StreamEvent};
use super::metrics::Metrics;
use super::obs::{EventKind, FlightRecorder, Registry, RouteObs, DEFAULT_CAPACITY};
use super::scheduler::{SchedPolicy, Scheduler};
use super::session::{SessionError, SessionTable};
use crate::model::{page_rows_for, KvDtype, SampleParams};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Per-request serving options beyond the prompt itself. `Default` gives
/// 16 tokens, no stop, neutral priority, anonymous client.
#[derive(Clone, Copy, Debug)]
pub struct RequestOpts {
    /// Generation budget (tokens).
    pub max_new: usize,
    /// Optional early-stop token (included in the output).
    pub stop: Option<u32>,
    /// Admission priority — higher is admitted sooner under fair-share
    /// admission (`server::batcher::AdmitPolicy::FairShare`).
    pub priority: i32,
    /// Originating client id; fair-share admission round-robins across
    /// distinct ids so one client cannot starve the rest.
    pub client_id: u64,
    /// Sampling knobs (temperature / top-k / top-p / seed). The default is
    /// greedy argmax — byte-identical to the pre-sampling stack.
    pub sample: SampleParams,
}

impl Default for RequestOpts {
    fn default() -> Self {
        RequestOpts {
            max_new: 16,
            stop: None,
            priority: 0,
            client_id: 0,
            sample: SampleParams::greedy(),
        }
    }
}

struct Route {
    batcher: Arc<Batcher>,
    /// The engine's vocab size, kept for admission-time prompt validation
    /// (an out-of-vocab token must be rejected here, not panic the worker).
    vocab: usize,
    /// KV cache storage dtype this route serves with (reported by the JSON
    /// api's `models` command).
    kv_dtype: KvDtype,
    /// Draft depth when this route decodes speculatively; `None` on
    /// non-speculative routes.
    draft_k: Option<usize>,
    /// Serving mode: "fixed" / "continuous" / "speculative".
    mode: &'static str,
    /// Admission policy the route's consumer applies (fixed-batch routes
    /// dispatch in arrival order — FIFO by construction).
    admit: AdmitPolicy,
    /// Session registry shared with the route's scheduler; `None` when the
    /// route does not serve sessions (fixed routes, `max_sessions == 0`).
    sessions: Option<Arc<SessionTable>>,
    /// KV page granularity (rows per page) of the route's paged pool.
    page_size: usize,
    /// Whether the route's scheduler shares prompt-prefix pages across
    /// requests (continuous routes only; off on fixed and speculative).
    prefix_cache: bool,
    _worker: std::thread::JoinHandle<()>,
}

/// Everything the JSON api's `models` command reports about one route.
#[derive(Clone, Debug)]
pub struct RouteInfo {
    pub name: String,
    pub kv_dtype: KvDtype,
    /// "fixed" / "continuous" / "speculative".
    pub mode: &'static str,
    /// Admission policy name ("fifo" / "sjf" / "fair-share").
    pub admit: &'static str,
    /// Speculative draft depth; `None` on non-speculative routes.
    pub draft_k: Option<usize>,
    /// Max concurrent multi-turn sessions; 0 = sessions unsupported.
    pub max_sessions: usize,
    /// Whether streamed delivery is available (all routes: native on
    /// continuous/speculative, emulated on fixed).
    pub streaming: bool,
    /// KV page granularity (rows per page) of the route's paged pool.
    pub page_size: usize,
    /// Whether the route shares prompt-prefix KV pages across requests.
    pub prefix_cache: bool,
}

/// Routes generation requests to named engines.
pub struct Router {
    routes: HashMap<String, Route>,
    /// Per-route metrics, keyed by model name.
    pub registry: Arc<Registry>,
    /// Lifecycle event ring shared by every route.
    pub recorder: Arc<FlightRecorder>,
    next_id: AtomicU64,
}

impl Router {
    pub fn new() -> Self {
        Router {
            routes: HashMap::new(),
            registry: Arc::new(Registry::new()),
            recorder: Arc::new(FlightRecorder::new(DEFAULT_CAPACITY)),
            next_id: AtomicU64::new(1),
        }
    }

    /// The metrics instance for a registered model's route.
    pub fn route_metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.registry.get(model)
    }

    /// This route's observability bundle: its registry metrics plus the
    /// shared recorder, under the model name.
    fn route_obs(&self, name: &str) -> RouteObs {
        RouteObs::new(self.registry.route(name), Arc::clone(&self.recorder), name)
    }

    /// Register an engine under its name with the legacy fixed-batch
    /// worker: each batch runs to completion via
    /// [`Engine::generate_batch`] before the next batch is formed.
    pub fn register(&mut self, engine: Engine, policy: BatchPolicy) {
        let name = engine.name.clone();
        let vocab = engine.config().vocab;
        let kv_dtype = engine.kv_dtype();
        let page_size = page_rows_for(engine.config().max_seq);
        let obs = self.route_obs(&name);
        let batcher =
            Arc::new(Batcher::with_recorder(policy, Arc::clone(&self.recorder), obs.route));
        let worker_batcher = batcher.clone();
        let worker = std::thread::spawn(move || {
            let metrics = &obs.metrics;
            while let Some(batch) = worker_batcher.next_batch() {
                let t0 = Instant::now();
                for (slot, p) in batch.iter().enumerate() {
                    let wait_s = p.wait_so_far().as_secs_f64();
                    metrics.record_queue_wait(wait_s);
                    obs.event(
                        EventKind::Admitted,
                        p.req.id,
                        slot as u32,
                        p.req.prompt.len().min(u32::MAX as usize) as u32,
                        (wait_s * 1e6).min(u32::MAX as f64) as u32,
                        batch.len() as u32,
                    );
                }
                let reqs: Vec<GenRequest> = batch.iter().map(|p| p.req.clone()).collect();
                let results = engine.generate_batch(&reqs);
                let elapsed = t0.elapsed().as_secs_f64();
                let new_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
                metrics.record_batch(batch.len(), new_tokens, elapsed);
                for (slot, (res, pending)) in results.into_iter().zip(batch).enumerate() {
                    metrics.record_request(pending.enqueued.elapsed().as_secs_f64());
                    obs.event(
                        EventKind::Retired,
                        res.id,
                        slot as u32,
                        res.tokens.len().min(u32::MAX as usize) as u32,
                        0,
                        0,
                    );
                    // Fixed batches run to completion, so streamed
                    // delivery is emulated: every token frame lands at
                    // once, then the same Done a scheduler would send.
                    if let Some(tx) = &pending.stream {
                        for (index, &token) in res.tokens.iter().enumerate() {
                            let _ = tx.send(StreamEvent::Token { index, token });
                        }
                        let _ = tx.send(StreamEvent::Done(res.clone()));
                    }
                    let _ = pending.result_slot.send(res);
                }
            }
        });
        let route = Route {
            batcher,
            vocab,
            kv_dtype,
            draft_k: None,
            mode: "fixed",
            admit: AdmitPolicy::Fifo,
            sessions: None,
            page_size,
            prefix_cache: false,
            _worker: worker,
        };
        self.routes.insert(name, route);
    }

    /// Register an engine under its name with the continuous-batching
    /// [`Scheduler`]: requests are admitted into the in-flight decode
    /// batch as cache slots free up and retire individually.
    pub fn register_continuous(&mut self, engine: Engine, policy: SchedPolicy) {
        let name = engine.name.clone();
        let vocab = engine.config().vocab;
        let page_size = page_rows_for(engine.config().max_seq);
        // Policy override, else the engine's own dtype — the same
        // resolution the scheduler applies to its pool.
        let kv_dtype = policy.kv_dtype.unwrap_or_else(|| engine.kv_dtype());
        let obs = self.route_obs(&name);
        let batcher = Arc::new(Batcher::with_recorder(
            BatchPolicy::default(),
            Arc::clone(&self.recorder),
            obs.route,
        ));
        let worker_batcher = batcher.clone();
        let scheduler = Scheduler::new(Arc::new(engine), policy);
        let sessions = scheduler.sessions().enabled().then(|| scheduler.sessions());
        let worker = std::thread::spawn(move || {
            scheduler.run(&worker_batcher, &obs);
        });
        let route = Route {
            batcher,
            vocab,
            kv_dtype,
            draft_k: None,
            mode: "continuous",
            admit: policy.admit,
            sessions,
            page_size,
            prefix_cache: true,
            _worker: worker,
        };
        self.routes.insert(name, route);
    }

    /// Register a **speculative** route under the target engine's name: a
    /// continuous-batching [`Scheduler`] whose step loop drafts
    /// `policy.draft_k` tokens per sequence on `draft` (typically the
    /// SLiM-compressed, kernel-backed twin) and verifies them in one
    /// batched forward on `target`. Tokens served are identical to
    /// [`Router::register_continuous`] over `target` alone.
    ///
    /// Panics if `policy.draft_k == 0` — a speculative route with no draft
    /// depth is a misconfiguration, not a fallback.
    pub fn register_speculative(&mut self, target: Engine, draft: Engine, policy: SchedPolicy) {
        let name = target.name.clone();
        let vocab = target.config().vocab;
        let page_size = page_rows_for(target.config().max_seq);
        let kv_dtype = policy.kv_dtype.unwrap_or_else(|| target.kv_dtype());
        let draft_k = Some(policy.draft_k);
        let obs = self.route_obs(&name);
        let batcher = Arc::new(Batcher::with_recorder(
            BatchPolicy::default(),
            Arc::clone(&self.recorder),
            obs.route,
        ));
        let worker_batcher = batcher.clone();
        let scheduler = Scheduler::new_spec(Arc::new(target), Arc::new(draft), policy);
        let sessions = scheduler.sessions().enabled().then(|| scheduler.sessions());
        let worker = std::thread::spawn(move || {
            scheduler.run(&worker_batcher, &obs);
        });
        let route = Route {
            batcher,
            vocab,
            kv_dtype,
            draft_k,
            mode: "speculative",
            admit: policy.admit,
            sessions,
            page_size,
            prefix_cache: false,
            _worker: worker,
        };
        self.routes.insert(name, route);
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Registered models with the KV cache dtype each route serves with.
    pub fn model_infos(&self) -> Vec<(&str, KvDtype)> {
        self.routes.iter().map(|(n, r)| (n.as_str(), r.kv_dtype)).collect()
    }

    /// Registered models with KV dtype and speculative draft depth
    /// (`None` on non-speculative routes).
    pub fn model_details(&self) -> Vec<(&str, KvDtype, Option<usize>)> {
        self.routes
            .iter()
            .map(|(n, r)| (n.as_str(), r.kv_dtype, r.draft_k))
            .collect()
    }

    /// Full per-route capability report — what the JSON api's `models`
    /// command serves: serving mode, admission policy, speculative draft
    /// depth, session capacity, and streaming support.
    pub fn route_infos(&self) -> Vec<RouteInfo> {
        self.routes
            .iter()
            .map(|(n, r)| RouteInfo {
                name: n.clone(),
                kv_dtype: r.kv_dtype,
                mode: r.mode,
                admit: r.admit.name(),
                draft_k: r.draft_k,
                max_sessions: r.sessions.as_ref().map_or(0, |t| t.max_sessions()),
                streaming: true,
                page_size: r.page_size,
                prefix_cache: r.prefix_cache,
            })
            .collect()
    }

    /// Submit a request; blocks until the result arrives.
    pub fn generate(&self, model: &str, prompt: Vec<u32>, max_new: usize) -> Result<GenResult> {
        self.generate_opts(model, prompt, max_new, None)
    }

    /// [`Router::generate`] with an optional stop token: generation retires
    /// early the moment the stop token is produced (it is included in the
    /// output).
    pub fn generate_opts(
        &self,
        model: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: Option<u32>,
    ) -> Result<GenResult> {
        self.generate_with(model, prompt, RequestOpts { max_new, stop, ..Default::default() })
    }

    /// Blocking submit with the full per-request options (stop token,
    /// admission priority, client id).
    pub fn generate_with(
        &self,
        model: &str,
        prompt: Vec<u32>,
        opts: RequestOpts,
    ) -> Result<GenResult> {
        let rx = self.submit_with(model, prompt, opts)?;
        rx.recv_timeout(std::time::Duration::from_secs(120))
            .map_err(|_| anyhow!("generation timed out"))
    }

    /// Non-blocking submit returning the receiver (for concurrent clients).
    pub fn submit(
        &self,
        model: &str,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Result<std::sync::mpsc::Receiver<GenResult>> {
        self.submit_opts(model, prompt, max_new, None)
    }

    /// [`Router::submit`] with an optional stop token.
    pub fn submit_opts(
        &self,
        model: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: Option<u32>,
    ) -> Result<std::sync::mpsc::Receiver<GenResult>> {
        self.submit_with(model, prompt, RequestOpts { max_new, stop, ..Default::default() })
    }

    /// Non-blocking submit with the full per-request options — the one
    /// place router requests become [`GenRequest`]s. `priority` and
    /// `client_id` feed the route's admission policy
    /// (`server::batcher::AdmitPolicy`); they are inert on FIFO routes.
    pub fn submit_with(
        &self,
        model: &str,
        prompt: Vec<u32>,
        opts: RequestOpts,
    ) -> Result<std::sync::mpsc::Receiver<GenResult>> {
        let (route, req) = self.build_request(model, prompt, &opts, None)?;
        Ok(route.batcher.submit(req))
    }

    /// Streamed submit: the returned receiver yields one
    /// [`StreamEvent::Token`] per generated token as the route emits it,
    /// then a [`StreamEvent::Done`] with the full [`GenResult`] — the
    /// concatenated frames always equal the result's tokens.
    pub fn submit_stream_with(
        &self,
        model: &str,
        prompt: Vec<u32>,
        opts: RequestOpts,
    ) -> Result<std::sync::mpsc::Receiver<StreamEvent>> {
        let (route, req) = self.build_request(model, prompt, &opts, None)?;
        Ok(route.batcher.submit_stream(req))
    }

    /// Validate and assemble one [`GenRequest`] against a route (model
    /// exists, tokens in vocab, sampling knobs in range).
    fn build_request(
        &self,
        model: &str,
        prompt: Vec<u32>,
        opts: &RequestOpts,
        session: Option<u64>,
    ) -> Result<(&Route, GenRequest)> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        if let Some(&t) = prompt.iter().find(|&&t| t as usize >= route.vocab) {
            return Err(anyhow!("token {t} out of vocab (size {})", route.vocab));
        }
        opts.sample.validate().map_err(|e| anyhow!(e))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = GenRequest {
            id,
            prompt,
            max_new: opts.max_new,
            stop: opts.stop,
            priority: opts.priority,
            client_id: opts.client_id,
            sample: opts.sample,
            session,
        };
        Ok((route, req))
    }

    /// Open a multi-turn session on `model`; returns the session id.
    /// Errors if the model is unknown, the route does not serve sessions,
    /// or the route's session table is full.
    pub fn session_open(&self, model: &str) -> Result<u64, SessionError> {
        self.route_sessions(model)?.open()
    }

    /// Append one turn to a session and submit it: `tokens` are the
    /// turn's NEW tokens only — the route's session table prepends the
    /// conversation history, and the scheduler resumes the parked KV slot
    /// so only the new tokens prefill. Blocks until the turn's result.
    pub fn session_append(
        &self,
        model: &str,
        session: u64,
        tokens: Vec<u32>,
        opts: RequestOpts,
    ) -> Result<GenResult, SessionError> {
        let (route, req) = self.build_session_request(model, session, tokens, &opts)?;
        route
            .batcher
            .submit(req)
            .recv_timeout(std::time::Duration::from_secs(120))
            .map_err(|_| SessionError::Invalid("generation timed out".into()))
    }

    /// Streamed [`Router::session_append`]: the turn's tokens arrive as
    /// [`StreamEvent`] frames.
    pub fn session_append_stream(
        &self,
        model: &str,
        session: u64,
        tokens: Vec<u32>,
        opts: RequestOpts,
    ) -> Result<std::sync::mpsc::Receiver<StreamEvent>, SessionError> {
        let (route, req) = self.build_session_request(model, session, tokens, &opts)?;
        Ok(route.batcher.submit_stream(req))
    }

    /// Drop a session, releasing its parked KV slot (lazily, on the
    /// scheduler's next tick). A turn in flight finishes first.
    pub fn session_drop(&self, model: &str, session: u64) -> Result<(), SessionError> {
        self.route_sessions(model)?.drop_session(session)
    }

    fn route_sessions(&self, model: &str) -> Result<&Arc<SessionTable>, SessionError> {
        self.routes
            .get(model)
            .ok_or(SessionError::Disabled)?
            .sessions
            .as_ref()
            .ok_or(SessionError::Disabled)
    }

    fn build_session_request(
        &self,
        model: &str,
        session: u64,
        tokens: Vec<u32>,
        opts: &RequestOpts,
    ) -> Result<(&Route, GenRequest), SessionError> {
        let table = self.route_sessions(model)?;
        let route = &self.routes[model];
        if let Some(&t) = tokens.iter().find(|&&t| t as usize >= route.vocab) {
            return Err(SessionError::Invalid(format!(
                "token {t} out of vocab (size {})",
                route.vocab
            )));
        }
        opts.sample.validate().map_err(SessionError::Invalid)?;
        // This stakes the turn (marks the session busy) — the submit
        // below cannot fail, so the turn always retires and un-busies.
        let prompt = table.append_begin(session, &tokens)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = GenRequest {
            id,
            prompt,
            max_new: opts.max_new,
            stop: opts.stop,
            priority: opts.priority,
            client_id: opts.client_id,
            sample: opts.sample,
            session: Some(session),
        };
        Ok((route, req))
    }

    /// Shut down all workers.
    pub fn shutdown(&self) {
        for route in self.routes.values() {
            route.batcher.close();
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;

    fn engine() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        Engine::new("sim-125m", cfg, Arc::new(w), None)
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.register(engine(), BatchPolicy::default());
        r
    }

    fn router_continuous() -> Router {
        let mut r = Router::new();
        r.register_continuous(engine(), SchedPolicy { max_slots: 4, ..Default::default() });
        r
    }

    /// The registered route's metrics (every test registers one model).
    fn m(r: &Router) -> Arc<Metrics> {
        r.route_metrics("sim-125m").expect("route metrics")
    }

    #[test]
    fn model_infos_report_kv_dtype() {
        let mut r = Router::new();
        // Engine-configured dtype is inherited when the policy leaves
        // kv_dtype unset...
        r.register_continuous(
            engine().with_kv_dtype(KvDtype::Int8),
            SchedPolicy { max_slots: 2, ..Default::default() },
        );
        let infos = r.model_infos();
        assert_eq!(infos, vec![("sim-125m", KvDtype::Int8)]);
        // ...and the int8-KV continuous route still serves correct-shape
        // output, token-identical to its (equally int8) solo reference.
        let out = r.generate("sim-125m", vec![3, 4, 5], 3).unwrap();
        assert_eq!(out.tokens.len(), 3);
        let req = GenRequest::new(1, vec![3, 4, 5], 3);
        let solo = engine().with_kv_dtype(KvDtype::Int8).generate_batch(&[req]);
        assert_eq!(out.tokens, solo[0].tokens);
    }

    fn kernel_draft() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let mut cw = crate::model::CompressedWeights::new();
        for (name, _d_in, _d_out) in cfg.linear_layers() {
            let q = crate::quant::slim_quant::quantize(w.expect(&name), 4);
            cw.insert(&name, crate::kernels::LinearOp::int4(&q, None));
        }
        Engine::with_kernels("sim-125m-draft", cfg, Arc::new(w), Arc::new(cw))
    }

    #[test]
    fn speculative_route_matches_continuous_and_reports_draft_k() {
        let mut r = Router::new();
        let policy = SchedPolicy { max_slots: 2, draft_k: 3, ..Default::default() };
        r.register_speculative(engine(), kernel_draft(), policy);
        // `models` reports the draft depth on speculative routes.
        assert_eq!(r.model_details(), vec![("sim-125m", KvDtype::F32, Some(3))]);
        // ...while non-speculative routes report None.
        let plain = router_continuous();
        assert_eq!(plain.model_details()[0].2, None);

        let out = r.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        let reference = plain.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        assert_eq!(out.tokens, reference.tokens);
        let (drafted, accepted) = out.spec.expect("speculative route reports draft stats");
        assert!(accepted <= drafted);
        assert!(m(&r).spec_drafted() >= drafted as u64);
    }

    #[test]
    fn routes_and_generates() {
        let r = router();
        let out = r.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert!(m(&r).requests() >= 1);
    }

    #[test]
    fn unknown_model_is_error() {
        let r = router();
        assert!(r.generate("gpt-9", vec![1], 1).is_err());
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let r = Arc::new(router());
        let mut handles = Vec::new();
        for i in 0..12u32 {
            let r2 = r.clone();
            handles.push(std::thread::spawn(move || {
                r2.generate("sim-125m", vec![i % 64 + 8], 2).unwrap()
            }));
        }
        let mut ok = 0;
        for h in handles {
            let res = h.join().unwrap();
            assert_eq!(res.tokens.len(), 2);
            ok += 1;
        }
        assert_eq!(ok, 12);
        // Batching should have coalesced at least some requests.
        assert!(m(&r).batches() <= 12);
    }

    #[test]
    fn continuous_route_generates_and_records_serving_metrics() {
        let r = router_continuous();
        let out = r.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        assert_eq!(out.tokens.len(), 4);
        // The continuous route matches the fixed route token-for-token
        // (both are solo-equivalent).
        let fixed = router().generate("sim-125m", vec![3, 4, 5], 4).unwrap();
        assert_eq!(out.tokens, fixed.tokens);
        let metrics = m(&r);
        assert!(metrics.requests() >= 1);
        assert!(metrics.ttft_pct(50.0) > 0.0);
        assert!(metrics.tokens() >= 4);
        // The shared recorder captured this request's lifecycle.
        let events = r.recorder.snapshot(None);
        assert!(events.iter().any(|e| e.kind == super::EventKind::Enqueued));
        assert!(events.iter().any(|e| e.kind == super::EventKind::Retired));
    }

    #[test]
    fn continuous_route_concurrent_mixed_lengths() {
        let r = Arc::new(router_continuous());
        let mut handles = Vec::new();
        for i in 0..10u32 {
            let r2 = r.clone();
            handles.push(std::thread::spawn(move || {
                let plen = 1 + (i as usize % 4);
                let prompt: Vec<u32> = (0..plen).map(|j| 8 + i + j as u32).collect();
                let out = r2.generate("sim-125m", prompt, 1 + (i as usize % 3)).unwrap();
                (i, out)
            }));
        }
        for h in handles {
            let (i, out) = h.join().unwrap();
            assert_eq!(out.tokens.len(), 1 + (i as usize % 3));
        }
        assert_eq!(m(&r).requests(), 10);
    }

    #[test]
    fn out_of_vocab_prompt_rejected_without_killing_route() {
        for r in [router(), router_continuous()] {
            let err = r.generate("sim-125m", vec![5, 99_999], 2);
            assert!(err.is_err(), "out-of-vocab token must be rejected");
            // The worker thread is still alive and serving.
            let ok = r.generate("sim-125m", vec![5, 6], 2).unwrap();
            assert_eq!(ok.tokens.len(), 2);
        }
    }

    fn drain(rx: std::sync::mpsc::Receiver<StreamEvent>) -> GenResult {
        let mut tokens: Vec<u32> = Vec::new();
        loop {
            let ev = rx
                .recv_timeout(std::time::Duration::from_secs(60))
                .expect("stream ended without Done");
            match ev {
                StreamEvent::Token { index, token } => {
                    assert_eq!(index, tokens.len());
                    tokens.push(token);
                }
                StreamEvent::Done(res) => {
                    assert_eq!(tokens, res.tokens);
                    return res;
                }
            }
        }
    }

    /// Streamed submits yield the same tokens as plain submits on every
    /// serving mode — native frames on continuous routes, emulated on the
    /// fixed-batch worker.
    #[test]
    fn streamed_submit_matches_plain_on_all_modes() {
        for r in [router(), router_continuous()] {
            let plain = r.generate("sim-125m", vec![3, 4, 5], 4).unwrap();
            let rx = r
                .submit_stream_with(
                    "sim-125m",
                    vec![3, 4, 5],
                    RequestOpts { max_new: 4, ..Default::default() },
                )
                .unwrap();
            assert_eq!(drain(rx).tokens, plain.tokens);
        }
    }

    #[test]
    fn sampling_plumbs_and_validates_through_router() {
        let r = router_continuous();
        let sample = SampleParams { temperature: 0.8, top_k: 16, top_p: 0.9, seed: 11 };
        let opts = RequestOpts { max_new: 5, sample, ..Default::default() };
        let a = r.generate_with("sim-125m", vec![3, 4, 5], opts).unwrap();
        let b = r.generate_with("sim-125m", vec![3, 4, 5], opts).unwrap();
        assert_eq!(a.tokens, b.tokens, "same seed must reproduce");
        // Out-of-range knobs are rejected at submit, not deep in a worker.
        let bad = RequestOpts {
            sample: SampleParams { top_p: 0.0, ..SampleParams::greedy() },
            ..Default::default()
        };
        assert!(r.generate_with("sim-125m", vec![3], bad).is_err());
    }

    #[test]
    fn session_api_roundtrip_and_capabilities() {
        let mut r = Router::new();
        let policy = SchedPolicy { max_slots: 2, max_sessions: 2, ..Default::default() };
        r.register_continuous(engine(), policy);
        let infos = r.route_infos();
        let info = &infos[0];
        assert_eq!((info.mode, info.admit), ("continuous", "fifo"));
        assert_eq!(info.max_sessions, 2);
        assert!(info.streaming);
        assert_eq!(info.draft_k, None);
        // sim-125m has max_seq 64 → 16-row pages; continuous routes share
        // prompt-prefix pages.
        assert_eq!(info.page_size, 16);
        assert!(info.prefix_cache);

        let sid = r.session_open("sim-125m").unwrap();
        let opts = RequestOpts { max_new: 3, ..Default::default() };
        let t1 = r.session_append("sim-125m", sid, vec![5, 6], opts).unwrap();
        assert_eq!(t1.tokens.len(), 3);
        // Turn 2 resumes the conversation; the streamed variant works too.
        let rx = r.session_append_stream("sim-125m", sid, vec![9], opts).unwrap();
        let t2 = drain(rx);
        // Reference: fresh request over the full conversation so far.
        let full = [vec![5, 6], t1.tokens.clone(), vec![9]].concat();
        let solo = r.generate("sim-125m", full, 3).unwrap();
        assert_eq!(t2.tokens, solo.tokens);
        r.session_drop("sim-125m", sid).unwrap();
        assert!(r.session_append("sim-125m", sid, vec![4], opts).is_err());
        // Session calls on a session-less route fail typed.
        let plain = router();
        assert!(matches!(plain.session_open("sim-125m"), Err(SessionError::Disabled)));
    }

    #[test]
    fn stop_token_plumbs_through_router() {
        let r = router();
        let free = r.generate("sim-125m", vec![5, 6, 7], 6).unwrap();
        let stop = free.tokens[1];
        let stopped = r.generate_opts("sim-125m", vec![5, 6, 7], 6, Some(stop)).unwrap();
        let cut = free.tokens.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(stopped.tokens, free.tokens[..cut].to_vec());
    }

    #[test]
    fn priority_and_client_id_plumb_through_router() {
        // A fair-share continuous route serves tagged requests correctly
        // (admission metadata must never change tokens), and the
        // continuous path reports a server-side TTFT.
        let mut r = Router::new();
        let policy = SchedPolicy {
            max_slots: 2,
            admit: crate::server::batcher::AdmitPolicy::FairShare,
            chunk_tokens: 2,
            step_tokens: 4,
            ..Default::default()
        };
        r.register_continuous(engine(), policy);
        let opts = RequestOpts { max_new: 3, priority: 2, client_id: 42, ..Default::default() };
        let out = r.generate_with("sim-125m", vec![3, 4, 5], opts).unwrap();
        assert_eq!(out.tokens.len(), 3);
        assert!(out.ttft_s.unwrap() > 0.0);
        let solo = engine().generate_batch(&[GenRequest::new(1, vec![3, 4, 5], 3)]);
        assert_eq!(out.tokens, solo[0].tokens);
        // Queue-wait metrics were recorded at admission.
        assert!(m(&r).queue_wait_pct(50.0) > 0.0);
    }
}
