//! TCP front-end: newline-delimited JSON over std::net.
//!
//! Request:  `{"model": "...", "prompt": [ints], "max_new": n, "stop": t?,
//!           "priority": p?, "client_id": c?, "kv_dtype": "..."?}`
//!           (`stop` is optional: generation retires early once token `t`
//!           is produced, included in the output. `priority` — higher is
//!           admitted sooner — and `client_id` feed the route's admission
//!           policy when it is fair-share (`SchedPolicy::admit`); both
//!           default to 0 and never change the generated tokens, only who
//!           waits when cache slots are scarce. `kv_dtype` is an optional
//!           assertion on the route's serving KV cache dtype — one of
//!           "f32", "f16"/"fp16", "bf16", "int8", "fp8"/"fp8-e4m3"; an
//!           unknown name errors listing the valid dtypes, and a known
//!           name that differs from what the route was registered with
//!           errors naming the route's actual dtype.)
//! Response: `{"ok": true, "tokens": [ints], "ttft_ms": f?, "drafted": n?,
//!           "accepted": n?, "accept_rate": f?}` or
//!           `{"ok": false, "error": "..."}` — `ttft_ms` is the
//!           server-measured submit→first-token latency, present on
//!           serving paths that observe one. The speculative-decoding
//!           trio appears only on speculative routes
//!           (`Router::register_speculative`): how many tokens the
//!           compressed draft proposed for this request, how many the
//!           dense target confirmed, and their ratio. They describe
//!           speed, never content — tokens are identical to the plain
//!           continuous route.
//! Special:  `{"cmd": "metrics"}` → `{"ok": true, "summary": "...",
//!           "routes": {route: {...}}}` — `summary` is the legacy one-line
//!           cross-route aggregate (queue-wait p50/p95, route-wide
//!           `spec_accept` rate, TTFT and decode percentiles); `routes`
//!           maps each route name to its structured metrics (counters,
//!           per-stage busy seconds, and each histogram as
//!           `{count, sum, p50, p95, p99}` — see `Metrics::export_json`);
//!           `{"cmd": "metrics_prom"}` → `{"ok": true, "text": "..."}` —
//!           the same registry as Prometheus text exposition (counters /
//!           gauges / summary-quantile families labelled by route), ready
//!           for a scrape endpoint to relay verbatim;
//!           `{"cmd": "trace", "last": n?}` → `{"ok": true, "trace":
//!           {...}}` — the flight recorder's request-lifecycle ring
//!           (optionally only the last `n` events) as Chrome trace-event
//!           JSON (`traceEvents` with `ph`/`ts`/`dur`/`pid`/`tid`), ready
//!           to save and load in Perfetto / `chrome://tracing`;
//!           `{"cmd": "models"}` → `{"ok": true, "models": [{"name": "...",
//!           "kv_dtype": "f32" | "f16" | "bf16" | "int8" | "fp8-e4m3",
//!           "spec": bool,
//!           "draft_k": n?}, ...]}` — `kv_dtype` is the serving KV cache
//!           storage dtype the route was registered with
//!           (`model::KvDtype`; the 8-bit dtypes hold ~4× fewer cache
//!           bytes per in-flight sequence, f16/bf16 2×); `spec` marks
//!           speculative
//!           routes and `draft_k` (present only when `spec` is true) is
//!           their configured draft depth.
//!
//! One thread per connection (the engines are the bottleneck, not the
//! accept loop), with the router's batcher coalescing across connections.

use super::router::{RequestOpts, Router};
use crate::model::KvDtype;
use crate::util::json::{n, obj, s, Json};
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Serve until the listener errors. Binds to `addr` ("127.0.0.1:0" picks a
/// free port); returns the bound address via callback before blocking.
pub fn serve(
    router: Arc<Router>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(router, stream);
        });
    }
    Ok(())
}

fn handle_conn(router: Arc<Router>, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let response = handle_line(&router, line.trim());
        writer.write_all(response.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// Process one request line (exposed for tests).
pub fn handle_line(router: &Router, line: &str) -> Json {
    match process(router, line) {
        Ok(v) => v,
        Err(e) => obj(vec![("ok", Json::Bool(false)), ("error", s(&e.to_string()))]),
    }
}

fn process(router: &Router, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "metrics" => Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("summary", s(&router.registry.summary())),
                ("routes", router.registry.to_json()),
            ])),
            "metrics_prom" => Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("text", s(&router.registry.prometheus())),
            ])),
            "trace" => {
                let last = req.get("last").and_then(Json::as_usize);
                Ok(obj(vec![
                    ("ok", Json::Bool(true)),
                    ("trace", router.recorder.trace_json(last)),
                ]))
            }
            "models" => Ok(obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "models",
                    Json::Arr(
                        router
                            .model_details()
                            .iter()
                            .map(|(name, dt, draft_k)| {
                                let mut fields = vec![
                                    ("name", s(name)),
                                    ("kv_dtype", s(dt.name())),
                                    ("spec", Json::Bool(draft_k.is_some())),
                                ];
                                if let Some(k) = draft_k {
                                    fields.push(("draft_k", n(*k as f64)));
                                }
                                obj(fields)
                            })
                            .collect(),
                    ),
                ),
            ])),
            other => Err(anyhow!("unknown cmd {other}")),
        };
    }
    let model = req
        .get("model")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing model"))?;
    // Optional KV-dtype assertion: an unknown name errors with the valid
    // list; a known name must match what the route was registered with.
    if let Some(want) = req.get("kv_dtype").and_then(Json::as_str) {
        let want = KvDtype::parse(want).map_err(|e| anyhow!("{e}"))?;
        let have = router
            .model_infos()
            .into_iter()
            .find(|&(name, _)| name == model)
            .map(|(_, dt)| dt)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        if want != have {
            return Err(anyhow!(
                "model {model} serves kv_dtype {}, not {}",
                have.name(),
                want.name()
            ));
        }
    }
    let prompt: Vec<u32> = req
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing prompt"))?
        .iter()
        .map(|v| v.as_usize().map(|u| u as u32).ok_or_else(|| anyhow!("bad token")))
        .collect::<Result<_>>()?;
    let max_new = req.get("max_new").and_then(Json::as_usize).unwrap_or(16);
    let stop = req.get("stop").and_then(Json::as_usize).map(|u| u as u32);
    // Admission metadata (both optional, both inert under FIFO routes).
    let priority = req.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i32;
    let client_id = req.get("client_id").and_then(Json::as_usize).unwrap_or(0) as u64;
    let opts = RequestOpts { max_new: max_new.min(256), stop, priority, client_id };
    let result = router.generate_with(model, prompt, opts)?;
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("tokens", Json::Arr(result.tokens.iter().map(|&t| n(t as f64)).collect())),
    ];
    if let Some(ttft) = result.ttft_s {
        fields.push(("ttft_ms", n(ttft * 1e3)));
    }
    if let Some((drafted, accepted)) = result.spec {
        fields.push(("drafted", n(drafted as f64)));
        fields.push(("accepted", n(accepted as f64)));
        let rate = if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
        fields.push(("accept_rate", n(rate)));
    }
    Ok(obj(fields))
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one JSON request, get one JSON response.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Convenience generate call.
    pub fn generate(&mut self, model: &str, prompt: &[u32], max_new: usize) -> Result<Vec<u32>> {
        let req = obj(vec![
            ("model", s(model)),
            ("prompt", Json::Arr(prompt.iter().map(|&t| n(t as f64)).collect())),
            ("max_new", n(max_new as f64)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("?")
            ));
        }
        Ok(resp
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_usize().map(|u| u as u32))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;
    use crate::server::{BatchPolicy, Engine};

    fn router() -> Arc<Router> {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let mut r = Router::new();
        r.register(
            Engine::new("sim-125m", cfg, Arc::new(w), None),
            BatchPolicy::default(),
        );
        Arc::new(r)
    }

    #[test]
    fn handle_line_generate() {
        let r = router();
        let resp = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":3}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);
    }

    #[test]
    fn handle_line_errors() {
        let r = router();
        let resp = handle_line(&r, "not json");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let resp = handle_line(&r, r#"{"model":"nope","prompt":[1]}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    /// The optional `kv_dtype` request field: a matching name passes, an
    /// unknown name errors listing every valid dtype, and a valid-but-
    /// mismatched name errors naming the route's actual dtype.
    #[test]
    fn kv_dtype_field_validated_against_route() {
        let r = router(); // registered with the default f32 KV store
        let ok = handle_line(
            &r,
            r#"{"model":"sim-125m","prompt":[5,6],"max_new":2,"kv_dtype":"f32"}"#,
        );
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        let bad = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"kv_dtype":"float8"}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let msg = bad.get("error").and_then(Json::as_str).unwrap();
        assert!(
            msg.contains(crate::model::attention::KV_DTYPE_NAMES),
            "error must list valid dtypes: {msg}"
        );
        let mismatch =
            handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"kv_dtype":"bf16"}"#);
        assert_eq!(mismatch.get("ok").and_then(Json::as_bool), Some(false));
        let msg = mismatch.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("serves kv_dtype f32"), "{msg}");
    }

    #[test]
    fn stop_field_retires_generation_early() {
        let r = router();
        let free = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":5}"#);
        let free_toks: Vec<usize> = free
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let stop = free_toks[1];
        let resp = handle_line(
            &r,
            &format!(r#"{{"model":"sim-125m","prompt":[5,6],"max_new":5,"stop":{stop}}}"#),
        );
        let got: Vec<usize> = resp
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let cut = free_toks.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(got, free_toks[..cut].to_vec());
    }

    #[test]
    fn metrics_and_models_cmds() {
        let r = router();
        let resp = handle_line(&r, r#"{"cmd":"models"}"#);
        let text = resp.to_string_compact();
        assert!(text.contains("sim-125m"));
        // Each model entry reports its serving KV cache dtype and whether
        // the route decodes speculatively.
        assert!(text.contains("kv_dtype"), "missing kv_dtype in {text}");
        assert!(text.contains("f32"));
        assert!(text.contains("\"spec\":false"), "missing spec flag in {text}");
        // `metrics` keeps the legacy one-line aggregate under `summary`
        // and adds the per-route structured export under `routes`.
        let _ = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":2}"#);
        let resp = handle_line(&r, r#"{"cmd":"metrics"}"#);
        assert!(resp.get("summary").and_then(Json::as_str).unwrap().contains("requests="));
        let route = resp.get("routes").and_then(|rt| rt.get("sim-125m")).expect("route json");
        assert!(route.get("requests").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(route
            .get("request_latency_seconds")
            .and_then(|h| h.get("p95"))
            .and_then(Json::as_f64)
            .is_some());
        // `metrics_prom` returns Prometheus text exposition.
        let prom = handle_line(&r, r#"{"cmd":"metrics_prom"}"#);
        let text = prom.get("text").and_then(Json::as_str).unwrap();
        assert!(text.contains("# TYPE slim_requests_total counter"), "{text}");
        assert!(text.contains("slim_requests_total{route=\"sim-125m\"}"), "{text}");
        // `trace` dumps the flight recorder as Chrome trace-event JSON,
        // honoring the optional `last` cap.
        let trace = handle_line(&r, r#"{"cmd":"trace"}"#);
        let evs = trace
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .expect("traceEvents");
        assert!(!evs.is_empty());
        let capped = handle_line(&r, r#"{"cmd":"trace","last":1}"#);
        let capped_evs = capped
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .expect("traceEvents");
        assert!(capped_evs.len() <= evs.len());
    }

    #[test]
    fn speculative_route_reports_draft_stats() {
        use crate::kernels::LinearOp;
        use crate::model::CompressedWeights;
        use crate::quant::slim_quant;
        use crate::server::scheduler::SchedPolicy;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = Arc::new(init(&cfg, &mut rng));
        let mut cw = CompressedWeights::new();
        for (name, _d_in, _d_out) in cfg.linear_layers() {
            let q = slim_quant::quantize(w.expect(&name), 4);
            cw.insert(&name, LinearOp::int4(&q, None));
        }
        let target = Engine::new("sim-125m", cfg.clone(), w.clone(), None);
        let draft = Engine::with_kernels("sim-125m-draft", cfg, w, Arc::new(cw));
        let mut router = Router::new();
        let policy = SchedPolicy { max_slots: 2, draft_k: 3, ..Default::default() };
        router.register_speculative(target, draft, policy);
        let r = Arc::new(router);

        // models advertises the route as speculative with its draft depth.
        let models = handle_line(&r, r#"{"cmd":"models"}"#).to_string_compact();
        assert!(models.contains("\"spec\":true"), "{models}");
        assert!(models.contains("\"draft_k\":3"), "{models}");

        let resp = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":6}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 6);
        let drafted = resp.get("drafted").and_then(Json::as_f64).unwrap();
        let accepted = resp.get("accepted").and_then(Json::as_f64).unwrap();
        let rate = resp.get("accept_rate").and_then(Json::as_f64).unwrap();
        assert!(accepted <= drafted);
        assert!((0.0..=1.0).contains(&rate));
        // The route-wide summary line carries the aggregate acceptance.
        let m = handle_line(&r, r#"{"cmd":"metrics"}"#);
        assert!(m.get("summary").and_then(Json::as_str).unwrap().contains("spec_accept"));
    }

    #[test]
    fn priority_client_id_accepted_and_ttft_reported() {
        // A fair-share continuous route accepts the admission fields and
        // reports the server-measured TTFT; tokens are unchanged by the
        // metadata (same greedy path).
        use crate::server::batcher::AdmitPolicy;
        use crate::server::scheduler::SchedPolicy;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let mut router = Router::new();
        router.register_continuous(
            Engine::new("sim-125m", cfg, Arc::new(w), None),
            SchedPolicy { max_slots: 2, admit: AdmitPolicy::FairShare, ..Default::default() },
        );
        let r = Arc::new(router);
        let line =
            r#"{"model":"sim-125m","prompt":[5,6],"max_new":3,"priority":2,"client_id":9}"#;
        let resp = handle_line(&r, line);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);
        assert!(resp.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        let plain = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":3}"#);
        assert_eq!(
            plain.get("tokens").and_then(Json::as_arr),
            resp.get("tokens").and_then(Json::as_arr),
            "admission metadata must not change tokens"
        );
    }

    #[test]
    fn tcp_round_trip() {
        let r = router();
        let (tx, rx) = std::sync::mpsc::channel();
        let r2 = r.clone();
        std::thread::spawn(move || {
            let _ = serve(r2, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
        let addr = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let mut client = Client::connect(addr).unwrap();
        let tokens = client.generate("sim-125m", &[9, 10, 11], 4).unwrap();
        assert_eq!(tokens.len(), 4);
    }
}
