//! TCP front-end: newline-delimited JSON over std::net.
//!
//! The full wire grammar — request/response shapes, the v1/v2 envelope
//! rules, streaming frames, session commands, error codes, and example
//! transcripts — is documented in `docs/PROTOCOL.md`. Parsing lives in
//! [`super::proto`]; this module binds parsed requests to a [`Router`]
//! and shapes responses.
//!
//! In brief: one JSON object per line in, one or more JSON frames per
//! line out. Non-streaming commands answer with exactly one frame.
//! A generate or session_append with `"stream": true` answers with one
//! `{"event":"token","index":i,"token":t}` frame per generated token
//! followed by a terminal `{"event":"done","ok":true,...}` frame carrying
//! the complete result. Errors are flat `{"ok":false,"error":"..."}` for
//! v1 requests and structured `{"ok":false,"v":2,"error":{"code",
//! "message"}}` for `"v":2` requests.
//!
//! One thread per connection (the engines are the bottleneck, not the
//! accept loop), with the router's batcher coalescing across connections.

use super::engine::{GenResult, StreamEvent};
use super::proto::{self, codes, Append, Envelope, Generate, ProtoError, Request};
use super::router::{RequestOpts, Router};
use super::session::SessionError;
use crate::model::KvDtype;
use crate::util::json::{n, obj, s, Json};
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// How long one request may generate before the api abandons it.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// Serve until the listener errors. Binds to `addr` ("127.0.0.1:0" picks a
/// free port); returns the bound address via callback before blocking.
pub fn serve(
    router: Arc<Router>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(router, stream);
        });
    }
    Ok(())
}

fn handle_conn(router: Arc<Router>, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let mut io_err = None;
        handle_request(&router, line.trim(), &mut |frame| {
            if io_err.is_some() {
                return;
            }
            let res = writer
                .write_all(frame.to_string_compact().as_bytes())
                .and_then(|_| writer.write_all(b"\n"))
                .and_then(|_| writer.flush());
            if let Err(e) = res {
                io_err = Some(e);
            }
        });
        if let Some(e) = io_err {
            return Err(e.into());
        }
    }
}

/// Process one request line, delivering each response frame through
/// `sink`. Non-streaming requests produce exactly one frame; streaming
/// requests produce token frames then a terminal done (or error) frame.
pub fn handle_request(router: &Router, line: &str, sink: &mut dyn FnMut(Json)) {
    let Envelope { v, req } = match proto::parse(line) {
        Ok(env) => env,
        Err((v, err)) => {
            sink(proto::error_json(v, &err));
            return;
        }
    };
    if let Err(err) = dispatch(router, v, req, sink) {
        sink(proto::error_json(v, &err));
    }
}

/// Process one request line and collect every response frame (exposed
/// for tests and tools that don't want the sink callback shape).
pub fn handle_frames(router: &Router, line: &str) -> Vec<Json> {
    let mut frames = Vec::new();
    handle_request(router, line, &mut |f| frames.push(f));
    frames
}

/// Process one request line, returning the FINAL response frame — the
/// whole response for non-streaming commands, the terminal `done` /
/// error frame for streaming ones (exposed for tests).
pub fn handle_line(router: &Router, line: &str) -> Json {
    handle_frames(router, line).pop().expect("every request produces at least one frame")
}

fn dispatch(
    router: &Router,
    v: u64,
    req: Request,
    sink: &mut dyn FnMut(Json),
) -> Result<(), ProtoError> {
    match req {
        Request::Generate(g) => generate(router, v, g, sink),
        Request::SessionAppend(a) => session_append(router, v, a, sink),
        Request::SessionOpen { model } => {
            require_model(router, &model)?;
            let sid = router.session_open(&model).map_err(session_err)?;
            sink(ok_obj(v, vec![("session", n(sid as f64))]));
            Ok(())
        }
        Request::SessionDrop { model, session } => {
            require_model(router, &model)?;
            router.session_drop(&model, session).map_err(session_err)?;
            sink(ok_obj(v, vec![("dropped", n(session as f64))]));
            Ok(())
        }
        Request::Metrics => {
            sink(ok_obj(
                v,
                vec![
                    ("summary", s(&router.registry.summary())),
                    ("routes", router.registry.to_json()),
                ],
            ));
            Ok(())
        }
        Request::MetricsProm => {
            sink(ok_obj(v, vec![("text", s(&router.registry.prometheus()))]));
            Ok(())
        }
        Request::Trace { last } => {
            sink(ok_obj(v, vec![("trace", router.recorder.trace_json(last))]));
            Ok(())
        }
        Request::Models => {
            let models = router
                .route_infos()
                .iter()
                .map(|info| {
                    let mut fields = vec![
                        ("name", s(&info.name)),
                        ("kv_dtype", s(info.kv_dtype.name())),
                        ("mode", s(info.mode)),
                        ("admit", s(info.admit)),
                        ("spec", Json::Bool(info.draft_k.is_some())),
                        ("sessions", n(info.max_sessions as f64)),
                        ("streaming", Json::Bool(info.streaming)),
                        ("page_size", n(info.page_size as f64)),
                        ("prefix_cache", Json::Bool(info.prefix_cache)),
                    ];
                    if let Some(k) = info.draft_k {
                        fields.push(("draft_k", n(k as f64)));
                    }
                    obj(fields)
                })
                .collect();
            sink(ok_obj(v, vec![("models", Json::Arr(models))]));
            Ok(())
        }
    }
}

fn generate(
    router: &Router,
    v: u64,
    g: Generate,
    sink: &mut dyn FnMut(Json),
) -> Result<(), ProtoError> {
    require_model(router, &g.model)?;
    // Optional KV-dtype assertion: an unknown name errors with the valid
    // list; a known name must match what the route was registered with.
    if let Some(want) = &g.kv_dtype {
        let want = KvDtype::parse(want).map_err(|e| ProtoError::new(codes::BAD_DTYPE, e))?;
        let have = router
            .model_infos()
            .into_iter()
            .find(|&(name, _)| name == g.model)
            .map(|(_, dt)| dt)
            .expect("model checked above");
        if want != have {
            let msg = format!(
                "model {} serves kv_dtype {}, not {}",
                g.model,
                have.name(),
                want.name()
            );
            return Err(ProtoError::new(codes::BAD_DTYPE, msg));
        }
    }
    let opts = RequestOpts {
        max_new: g.max_new,
        stop: g.stop,
        priority: g.priority,
        client_id: g.client_id,
        sample: g.sample,
    };
    if g.stream {
        let rx = router
            .submit_stream_with(&g.model, g.prompt, opts)
            .map_err(|e| ProtoError::bad_request(e.to_string()))?;
        pump_stream(rx, v, None, sink)
    } else {
        let rx = router
            .submit_with(&g.model, g.prompt, opts)
            .map_err(|e| ProtoError::bad_request(e.to_string()))?;
        let result = rx
            .recv_timeout(REQUEST_TIMEOUT)
            .map_err(|_| ProtoError::new(codes::INTERNAL, "generation timed out"))?;
        sink(obj(result_fields(v, &result, None)));
        Ok(())
    }
}

fn session_append(
    router: &Router,
    v: u64,
    a: Append,
    sink: &mut dyn FnMut(Json),
) -> Result<(), ProtoError> {
    require_model(router, &a.model)?;
    let opts = RequestOpts {
        max_new: a.max_new,
        stop: a.stop,
        priority: a.priority,
        client_id: a.client_id,
        sample: a.sample,
    };
    let rx = router
        .session_append_stream(&a.model, a.session, a.tokens, opts)
        .map_err(session_err)?;
    if a.stream {
        pump_stream(rx, v, Some(a.session), sink)
    } else {
        // Same submission path as streamed turns; only delivery differs.
        loop {
            match rx.recv_timeout(REQUEST_TIMEOUT) {
                Ok(StreamEvent::Token { .. }) => continue,
                Ok(StreamEvent::Done(result)) => {
                    sink(obj(result_fields(v, &result, Some(a.session))));
                    return Ok(());
                }
                Err(_) => {
                    return Err(ProtoError::new(codes::INTERNAL, "generation timed out"))
                }
            }
        }
    }
}

/// Relay a stream: one `token` frame per generated token, then the
/// terminal `done` frame with the full result.
fn pump_stream(
    rx: Receiver<StreamEvent>,
    v: u64,
    session: Option<u64>,
    sink: &mut dyn FnMut(Json),
) -> Result<(), ProtoError> {
    loop {
        match rx.recv_timeout(REQUEST_TIMEOUT) {
            Ok(StreamEvent::Token { index, token }) => {
                sink(obj(vec![
                    ("event", s("token")),
                    ("index", n(index as f64)),
                    ("token", n(token as f64)),
                ]));
            }
            Ok(StreamEvent::Done(result)) => {
                let mut fields = vec![("event", s("done"))];
                fields.extend(result_fields(v, &result, session));
                sink(obj(fields));
                return Ok(());
            }
            Err(_) => return Err(ProtoError::new(codes::INTERNAL, "generation timed out")),
        }
    }
}

/// The success-response fields for one finished generation.
fn result_fields(
    v: u64,
    result: &GenResult,
    session: Option<u64>,
) -> Vec<(&'static str, Json)> {
    let mut fields = vec![("ok", Json::Bool(true))];
    if v >= 2 {
        fields.push(("v", n(2.0)));
    }
    if let Some(sid) = session {
        fields.push(("session", n(sid as f64)));
    }
    fields.push(("tokens", Json::Arr(result.tokens.iter().map(|&t| n(t as f64)).collect())));
    if let Some(ttft) = result.ttft_s {
        fields.push(("ttft_ms", n(ttft * 1e3)));
    }
    if let Some((drafted, accepted)) = result.spec {
        fields.push(("drafted", n(drafted as f64)));
        fields.push(("accepted", n(accepted as f64)));
        let rate = if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
        fields.push(("accept_rate", n(rate)));
    }
    fields
}

/// A single-frame success response: `{"ok":true, ...}` plus the version
/// stamp on v2.
fn ok_obj(v: u64, mut fields: Vec<(&'static str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    if v >= 2 {
        all.push(("v", n(2.0)));
    }
    all.append(&mut fields);
    obj(all)
}

fn require_model(router: &Router, model: &str) -> Result<(), ProtoError> {
    if router.models().iter().any(|m| *m == model) {
        Ok(())
    } else {
        Err(ProtoError::new(codes::UNKNOWN_MODEL, format!("unknown model {model}")))
    }
}

/// Session failures keep their typed identity on the wire.
fn session_err(e: SessionError) -> ProtoError {
    let code = match &e {
        SessionError::Disabled => codes::SESSIONS_DISABLED,
        SessionError::Unknown(_) => codes::UNKNOWN_SESSION,
        SessionError::Busy(_) => codes::SESSION_BUSY,
        SessionError::TableFull(_) => codes::SESSION_LIMIT,
        SessionError::Invalid(_) => codes::BAD_REQUEST,
    };
    ProtoError::new(code, e.to_string())
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one JSON request line without waiting for the response.
    pub fn send(&mut self, req: &Json) -> Result<()> {
        self.writer.write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read one response frame (streaming responses deliver several per
    /// request — read until a frame with `"event":"done"` or `"ok":false`).
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("connection closed"));
        }
        Json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Send one JSON request, get one JSON response.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        self.recv()
    }

    /// Convenience generate call.
    pub fn generate(&mut self, model: &str, prompt: &[u32], max_new: usize) -> Result<Vec<u32>> {
        let req = obj(vec![
            ("model", s(model)),
            ("prompt", Json::Arr(prompt.iter().map(|&t| n(t as f64)).collect())),
            ("max_new", n(max_new as f64)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("?")
            ));
        }
        Ok(resp
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_usize().map(|u| u as u32))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{by_name, init};
    use crate::rng::Pcg32;
    use crate::server::scheduler::SchedPolicy;
    use crate::server::{BatchPolicy, Engine};

    fn engine() -> Engine {
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        Engine::new("sim-125m", cfg, Arc::new(w), None)
    }

    fn router() -> Arc<Router> {
        let mut r = Router::new();
        r.register(engine(), BatchPolicy::default());
        Arc::new(r)
    }

    fn session_router() -> Arc<Router> {
        let mut r = Router::new();
        let policy = SchedPolicy { max_slots: 2, max_sessions: 2, ..Default::default() };
        r.register_continuous(engine(), policy);
        Arc::new(r)
    }

    fn toks(resp: &Json) -> Vec<usize> {
        resp.get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_usize)
            .collect()
    }

    #[test]
    fn handle_line_generate() {
        let r = router();
        let resp = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":3}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);
        // The legacy v1 success carries no version stamp; v2 does.
        assert!(resp.get("v").is_none());
        let resp =
            handle_line(&r, r#"{"v":2,"model":"sim-125m","prompt":[5,6],"max_new":3}"#);
        assert_eq!(resp.get("v").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn handle_line_errors() {
        let r = router();
        // v1 errors keep the legacy flat string shape.
        let resp = handle_line(&r, "not json");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert!(resp.get("error").and_then(Json::as_str).is_some());
        let resp = handle_line(&r, r#"{"model":"nope","prompt":[1]}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        // v2 errors are structured with a stable machine-readable code.
        let resp = handle_line(&r, r#"{"v":2,"model":"nope","prompt":[1]}"#);
        let err = resp.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::UNKNOWN_MODEL));
        assert!(err.get("message").and_then(Json::as_str).unwrap().contains("nope"));
        let resp = handle_line(&r, r#"{"v":2,"model":"sim-125m","prompt":[1],"oops":3}"#);
        let err = resp.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::BAD_REQUEST));
        let resp = handle_line(&r, r#"{"v":2,"cmd":"nope"}"#);
        let err = resp.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::UNKNOWN_CMD));
    }

    /// The optional `kv_dtype` request field: a matching name passes, an
    /// unknown name errors listing every valid dtype, and a valid-but-
    /// mismatched name errors naming the route's actual dtype.
    #[test]
    fn kv_dtype_field_validated_against_route() {
        let r = router(); // registered with the default f32 KV store
        let ok = handle_line(
            &r,
            r#"{"model":"sim-125m","prompt":[5,6],"max_new":2,"kv_dtype":"f32"}"#,
        );
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        let bad = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"kv_dtype":"float8"}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let msg = bad.get("error").and_then(Json::as_str).unwrap();
        assert!(
            msg.contains(crate::model::attention::KV_DTYPE_NAMES),
            "error must list valid dtypes: {msg}"
        );
        let mismatch =
            handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"kv_dtype":"bf16"}"#);
        assert_eq!(mismatch.get("ok").and_then(Json::as_bool), Some(false));
        let msg = mismatch.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("serves kv_dtype f32"), "{msg}");
        // v2 carries the same failures under the bad_dtype code.
        let resp = handle_line(
            &r,
            r#"{"v":2,"model":"sim-125m","prompt":[5,6],"kv_dtype":"bf16"}"#,
        );
        let err = resp.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::BAD_DTYPE));
    }

    #[test]
    fn stop_field_retires_generation_early() {
        let r = router();
        let free = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":5}"#);
        let free_toks = toks(&free);
        let stop = free_toks[1];
        let resp = handle_line(
            &r,
            &format!(r#"{{"model":"sim-125m","prompt":[5,6],"max_new":5,"stop":{stop}}}"#),
        );
        let got = toks(&resp);
        let cut = free_toks.iter().position(|&t| t == stop).unwrap() + 1;
        assert_eq!(got, free_toks[..cut].to_vec());
    }

    /// A `"stream":true` generate yields one token frame per generated
    /// token then a done frame whose tokens equal the concatenation —
    /// and equal the non-streamed response for the same request.
    #[test]
    fn streamed_generate_frames_concatenate_to_plain_response() {
        for r in [router(), session_router()] {
            let plain = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":4}"#);
            let frames = handle_frames(
                &r,
                r#"{"v":2,"model":"sim-125m","prompt":[5,6],"max_new":4,"stream":true}"#,
            );
            assert_eq!(frames.len(), 5, "4 token frames + done");
            let mut streamed = Vec::new();
            for (i, f) in frames[..4].iter().enumerate() {
                assert_eq!(f.get("event").and_then(Json::as_str), Some("token"));
                assert_eq!(f.get("index").and_then(Json::as_usize), Some(i));
                streamed.push(f.get("token").and_then(Json::as_usize).unwrap());
            }
            let done = &frames[4];
            assert_eq!(done.get("event").and_then(Json::as_str), Some("done"));
            assert_eq!(done.get("ok").and_then(Json::as_bool), Some(true));
            assert_eq!(done.get("v").and_then(Json::as_f64), Some(2.0));
            assert_eq!(toks(done), streamed);
            assert_eq!(toks(done), toks(&plain));
        }
    }

    /// One v2 `session_append` request line for the test route: `rest` is
    /// the trailing fields after the session id (starting with a comma).
    fn append_line(sid: usize, rest: &str) -> String {
        let head = r#""v":2,"cmd":"session_append","model":"sim-125m""#;
        format!(r#"{{{head},"session":{sid}{rest}}}"#)
    }

    /// The full session lifecycle over the wire: open, two appended turns
    /// (one streamed), drop, and typed errors afterwards.
    #[test]
    fn session_commands_over_the_wire() {
        let r = session_router();
        let opened = handle_line(&r, r#"{"v":2,"cmd":"session_open","model":"sim-125m"}"#);
        assert_eq!(opened.get("ok").and_then(Json::as_bool), Some(true));
        let sid = opened.get("session").and_then(Json::as_usize).expect("session id");
        let t1 = handle_line(&r, &append_line(sid, r#","tokens":[5,6],"max_new":3"#));
        assert_eq!(t1.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(t1.get("session").and_then(Json::as_usize), Some(sid));
        let t1_toks = toks(&t1);
        assert_eq!(t1_toks.len(), 3);
        // Turn 2 streams; its done frame carries the session id too.
        let turn2 = append_line(sid, r#","tokens":[9],"max_new":3,"stream":true"#);
        let frames = handle_frames(&r, &turn2);
        let done = frames.last().unwrap();
        assert_eq!(done.get("event").and_then(Json::as_str), Some("done"));
        assert_eq!(done.get("session").and_then(Json::as_usize), Some(sid));
        // Reference: fresh request over the full conversation so far.
        let mid: Vec<u32> = t1_toks.iter().map(|&t| t as u32).collect();
        let full = [vec![5u32, 6], mid, vec![9u32]].concat();
        let solo = r.generate("sim-125m", full, 3).unwrap();
        let got: Vec<u32> = toks(done).iter().map(|&t| t as u32).collect();
        assert_eq!(got, solo.tokens);
        let dropped = handle_line(
            &r,
            &format!(r#"{{"v":2,"cmd":"session_drop","model":"sim-125m","session":{sid}}}"#),
        );
        assert_eq!(dropped.get("dropped").and_then(Json::as_usize), Some(sid));
        let gone = handle_line(&r, &append_line(sid, r#","tokens":[4]"#));
        let err = gone.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::UNKNOWN_SESSION));
        // Session commands on a session-less route fail typed too.
        let plain = router();
        let resp = handle_line(&plain, r#"{"v":2,"cmd":"session_open","model":"sim-125m"}"#);
        let err = resp.get("error").expect("structured error");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(codes::SESSIONS_DISABLED));
    }

    #[test]
    fn metrics_and_models_cmds() {
        let r = session_router();
        let resp = handle_line(&r, r#"{"cmd":"models"}"#);
        let text = resp.to_string_compact();
        assert!(text.contains("sim-125m"));
        // Each model entry reports its serving KV cache dtype, mode,
        // admission policy, session capacity, and streaming support.
        assert!(text.contains("kv_dtype"), "missing kv_dtype in {text}");
        assert!(text.contains("f32"));
        assert!(text.contains("\"spec\":false"), "missing spec flag in {text}");
        assert!(text.contains("\"mode\":\"continuous\""), "missing mode in {text}");
        assert!(text.contains("\"admit\":\"fifo\""), "missing admit in {text}");
        assert!(text.contains("\"sessions\":2"), "missing sessions in {text}");
        assert!(text.contains("\"streaming\":true"), "missing streaming in {text}");
        // Paged-KV capabilities: page granularity plus prefix sharing
        // (on for continuous routes).
        assert!(text.contains("\"page_size\":16"), "missing page_size in {text}");
        assert!(text.contains("\"prefix_cache\":true"), "missing prefix_cache in {text}");
        // `metrics` keeps the legacy one-line aggregate under `summary`
        // and adds the per-route structured export under `routes`.
        let _ = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":2}"#);
        let resp = handle_line(&r, r#"{"cmd":"metrics"}"#);
        assert!(resp.get("summary").and_then(Json::as_str).unwrap().contains("requests="));
        let route = resp.get("routes").and_then(|rt| rt.get("sim-125m")).expect("route json");
        assert!(route.get("requests").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(route
            .get("request_latency_seconds")
            .and_then(|h| h.get("p95"))
            .and_then(Json::as_f64)
            .is_some());
        // `metrics_prom` returns Prometheus text exposition.
        let prom = handle_line(&r, r#"{"cmd":"metrics_prom"}"#);
        let text = prom.get("text").and_then(Json::as_str).unwrap();
        assert!(text.contains("# TYPE slim_requests_total counter"), "{text}");
        assert!(text.contains("slim_requests_total{route=\"sim-125m\"}"), "{text}");
        // `trace` dumps the flight recorder as Chrome trace-event JSON,
        // honoring the optional `last` cap.
        let trace = handle_line(&r, r#"{"cmd":"trace"}"#);
        let evs = trace
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .expect("traceEvents");
        assert!(!evs.is_empty());
        let capped = handle_line(&r, r#"{"cmd":"trace","last":1}"#);
        let capped_evs = capped
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .expect("traceEvents");
        assert!(capped_evs.len() <= evs.len());
    }

    #[test]
    fn speculative_route_reports_draft_stats() {
        use crate::kernels::LinearOp;
        use crate::model::CompressedWeights;
        use crate::quant::slim_quant;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = Arc::new(init(&cfg, &mut rng));
        let mut cw = CompressedWeights::new();
        for (name, _d_in, _d_out) in cfg.linear_layers() {
            let q = slim_quant::quantize(w.expect(&name), 4);
            cw.insert(&name, LinearOp::int4(&q, None));
        }
        let target = Engine::new("sim-125m", cfg.clone(), w.clone(), None);
        let draft = Engine::with_kernels("sim-125m-draft", cfg, w, Arc::new(cw));
        let mut router = Router::new();
        let policy = SchedPolicy { max_slots: 2, draft_k: 3, ..Default::default() };
        router.register_speculative(target, draft, policy);
        let r = Arc::new(router);

        // models advertises the route as speculative with its draft depth.
        let models = handle_line(&r, r#"{"cmd":"models"}"#).to_string_compact();
        assert!(models.contains("\"spec\":true"), "{models}");
        assert!(models.contains("\"draft_k\":3"), "{models}");
        assert!(models.contains("\"mode\":\"speculative\""), "{models}");
        // Speculative routes run twin pools in lockstep — no prefix
        // sharing there.
        assert!(models.contains("\"prefix_cache\":false"), "{models}");

        let resp = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":6}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 6);
        let drafted = resp.get("drafted").and_then(Json::as_f64).unwrap();
        let accepted = resp.get("accepted").and_then(Json::as_f64).unwrap();
        let rate = resp.get("accept_rate").and_then(Json::as_f64).unwrap();
        assert!(accepted <= drafted);
        assert!((0.0..=1.0).contains(&rate));
        // The route-wide summary line carries the aggregate acceptance.
        let m = handle_line(&r, r#"{"cmd":"metrics"}"#);
        assert!(m.get("summary").and_then(Json::as_str).unwrap().contains("spec_accept"));
    }

    #[test]
    fn priority_client_id_accepted_and_ttft_reported() {
        // A fair-share continuous route accepts the admission fields and
        // reports the server-measured TTFT; tokens are unchanged by the
        // metadata (same greedy path).
        use crate::server::batcher::AdmitPolicy;
        let cfg = by_name("sim-125m").unwrap();
        let mut rng = Pcg32::seeded(1);
        let w = init(&cfg, &mut rng);
        let mut router = Router::new();
        router.register_continuous(
            Engine::new("sim-125m", cfg, Arc::new(w), None),
            SchedPolicy { max_slots: 2, admit: AdmitPolicy::FairShare, ..Default::default() },
        );
        let r = Arc::new(router);
        let line =
            r#"{"model":"sim-125m","prompt":[5,6],"max_new":3,"priority":2,"client_id":9}"#;
        let resp = handle_line(&r, line);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);
        assert!(resp.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        let plain = handle_line(&r, r#"{"model":"sim-125m","prompt":[5,6],"max_new":3}"#);
        assert_eq!(
            plain.get("tokens").and_then(Json::as_arr),
            resp.get("tokens").and_then(Json::as_arr),
            "admission metadata must not change tokens"
        );
    }

    /// Sampling knobs flow through the wire: same seed reproduces, and a
    /// temperature-sampled response differs from greedy for some seed.
    #[test]
    fn sampling_fields_flow_through_the_wire() {
        let r = router();
        let base = r#"{"model":"sim-125m","prompt":[5,6],"max_new":6"#;
        let line = format!(r#"{base},"temperature":0.9,"top_k":12,"top_p":0.95,"seed":7}}"#);
        let a = handle_line(&r, &line);
        let b = handle_line(&r, &line);
        assert_eq!(toks(&a), toks(&b), "same seed must reproduce over the wire");
        // Out-of-range knobs are rejected at the protocol boundary.
        let bad = handle_line(&r, r#"{"model":"sim-125m","prompt":[5],"top_p":1.5}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn tcp_round_trip() {
        let r = router();
        let (tx, rx) = std::sync::mpsc::channel();
        let r2 = r.clone();
        std::thread::spawn(move || {
            let _ = serve(r2, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
        let addr = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let mut client = Client::connect(addr).unwrap();
        let tokens = client.generate("sim-125m", &[9, 10, 11], 4).unwrap();
        assert_eq!(tokens.len(), 4);
    }

    /// Streaming over a real TCP connection: frames arrive one per line,
    /// terminated by the done frame.
    #[test]
    fn tcp_streaming_round_trip() {
        let r = session_router();
        let (tx, rx) = std::sync::mpsc::channel();
        let r2 = r.clone();
        std::thread::spawn(move || {
            let _ = serve(r2, "127.0.0.1:0", move |addr| {
                let _ = tx.send(addr);
            });
        });
        let addr = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let mut client = Client::connect(addr).unwrap();
        let req = obj(vec![
            ("v", n(2.0)),
            ("model", s("sim-125m")),
            ("prompt", Json::Arr(vec![n(9.0), n(10.0)])),
            ("max_new", n(4.0)),
            ("stream", Json::Bool(true)),
        ]);
        client.send(&req).unwrap();
        let mut streamed = Vec::new();
        let done = loop {
            let frame = client.recv().unwrap();
            match frame.get("event").and_then(Json::as_str) {
                Some("token") => {
                    assert_eq!(
                        frame.get("index").and_then(Json::as_usize),
                        Some(streamed.len())
                    );
                    streamed.push(frame.get("token").and_then(Json::as_usize).unwrap());
                }
                Some("done") => break frame,
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(done.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(toks(&done), streamed);
        assert_eq!(streamed.len(), 4);
    }
}
