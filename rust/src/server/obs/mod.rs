//! Observability subsystem: per-route metrics registry + request-lifecycle
//! flight recorder.
//!
//! Two halves, split by cost budget:
//!
//! * **[`Registry`]** — named routes, each owning a lock-free [`Metrics`]
//!   instance (`server::metrics`) built on the log-bucketed [`Histogram`]
//!   from [`hist`]. The registry's own lock is only taken on
//!   register/export, never on the sample record path. Exports: structured
//!   JSON (route → metric → `{count, sum, p50, p95, p99}`), Prometheus
//!   text exposition, and the legacy one-line summary aggregated across
//!   routes.
//! * **[`FlightRecorder`]** — a shared fixed-capacity ring of structured
//!   lifecycle events ([`recorder`]) exported as Chrome trace-event JSON
//!   for Perfetto. One recorder serves all routes (events carry an
//!   interned route id) so a trace shows cross-route interleaving.
//!
//! [`RouteObs`] bundles one route's metrics handle with the shared
//! recorder — it is what the scheduler and workers take, so call sites
//! never juggle the two halves separately.

pub mod hist;
pub mod recorder;

pub use hist::{AtomicF64, Histogram, SampleRing};
pub use recorder::{Event, EventKind, FlightRecorder, DEFAULT_CAPACITY};

use super::metrics::Metrics;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

/// Named per-route [`Metrics`] instances plus cross-route aggregation and
/// export. Route lookup/creation locks briefly; recording against a route
/// handle never touches the registry again.
pub struct Registry {
    routes: Mutex<Vec<(String, Arc<Metrics>)>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { routes: Mutex::new(Vec::new()) }
    }

    /// Get or create the metrics for `name`.
    pub fn route(&self, name: &str) -> Arc<Metrics> {
        let mut routes = self.routes.lock().unwrap();
        if let Some((_, m)) = routes.iter().find(|(r, _)| r == name) {
            return Arc::clone(m);
        }
        let m = Arc::new(Metrics::new());
        routes.push((name.to_string(), Arc::clone(&m)));
        m
    }

    /// The metrics for `name`, if the route exists.
    pub fn get(&self, name: &str) -> Option<Arc<Metrics>> {
        let routes = self.routes.lock().unwrap();
        routes.iter().find(|(r, _)| r == name).map(|(_, m)| Arc::clone(m))
    }

    /// Registered `(route, metrics)` pairs in registration order.
    pub fn routes(&self) -> Vec<(String, Arc<Metrics>)> {
        self.routes.lock().unwrap().clone()
    }

    /// A fresh [`Metrics`] holding every route's samples folded together.
    pub fn aggregate(&self) -> Metrics {
        let agg = Metrics::new();
        for (_, m) in self.routes() {
            agg.absorb(&m);
        }
        agg
    }

    /// Legacy one-line summary over the cross-route aggregate (same format
    /// the old single global `Metrics` printed).
    pub fn summary(&self) -> String {
        self.aggregate().summary()
    }

    /// Structured export: route name → that route's
    /// [`Metrics::export_json`] object.
    pub fn to_json(&self) -> Json {
        let map: BTreeMap<String, Json> =
            self.routes().into_iter().map(|(name, m)| (name, m.export_json())).collect();
        Json::Obj(map)
    }

    /// Prometheus text exposition. Families are emitted once each with
    /// routes as label values; histograms use summary-style quantile
    /// series (`{quantile="0.5|0.95|0.99"}` + `_sum` + `_count`) rather
    /// than 482 `le` buckets.
    pub fn prometheus(&self) -> String {
        let routes = self.routes();
        let mut out = String::new();
        let counters: [(&str, fn(&Metrics) -> f64); 9] = [
            ("slim_requests_total", |m| m.requests() as f64),
            ("slim_batches_total", |m| m.batches() as f64),
            ("slim_tokens_total", |m| m.tokens() as f64),
            ("slim_spec_drafted_total", |m| m.spec_drafted() as f64),
            ("slim_spec_accepted_total", |m| m.spec_accepted() as f64),
            ("slim_prefix_cache_hits_total", |m| m.kv_pages().prefix_hits as f64),
            ("slim_prefix_cache_misses_total", |m| m.kv_pages().prefix_misses as f64),
            ("slim_prefix_cache_evictions_total", |m| m.kv_pages().prefix_evictions as f64),
            ("slim_prefix_cache_saved_tokens_total", |m| m.kv_pages().prefix_saved_tokens as f64),
        ];
        for (name, get) in counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            for (route, m) in &routes {
                let _ = writeln!(out, "{name}{{route=\"{route}\"}} {}", get(m));
            }
        }
        let gauges: [(&str, fn(&Metrics) -> f64); 5] = [
            ("slim_queue_depth", |m| m.queue_depth() as f64),
            ("slim_queue_depth_max", |m| m.max_queue_depth() as f64),
            ("slim_kv_pages_total", |m| m.kv_pages().pages_total as f64),
            ("slim_kv_pages_used", |m| m.kv_pages().pages_used as f64),
            ("slim_kv_pages_shared", |m| m.kv_pages().pages_shared as f64),
        ];
        for (name, get) in gauges {
            let _ = writeln!(out, "# TYPE {name} gauge");
            for (route, m) in &routes {
                let _ = writeln!(out, "{name}{{route=\"{route}\"}} {}", get(m));
            }
        }
        let _ = writeln!(out, "# TYPE slim_busy_seconds_total counter");
        for (route, m) in &routes {
            let _ =
                writeln!(out, "slim_busy_seconds_total{{route=\"{route}\"}} {}", m.busy_seconds());
        }
        let _ = writeln!(out, "# TYPE slim_stage_busy_seconds_total counter");
        for (route, m) in &routes {
            for stage in super::metrics::Stage::ALL {
                let _ = writeln!(
                    out,
                    "slim_stage_busy_seconds_total{{route=\"{route}\",stage=\"{}\"}} {}",
                    stage.name(),
                    m.stage_busy_s(stage)
                );
            }
        }
        // Histogram families, as Prometheus summaries. The family list is
        // identical for every route, so take it from the first.
        let n_families = routes.first().map(|(_, m)| m.histograms().len()).unwrap_or(0);
        for fam in 0..n_families {
            let fam_name = routes[0].1.histograms()[fam].0;
            let _ = writeln!(out, "# TYPE slim_{fam_name} summary");
            for (route, m) in &routes {
                let (_, h) = m.histograms()[fam];
                for (q, pct) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                    let _ = writeln!(
                        out,
                        "slim_{fam_name}{{route=\"{route}\",quantile=\"{q}\"}} {}",
                        h.percentile(pct)
                    );
                }
                let _ = writeln!(out, "slim_{fam_name}_sum{{route=\"{route}\"}} {}", h.sum());
                let _ = writeln!(out, "slim_{fam_name}_count{{route=\"{route}\"}} {}", h.count());
            }
        }
        out
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// One route's observability bundle: its [`Metrics`] handle, the shared
/// [`FlightRecorder`], and the route's interned id for events. This is
/// what scheduler/worker loops take.
#[derive(Clone)]
pub struct RouteObs {
    pub metrics: Arc<Metrics>,
    pub recorder: Arc<FlightRecorder>,
    pub route: u16,
}

impl RouteObs {
    pub fn new(metrics: Arc<Metrics>, recorder: Arc<FlightRecorder>, route_name: &str) -> Self {
        let route = recorder.register_route(route_name);
        RouteObs { metrics, recorder, route }
    }

    /// Fresh metrics + recorder for one route — tests and benches that
    /// drive a scheduler without a router.
    pub fn standalone(route_name: &str) -> Self {
        Self::new(
            Arc::new(Metrics::new()),
            Arc::new(FlightRecorder::new(DEFAULT_CAPACITY)),
            route_name,
        )
    }

    /// Like [`RouteObs::standalone`] but with event recording compiled to
    /// a no-op sink (the overhead bench's "off" arm).
    pub fn standalone_disabled(route_name: &str) -> Self {
        Self::new(Arc::new(Metrics::new()), Arc::new(FlightRecorder::disabled()), route_name)
    }

    /// Record a point lifecycle event on this route.
    pub fn event(&self, kind: EventKind, req: u64, slot: u32, tokens: u32, a: u32, b: u32) {
        self.recorder.record_now(kind, self.route, req, slot, tokens, a, b);
    }

    /// Record a spanned lifecycle event on this route (`ts_us` from
    /// [`FlightRecorder::now_us`], `dur_us` the span length).
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        kind: EventKind,
        ts_us: u64,
        dur_us: u64,
        req: u64,
        slot: u32,
        tokens: u32,
        a: u32,
        b: u32,
    ) {
        self.recorder.record(Event {
            ts_us,
            dur_us,
            kind,
            route: self.route,
            req,
            slot,
            tokens,
            a,
            b,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_routes_are_distinct_and_stable() {
        let reg = Registry::new();
        let a = reg.route("alpha");
        let b = reg.route("beta");
        a.record_request(0.010);
        b.record_request(0.030);
        b.record_request(0.031);
        assert!(Arc::ptr_eq(&reg.route("alpha"), &a));
        assert_eq!(reg.get("alpha").unwrap().requests(), 1);
        assert_eq!(reg.get("beta").unwrap().requests(), 2);
        assert!(reg.get("missing").is_none());
        assert_eq!(reg.routes().len(), 2);
        // Aggregate folds both routes; summary keeps the legacy shape.
        assert_eq!(reg.aggregate().requests(), 3);
        assert!(reg.summary().contains("requests=3"));
    }

    #[test]
    fn registry_json_is_keyed_by_route() {
        let reg = Registry::new();
        reg.route("m").record_request(0.010);
        let j = reg.to_json();
        let m = j.get("m").expect("route key");
        assert_eq!(m.get("requests").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn prometheus_exposition_is_line_valid() {
        let reg = Registry::new();
        let m = reg.route("sim-125m");
        m.record_request(0.010);
        m.record_ttft(0.004);
        m.record_batch(2, 8, 0.020);
        let text = reg.prometheus();
        assert!(text.contains("# TYPE slim_requests_total counter"));
        assert!(text.contains("slim_requests_total{route=\"sim-125m\"} 1"));
        assert!(text.contains("# TYPE slim_kv_pages_used gauge"));
        assert!(text.contains("# TYPE slim_prefix_cache_hits_total counter"));
        assert!(text.contains("quantile=\"0.95\""));
        // Each TYPE family declared exactly once even with several routes.
        reg.route("other");
        let text = reg.prometheus();
        assert_eq!(text.matches("# TYPE slim_requests_total ").count(), 1);
        // Every non-comment line is `name{labels} value` with a float value.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (head, value) = line.rsplit_once(' ').expect("metric line");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(head.contains("{route="), "missing route label in {line:?}");
        }
    }

    #[test]
    fn route_obs_records_against_shared_recorder() {
        let recorder = Arc::new(FlightRecorder::new(64));
        let reg = Registry::new();
        let a = RouteObs::new(reg.route("a"), Arc::clone(&recorder), "a");
        let b = RouteObs::new(reg.route("b"), Arc::clone(&recorder), "b");
        a.event(EventKind::Enqueued, 1, 0, 5, 0, 0);
        b.event(EventKind::Enqueued, 2, 0, 7, 0, 0);
        let snap = recorder.snapshot(None);
        assert_eq!(snap.len(), 2);
        assert_ne!(snap[0].route, snap[1].route);
    }
}
