//! Lock-free metric primitives: log-bucketed histograms and a raw-sample
//! ring, both recordable from any thread with atomics only.
//!
//! [`Histogram`] is the percentile workhorse: a fixed array of
//! [`AtomicU64`] buckets on a geometric grid ([`BUCKETS_PER_OCTAVE`]
//! buckets per power of two, spanning [`HIST_MIN`]..[`HIST_MAX`]). The
//! record path is one bucket-index computation plus three relaxed atomic
//! adds — no `Mutex`, no allocation, no ordering stalls — so it can sit on
//! the scheduler's per-tick hot path. A percentile query walks the bucket
//! array once (O(buckets), independent of sample count) and returns the
//! geometric midpoint of the bucket holding the requested rank, which is
//! within one bucket's relative error (`2^(1/8) ≈ 9%`, typically half
//! that) of the exact sorted-reference percentile — property-tested in
//! `tests/property.rs` against bimodal, heavy-tail, and constant
//! distributions.
//!
//! [`SampleRing`] keeps the *exact* most-recent values where a distribution
//! summary is not enough (e.g. per-request speculative acceptance rates,
//! which are ratios in [0, 1] — far below the histogram grid's resolution
//! of interest). It is the fixed-capacity replacement for the old
//! `Mutex<Vec<f64>>` + `Vec::remove(0)` window: one atomic cursor
//! `fetch_add`, one indexed store, no memmove, no lock.

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic `f64` accumulator over its bit pattern (CAS add loop).
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        AtomicF64(AtomicU64::new(v.to_bits()))
    }

    /// Lock-free `+=` via compare-exchange on the bit pattern.
    pub fn add(&self, d: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Buckets per power of two: relative bucket width `2^(1/8) − 1 ≈ 9%`.
pub const BUCKETS_PER_OCTAVE: usize = 8;
/// Octaves spanned above [`HIST_MIN`].
const OCTAVES: usize = 60;
/// Values at or below this land in the underflow bucket (1 ns when the
/// unit is seconds — below every duration the serving stack can resolve).
pub const HIST_MIN: f64 = 1e-9;
/// Values above `HIST_MIN · 2^60 ≈ 1.15e9` land in the overflow bucket.
pub const HIST_MAX: f64 = HIST_MIN * (1u64 << OCTAVES) as f64;
/// Geometric buckets plus underflow (index 0) and overflow (last).
const SLOTS: usize = OCTAVES * BUCKETS_PER_OCTAVE + 2;

/// Lock-free log-bucketed histogram of non-negative samples.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicF64,
    buckets: Box<[AtomicU64]>,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicF64::new(0.0),
            buckets: (0..SLOTS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Bucket index for `v`: 0 is underflow (`v ≤ HIST_MIN`, NaN, or
    /// negative), `SLOTS-1` is overflow.
    fn bucket_index(v: f64) -> usize {
        if v.is_nan() || v <= HIST_MIN {
            return 0;
        }
        let octs = (v / HIST_MIN).log2() * BUCKETS_PER_OCTAVE as f64;
        (octs as usize + 1).min(SLOTS - 1)
    }

    /// Representative value reported for bucket `i`: the geometric midpoint
    /// of its `[lo, lo·2^(1/8))` span, so the estimate is within
    /// `2^(1/16) ≈ 4.4%` of any sample the bucket holds.
    fn representative(i: usize) -> f64 {
        if i == 0 {
            return 0.0;
        }
        if i >= SLOTS - 1 {
            return HIST_MAX;
        }
        HIST_MIN * 2f64.powf(((i - 1) as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64)
    }

    /// Record one sample: three relaxed atomic adds, nothing else.
    pub fn record(&self, v: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.add(if v.is_finite() && v > 0.0 { v } else { 0.0 });
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum.get()
    }

    /// Mean of all recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() / c as f64
        }
    }

    /// Percentile (0..100) estimate: one O(buckets) cumulative walk, same
    /// rank convention as a sorted array (`round(pct/100 · (n−1))`), so it
    /// lands in the same bucket as the exact reference sample. Returns 0
    /// when empty.
    pub fn percentile(&self, pct: f64) -> f64 {
        // Snapshot the buckets once so the rank target and the walk agree
        // even while other threads keep recording.
        let mut counts = [0u64; SLOTS];
        for (c, b) in counts.iter_mut().zip(self.buckets.iter()) {
            *c = b.load(Ordering::Relaxed);
        }
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((pct / 100.0) * (total - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::representative(i);
            }
        }
        Self::representative(SLOTS - 1)
    }

    /// Fold `other`'s samples into `self` (bucket layouts are identical by
    /// construction) — how the registry aggregates routes.
    pub fn absorb(&self, other: &Histogram) {
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.add(other.sum());
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Fixed-capacity lock-free ring of the most recent raw samples.
pub struct SampleRing {
    slots: Box<[AtomicU64]>,
    next: AtomicU64,
}

impl SampleRing {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "sample ring needs capacity >= 1");
        SampleRing {
            slots: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            next: AtomicU64::new(0),
        }
    }

    /// Record one sample, overwriting the oldest once full: a cursor
    /// `fetch_add` plus one indexed store — the O(1) replacement for the
    /// old `Vec::remove(0)` window, which memmoved 10k entries per push.
    pub fn push(&self, v: f64) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        self.slots[i].store(v.to_bits(), Ordering::Relaxed);
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        (self.next.load(Ordering::Relaxed) as usize).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy of the held samples (unordered — the window is a ring).
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len())
            .map(|i| f64::from_bits(self.slots[i].load(Ordering::Relaxed)))
            .collect()
    }

    /// Exact percentile (0..100) over the held window (sort-on-query; the
    /// query path may allocate, the record path never does).
    pub fn percentile(&self, pct: f64) -> f64 {
        let mut l = self.snapshot();
        if l.is_empty() {
            return 0.0;
        }
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((pct / 100.0) * (l.len() - 1) as f64).round() as usize;
        l[idx.min(l.len() - 1)]
    }

    /// Fold `other`'s held samples into `self`.
    pub fn absorb(&self, other: &SampleRing) {
        for v in other.snapshot() {
            self.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_f64_accumulates() {
        let a = AtomicF64::new(0.0);
        a.add(1.5);
        a.add(2.25);
        assert!((a.get() - 3.75).abs() < 1e-12);
    }

    #[test]
    fn histogram_estimates_within_bucket_error() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(0.004);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 0.4).abs() < 1e-9);
        for pct in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let e = h.percentile(pct);
            assert!((e / 0.004 - 1.0).abs() < 0.05, "p{pct} estimate {e}");
        }
    }

    #[test]
    fn histogram_rank_walk_matches_sorted_convention() {
        let h = Histogram::new();
        // 3 samples, widely separated: p50 must come from the middle one.
        for v in [0.002, 0.004, 0.050] {
            h.record(v);
        }
        assert!((h.percentile(50.0) / 0.004 - 1.0).abs() < 0.05);
        assert!((h.percentile(95.0) / 0.050 - 1.0).abs() < 0.05);
        assert!((h.percentile(0.0) / 0.002 - 1.0).abs() < 0.05);
    }

    #[test]
    fn histogram_edge_values() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0); // empty
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        assert_eq!(h.percentile(99.0), 0.0); // all in the underflow bucket
        h.record(1e12); // beyond HIST_MAX
        assert_eq!(h.percentile(100.0), HIST_MAX);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn histogram_absorb_merges_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(1.0);
        b.record(100.0);
        b.record(100.0);
        a.absorb(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - 201.0).abs() < 1e-9);
        assert!((a.percentile(99.0) / 100.0 - 1.0).abs() < 0.05);
    }

    #[test]
    fn sample_ring_overwrites_oldest() {
        let r = SampleRing::new(4);
        assert!(r.is_empty());
        for i in 0..6 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 4);
        let mut snap = r.snapshot();
        snap.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(snap, vec![2.0, 3.0, 4.0, 5.0]); // 0 and 1 overwritten
        assert!((r.percentile(0.0) - 2.0).abs() < 1e-12);
        assert!((r.percentile(100.0) - 5.0).abs() < 1e-12);
    }
}
