//! Request-lifecycle flight recorder: a fixed-capacity ring of structured
//! events cheap enough to leave on in production, exportable as Chrome
//! trace-event JSON (loadable directly in Perfetto / `chrome://tracing`).
//!
//! Every event is one fixed-size struct (no heap payload) written into a
//! preallocated ring under a short mutex hold — the recorder sits on the
//! scheduler's per-step path, not the per-sample metrics path, so a mutex
//! is acceptable: the step loop already serializes on the batcher queue
//! lock, and one ring write per *event* (a handful per step) is noise next
//! to a forward pass. A recorder built with [`FlightRecorder::disabled`]
//! (capacity 0) short-circuits before taking any lock, which is what the
//! overhead bench's "recorder off" arm measures.
//!
//! Timestamps are microseconds from a per-recorder [`Instant`] epoch, so
//! they are monotonic across threads and directly usable as Chrome trace
//! `ts` values.

use crate::util::json::{n, obj, s, Json};
use std::collections::HashSet;
use std::sync::Mutex;
use std::time::Instant;

/// Lifecycle stage an [`Event`] marks. Payload fields `a`/`b` are
/// per-kind (documented on each variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the route's queue. `tokens` = prompt length,
    /// `b` = queue depth after the push.
    Enqueued,
    /// Scheduler admitted the request into a slot. `a` = queue wait in µs,
    /// `b` = queue depth at admission.
    Admitted,
    /// One chunked-prefill tick fed `tokens` prompt tokens.
    /// `a` = 1 if this chunk completed the prompt.
    PrefillChunk,
    /// One plain decode tick emitted `tokens` tokens for the request.
    DecodeStep,
    /// One speculative draft phase (engine-wide, `req` 0): `tokens`
    /// tokens drafted across the batch, `dur_us` = draft wall time.
    SpecDraft,
    /// One speculative verify tick emitted `tokens` tokens for the
    /// request. `a` = drafted, `b` = accepted this tick.
    SpecVerify,
    /// Request finished and freed its slot. `tokens` = generated length,
    /// `a`/`b` = lifetime drafted/accepted token counts.
    Retired,
}

impl EventKind {
    /// Stable name used in trace export and tests.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueued => "enqueued",
            EventKind::Admitted => "admitted",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::DecodeStep => "decode_step",
            EventKind::SpecDraft => "spec_draft",
            EventKind::SpecVerify => "spec_verify",
            EventKind::Retired => "retired",
        }
    }
}

/// One fixed-size lifecycle record. `route` indexes the recorder's
/// interned route-name table; `req` 0 means "engine-wide" (no single
/// request), used by [`EventKind::SpecDraft`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Microseconds since the recorder's epoch at the *start* of the
    /// spanned work (or the event instant for point events).
    pub ts_us: u64,
    /// Span duration in microseconds (0 for point events).
    pub dur_us: u64,
    pub kind: EventKind,
    pub route: u16,
    pub req: u64,
    pub slot: u32,
    pub tokens: u32,
    pub a: u32,
    pub b: u32,
}

struct Ring {
    buf: Vec<Event>,
    /// Total events ever written; `next % cap` is the write slot.
    next: u64,
}

/// Fixed-capacity ring of lifecycle [`Event`]s with Chrome-trace export.
pub struct FlightRecorder {
    /// Ring capacity; 0 = disabled, checked before any lock is taken.
    cap: usize,
    ring: Mutex<Ring>,
    routes: Mutex<Vec<String>>,
    epoch: Instant,
}

/// Default ring capacity: at ~5 events per request this holds the last
/// few thousand request lifecycles in ~1 MiB.
pub const DEFAULT_CAPACITY: usize = 16_384;

impl FlightRecorder {
    pub fn new(capacity: usize) -> Self {
        FlightRecorder {
            cap: capacity,
            ring: Mutex::new(Ring { buf: Vec::with_capacity(capacity), next: 0 }),
            routes: Mutex::new(Vec::new()),
            epoch: Instant::now(),
        }
    }

    /// No-op sink: `record` returns before touching the ring lock.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Intern `name` and return its route id for [`Event::route`].
    pub fn register_route(&self, name: &str) -> u16 {
        let mut routes = self.routes.lock().unwrap();
        if let Some(i) = routes.iter().position(|r| r == name) {
            return i as u16;
        }
        routes.push(name.to_string());
        (routes.len() - 1) as u16
    }

    /// Microseconds since the recorder's epoch (monotonic).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Write one event into the ring (overwrites the oldest when full).
    pub fn record(&self, ev: Event) {
        if self.cap == 0 {
            return;
        }
        let mut ring = self.ring.lock().unwrap();
        let i = (ring.next % self.cap as u64) as usize;
        if i < ring.buf.len() {
            ring.buf[i] = ev;
        } else {
            ring.buf.push(ev);
        }
        ring.next += 1;
    }

    /// Record a point event stamped `now_us()`.
    #[allow(clippy::too_many_arguments)]
    pub fn record_now(
        &self,
        kind: EventKind,
        route: u16,
        req: u64,
        slot: u32,
        tokens: u32,
        a: u32,
        b: u32,
    ) {
        if self.cap == 0 {
            return;
        }
        let ts_us = self.now_us();
        self.record(Event { ts_us, dur_us: 0, kind, route, req, slot, tokens, a, b });
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        let ring = self.ring.lock().unwrap();
        ring.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The last `last` events in record order (all held events if `last`
    /// is `None` or larger than the ring).
    pub fn snapshot(&self, last: Option<usize>) -> Vec<Event> {
        if self.cap == 0 {
            return Vec::new();
        }
        let ring = self.ring.lock().unwrap();
        let held = ring.buf.len();
        let mut out = Vec::with_capacity(held);
        // Oldest-first: when the ring has wrapped, the event after the
        // write cursor is the oldest.
        let start = if ring.next as usize > held {
            (ring.next % self.cap as u64) as usize
        } else {
            0
        };
        for k in 0..held {
            out.push(ring.buf[(start + k) % held]);
        }
        if let Some(last) = last {
            if last < out.len() {
                out.drain(..out.len() - last);
            }
        }
        out
    }

    fn route_name(&self, id: u16) -> String {
        let routes = self.routes.lock().unwrap();
        routes.get(id as usize).cloned().unwrap_or_else(|| format!("route-{id}"))
    }

    /// Export the last `last` events (all if `None`) as a Chrome
    /// trace-event JSON object (`{"traceEvents": [...]}`) loadable in
    /// Perfetto. Each request becomes a `tid` lane: its queue wait is a
    /// `queued` B/E span, its residency a `request` B/E span, and every
    /// prefill chunk / decode step / verify step an `X` complete slice
    /// inside it. Ring eviction can orphan a span's begin event; the
    /// exporter tracks open spans while walking the snapshot and never
    /// emits an `E` without its `B` (an evicted-begin `Retired` degrades
    /// to an instant event), so the output always validates.
    pub fn trace_json(&self, last: Option<usize>) -> Json {
        let events = self.snapshot(last);
        let mut out: Vec<Json> = Vec::with_capacity(events.len() + 8);
        let mut queued_open: HashSet<u64> = HashSet::new();
        let mut serving_open: HashSet<u64> = HashSet::new();
        for ev in &events {
            let route = self.route_name(ev.route);
            let base = |ph: &str, name: &str, ts: u64| {
                vec![
                    ("ph", s(ph)),
                    ("name", s(name)),
                    ("pid", n(1.0)),
                    ("tid", n(ev.req as f64)),
                    ("ts", n(ts as f64)),
                    ("cat", s(&route)),
                ]
            };
            match ev.kind {
                EventKind::Enqueued => {
                    let mut fields = base("B", "queued", ev.ts_us);
                    fields.push((
                        "args",
                        obj(vec![
                            ("prompt_tokens", n(ev.tokens as f64)),
                            ("queue_depth", n(ev.b as f64)),
                        ]),
                    ));
                    queued_open.insert(ev.req);
                    out.push(obj(fields));
                }
                EventKind::Admitted => {
                    if queued_open.remove(&ev.req) {
                        out.push(obj(base("E", "queued", ev.ts_us)));
                    }
                    let mut fields = base("B", "request", ev.ts_us);
                    fields.push((
                        "args",
                        obj(vec![
                            ("queue_wait_ms", n(ev.a as f64 / 1000.0)),
                            ("queue_depth", n(ev.b as f64)),
                            ("slot", n(ev.slot as f64)),
                        ]),
                    ));
                    serving_open.insert(ev.req);
                    out.push(obj(fields));
                }
                EventKind::PrefillChunk
                | EventKind::DecodeStep
                | EventKind::SpecVerify
                | EventKind::SpecDraft => {
                    let mut fields = base("X", ev.kind.name(), ev.ts_us);
                    fields.push(("dur", n(ev.dur_us as f64)));
                    let args = match ev.kind {
                        EventKind::PrefillChunk => vec![
                            ("fed_tokens", n(ev.tokens as f64)),
                            ("prompt_done", n(ev.a as f64)),
                            ("slot", n(ev.slot as f64)),
                        ],
                        EventKind::SpecVerify => vec![
                            ("emitted", n(ev.tokens as f64)),
                            ("drafted", n(ev.a as f64)),
                            ("accepted", n(ev.b as f64)),
                            ("slot", n(ev.slot as f64)),
                        ],
                        EventKind::SpecDraft => vec![("drafted", n(ev.tokens as f64))],
                        _ => vec![("emitted", n(ev.tokens as f64)), ("slot", n(ev.slot as f64))],
                    };
                    fields.push(("args", obj(args)));
                    out.push(obj(fields));
                }
                EventKind::Retired => {
                    let args = obj(vec![
                        ("generated_tokens", n(ev.tokens as f64)),
                        ("drafted", n(ev.a as f64)),
                        ("accepted", n(ev.b as f64)),
                    ]);
                    if serving_open.remove(&ev.req) {
                        let mut fields = base("E", "request", ev.ts_us);
                        fields.push(("args", args));
                        out.push(obj(fields));
                    } else {
                        let mut fields = base("i", "retired", ev.ts_us);
                        fields.push(("s", s("t")));
                        fields.push(("args", args));
                        out.push(obj(fields));
                    }
                }
            }
        }
        obj(vec![("traceEvents", Json::Arr(out)), ("displayTimeUnit", s("ms"))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, req: u64, ts_us: u64) -> Event {
        Event { ts_us, dur_us: 0, kind, route: 0, req, slot: 0, tokens: 1, a: 0, b: 0 }
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let r = FlightRecorder::disabled();
        assert!(!r.enabled());
        r.record(ev(EventKind::Enqueued, 1, 0));
        assert!(r.is_empty());
        let trace = r.trace_json(None);
        assert_eq!(trace.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len), Some(0));
    }

    #[test]
    fn ring_overwrites_oldest_and_snapshots_in_order() {
        let r = FlightRecorder::new(4);
        assert!(r.enabled());
        for i in 0..6u64 {
            r.record(ev(EventKind::DecodeStep, i, i * 10));
        }
        let snap = r.snapshot(None);
        assert_eq!(snap.len(), 4);
        let reqs: Vec<u64> = snap.iter().map(|e| e.req).collect();
        assert_eq!(reqs, vec![2, 3, 4, 5]); // 0 and 1 evicted, order kept
        let last2 = r.snapshot(Some(2));
        assert_eq!(last2.iter().map(|e| e.req).collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn route_interning_is_stable() {
        let r = FlightRecorder::new(8);
        let a = r.register_route("alpha");
        let b = r.register_route("beta");
        assert_ne!(a, b);
        assert_eq!(r.register_route("alpha"), a);
        assert_eq!(r.route_name(a), "alpha");
        assert_eq!(r.route_name(99), "route-99");
    }

    #[test]
    fn trace_pairs_spans_and_degrades_orphans() {
        let r = FlightRecorder::new(16);
        r.register_route("m");
        // Full lifecycle for req 1; req 2's Enqueued/Admitted were evicted
        // (simulated by simply not recording them), so its Retired must
        // degrade to an instant event rather than an unmatched "E".
        r.record(ev(EventKind::Enqueued, 1, 10));
        r.record(ev(EventKind::Admitted, 1, 20));
        r.record(ev(EventKind::DecodeStep, 1, 30));
        r.record(ev(EventKind::Retired, 1, 40));
        r.record(ev(EventKind::Retired, 2, 50));
        let trace = r.trace_json(None);
        let evs = trace.get("traceEvents").and_then(Json::as_arr).unwrap();
        let phs: Vec<&str> =
            evs.iter().map(|e| e.get("ph").and_then(Json::as_str).unwrap()).collect();
        assert_eq!(phs, vec!["B", "E", "B", "X", "E", "i"]);
        // Round-trips through the parser.
        let text = trace.to_string_compact();
        assert!(Json::parse(&text).is_ok());
    }
}
