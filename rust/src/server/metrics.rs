//! Serving metrics: request/batch counters, latency distributions, and the
//! continuous-scheduler gauges (queue depth, queue wait, time-to-first-
//! token and per-token decode latency percentiles). Queue wait
//! (enqueue→admit) is recorded separately from TTFT so admission-policy
//! effects — who gets a cache slot first under FIFO / SJF / fair-share —
//! are visible on their own, not folded into prefill time.
//!
//! Every distribution is a lock-free log-bucketed [`Histogram`]
//! (`server::obs::hist`): the record path is a handful of relaxed atomic
//! adds with no `Mutex` and no allocation, and percentile queries walk a
//! fixed bucket array instead of cloning and sorting a 10k-sample window.
//! The one exception is per-request speculative acceptance
//! ([`Metrics::record_spec_request`]), where the exact recent values are
//! wanted — that keeps a raw-sample ring ([`SampleRing`]), still lock-free.
//!
//! Busy time is additionally attributed per [`Stage`] (prefill vs decode
//! vs speculative draft vs speculative verify), so a route's throughput
//! number can be decomposed into where the engine actually spent its
//! seconds. One `Metrics` instance covers one route; the per-route
//! registry and export surfaces live in `server::obs`.

use super::obs::{AtomicF64, Histogram, SampleRing};
use crate::model::KvPageStats;
use crate::util::json::{n, obj, Json};
use std::sync::atomic::{AtomicU64, Ordering};

/// Exact samples kept in the spec-acceptance window.
const WINDOW: usize = 10_000;

/// Engine-busy stage for per-route time attribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Prompt prefill forwards (chunked or one-shot).
    Prefill,
    /// Plain one-token-per-sequence decode forwards.
    Decode,
    /// Speculative routes: drafting on the compressed twin.
    SpecDraft,
    /// Speculative routes: batched target verification (tick time minus
    /// the draft phase).
    SpecVerify,
}

impl Stage {
    pub const ALL: [Stage; 4] =
        [Stage::Prefill, Stage::Decode, Stage::SpecDraft, Stage::SpecVerify];

    /// Stable name used in JSON/Prometheus export.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
            Stage::SpecDraft => "spec_draft",
            Stage::SpecVerify => "spec_verify",
        }
    }

    fn idx(self) -> usize {
        match self {
            Stage::Prefill => 0,
            Stage::Decode => 1,
            Stage::SpecDraft => 2,
            Stage::SpecVerify => 3,
        }
    }
}

/// Lock-free metrics for one route, shared by router + workers.
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    tokens: AtomicU64,
    /// Most recent queue depth observed at admission.
    queue_depth: AtomicU64,
    /// High-water mark of the queue depth.
    max_queue_depth: AtomicU64,
    /// Request latencies (seconds).
    latencies: Histogram,
    /// Submit→first-token latencies (seconds).
    ttfts: Histogram,
    /// Enqueue→admit waits (seconds).
    queue_waits: Histogram,
    /// Per-token decode-step durations (seconds) — the per-token decode
    /// latency every active sequence paid for that step.
    decode_steps: Histogram,
    /// Per-sequence inter-token gaps (seconds): the wall time between two
    /// consecutive emission events of one sequence — what a streaming
    /// client actually observes between token frames. Distinct from
    /// `decode_steps` (engine time per token): a sequence's gap also
    /// includes ticks spent on other sequences' prefill chunks.
    inter_tokens: Histogram,
    /// Fixed-route batch sizes (requests per generate_batch call).
    batch_sizes: Histogram,
    /// Continuous-route step occupancy (active slots per scheduler tick).
    occupancy: Histogram,
    /// Total engine-busy seconds.
    busy: AtomicF64,
    /// Busy seconds attributed per [`Stage`] (indexed by `Stage::idx`).
    stage_busy: [AtomicF64; 4],
    /// Tokens drafted by the compressed twin on speculative routes.
    spec_drafted: AtomicU64,
    /// Drafted tokens the dense target confirmed.
    spec_accepted: AtomicU64,
    /// Per-request acceptance rates (accepted/drafted), exact recent ring.
    spec_accepts: SampleRing,
    /// Paged-KV pool snapshot, refreshed by the scheduler each tick
    /// ([`Metrics::record_kv_pages`]): occupancy gauges (frames total /
    /// mapped / shared) and the cumulative prefix-cache counters.
    kv_pages_total: AtomicU64,
    kv_pages_used: AtomicU64,
    kv_pages_shared: AtomicU64,
    prefix_hits: AtomicU64,
    prefix_misses: AtomicU64,
    prefix_evictions: AtomicU64,
    prefix_saved_tokens: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            max_queue_depth: AtomicU64::new(0),
            latencies: Histogram::new(),
            ttfts: Histogram::new(),
            queue_waits: Histogram::new(),
            decode_steps: Histogram::new(),
            inter_tokens: Histogram::new(),
            batch_sizes: Histogram::new(),
            occupancy: Histogram::new(),
            busy: AtomicF64::new(0.0),
            stage_busy: [
                AtomicF64::new(0.0),
                AtomicF64::new(0.0),
                AtomicF64::new(0.0),
                AtomicF64::new(0.0),
            ],
            spec_drafted: AtomicU64::new(0),
            spec_accepted: AtomicU64::new(0),
            spec_accepts: SampleRing::new(WINDOW),
            kv_pages_total: AtomicU64::new(0),
            kv_pages_used: AtomicU64::new(0),
            kv_pages_shared: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_misses: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            prefix_saved_tokens: AtomicU64::new(0),
        }
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies.record(latency_s);
    }

    /// Record one fixed-route batch: `batch_size` requests generated
    /// `new_tokens` tokens in `elapsed_s` of engine time.
    pub fn record_batch(&self, batch_size: usize, new_tokens: usize, elapsed_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        self.batch_sizes.record(batch_size as f64);
        self.add_busy(Stage::Decode, elapsed_s);
    }

    /// Record the queue depth observed when a request was admitted.
    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Record one request's submit→first-token latency.
    pub fn record_ttft(&self, ttft_s: f64) {
        self.ttfts.record(ttft_s);
    }

    /// Record one request's enqueue→admit wait (how long it sat in the
    /// queue before an admission policy picked it).
    pub fn record_queue_wait(&self, wait_s: f64) {
        self.queue_waits.record(wait_s);
    }

    /// Record the number of active sequences (prefilling + decoding) one
    /// scheduler tick worked on.
    pub fn record_step_occupancy(&self, active: usize) {
        self.occupancy.record(active as f64);
    }

    /// Record prefill work: tokens count toward throughput and the elapsed
    /// time toward engine-busy, but NOT into the decode-latency histogram
    /// (prefill passes are prompt-sized, decode steps are single-token).
    pub fn record_prefill(&self, new_tokens: usize, elapsed_s: f64) {
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        self.add_busy(Stage::Prefill, elapsed_s);
    }

    /// Record one continuous decode step that emitted `new_tokens` tokens
    /// across `seqs` active sequences. The per-token decode latency is
    /// `elapsed_s * seqs / new_tokens`: each sequence waited `elapsed_s`
    /// for the step, and a step that lands several tokens per sequence
    /// amortises that wait across all of them (on the classic
    /// one-token-per-sequence path `seqs == new_tokens` and this reduces
    /// to `elapsed_s`).
    pub fn record_decode_step(&self, new_tokens: usize, seqs: usize, elapsed_s: f64) {
        if new_tokens == 0 {
            return;
        }
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        self.add_busy(Stage::Decode, elapsed_s);
        self.decode_steps.record(elapsed_s * seqs as f64 / new_tokens as f64);
    }

    /// Speculative flavor of [`Metrics::record_decode_step`]: the tick's
    /// `elapsed_s` is split into the draft phase (`draft_s`, compressed
    /// twin) and the verify remainder (dense target), attributed to
    /// [`Stage::SpecDraft`] / [`Stage::SpecVerify`] respectively. The
    /// draft window nests inside the tick, so the remainder is clamped at
    /// zero rather than trusted to stay positive.
    pub fn record_spec_decode_step(
        &self,
        new_tokens: usize,
        seqs: usize,
        elapsed_s: f64,
        draft_s: f64,
    ) {
        if new_tokens == 0 {
            return;
        }
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        let draft = draft_s.clamp(0.0, elapsed_s);
        self.busy.add(elapsed_s);
        self.stage_busy[Stage::SpecDraft.idx()].add(draft);
        self.stage_busy[Stage::SpecVerify.idx()].add(elapsed_s - draft);
        self.decode_steps.record(elapsed_s * seqs as f64 / new_tokens as f64);
    }

    /// Record one sequence's gap between two consecutive token-emission
    /// events (the cadence a streaming client sees between frames). The
    /// scheduler records one observation per sequence per emitting tick,
    /// starting from the second emission — the first gap is TTFT and lands
    /// in its own histogram.
    pub fn record_inter_token(&self, gap_s: f64) {
        self.inter_tokens.record(gap_s);
    }

    /// Inter-token gap percentile (0..100).
    pub fn inter_token_pct(&self, pct: f64) -> f64 {
        self.inter_tokens.percentile(pct)
    }

    /// Record one speculative verify step: the draft proposed `drafted`
    /// tokens and the target accepted `accepted` of them.
    pub fn record_spec_step(&self, drafted: usize, accepted: usize) {
        self.spec_drafted.fetch_add(drafted as u64, Ordering::Relaxed);
        self.spec_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
    }

    /// Record one finished request's lifetime draft acceptance; no-op when
    /// nothing was drafted (e.g. single-token or fallback-only requests).
    pub fn record_spec_request(&self, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return;
        }
        self.spec_accepts.push(accepted as f64 / drafted as f64);
    }

    /// Refresh the paged-KV pool snapshot. The pool lives on the scheduler
    /// thread; this copies its point-in-time occupancy gauges and
    /// monotonic prefix-cache counters (plain stores — the pool's own
    /// counters are the source of truth, so the last tick wins).
    pub fn record_kv_pages(&self, s: KvPageStats) {
        self.kv_pages_total.store(s.pages_total as u64, Ordering::Relaxed);
        self.kv_pages_used.store(s.pages_used as u64, Ordering::Relaxed);
        self.kv_pages_shared.store(s.pages_shared as u64, Ordering::Relaxed);
        self.prefix_hits.store(s.prefix_hits, Ordering::Relaxed);
        self.prefix_misses.store(s.prefix_misses, Ordering::Relaxed);
        self.prefix_evictions.store(s.prefix_evictions, Ordering::Relaxed);
        self.prefix_saved_tokens.store(s.prefix_saved_tokens, Ordering::Relaxed);
    }

    /// Most recent paged-KV pool snapshot (zeros before any tick ran).
    pub fn kv_pages(&self) -> KvPageStats {
        KvPageStats {
            pages_total: self.kv_pages_total.load(Ordering::Relaxed) as usize,
            pages_used: self.kv_pages_used.load(Ordering::Relaxed) as usize,
            pages_shared: self.kv_pages_shared.load(Ordering::Relaxed) as usize,
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_misses: self.prefix_misses.load(Ordering::Relaxed),
            prefix_evictions: self.prefix_evictions.load(Ordering::Relaxed),
            prefix_saved_tokens: self.prefix_saved_tokens.load(Ordering::Relaxed),
        }
    }

    fn add_busy(&self, stage: Stage, elapsed_s: f64) {
        self.busy.add(elapsed_s);
        self.stage_busy[stage.idx()].add(elapsed_s);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Queue depth at the most recent admission.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed) as usize
    }

    /// Deepest queue observed so far.
    pub fn max_queue_depth(&self) -> usize {
        self.max_queue_depth.load(Ordering::Relaxed) as usize
    }

    /// Mean batch size over recorded batches (fixed-batch routes; 0 when
    /// no batches were recorded, e.g. on continuous routes).
    pub fn mean_batch_size(&self) -> f64 {
        self.batch_sizes.mean()
    }

    /// Batch-size percentile (0..100) on fixed routes.
    pub fn batch_size_pct(&self, pct: f64) -> f64 {
        self.batch_sizes.percentile(pct)
    }

    /// Mean active sequences per scheduler tick on continuous routes.
    pub fn mean_step_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    /// Step-occupancy percentile (0..100) on continuous routes.
    pub fn step_occupancy_pct(&self, pct: f64) -> f64 {
        self.occupancy.percentile(pct)
    }

    /// Request-latency percentile (0..100).
    pub fn latency_pct(&self, pct: f64) -> f64 {
        self.latencies.percentile(pct)
    }

    /// Time-to-first-token percentile (0..100).
    pub fn ttft_pct(&self, pct: f64) -> f64 {
        self.ttfts.percentile(pct)
    }

    /// Queue-wait (enqueue→admit) percentile (0..100) — the knob
    /// admission policies actually move.
    pub fn queue_wait_pct(&self, pct: f64) -> f64 {
        self.queue_waits.percentile(pct)
    }

    /// Per-token decode-latency percentile (0..100).
    pub fn decode_pct(&self, pct: f64) -> f64 {
        self.decode_steps.percentile(pct)
    }

    /// Total tokens drafted on speculative routes.
    pub fn spec_drafted(&self) -> u64 {
        self.spec_drafted.load(Ordering::Relaxed)
    }

    /// Total drafted tokens the target confirmed.
    pub fn spec_accepted(&self) -> u64 {
        self.spec_accepted.load(Ordering::Relaxed)
    }

    /// Aggregate draft acceptance rate (accepted / drafted); 0 before any
    /// speculative step ran.
    pub fn spec_acceptance_rate(&self) -> f64 {
        let d = self.spec_drafted();
        if d == 0 {
            return 0.0;
        }
        self.spec_accepted() as f64 / d as f64
    }

    /// Per-request acceptance-rate percentile (0..100) over the recent
    /// window (exact — raw-sample ring, not bucketed).
    pub fn spec_accept_pct(&self, pct: f64) -> f64 {
        self.spec_accepts.percentile(pct)
    }

    /// Total engine-busy seconds.
    pub fn busy_seconds(&self) -> f64 {
        self.busy.get()
    }

    /// Busy seconds attributed to one [`Stage`].
    pub fn stage_busy_s(&self, stage: Stage) -> f64 {
        self.stage_busy[stage.idx()].get()
    }

    /// Decode throughput: generated tokens per engine-busy second.
    pub fn tokens_per_busy_second(&self) -> f64 {
        let busy = self.busy.get();
        if busy <= 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / busy
    }

    /// Histogram families exported per route, as `(family name, histogram)`.
    pub fn histograms(&self) -> [(&'static str, &Histogram); 7] {
        [
            ("request_latency_seconds", &self.latencies),
            ("ttft_seconds", &self.ttfts),
            ("queue_wait_seconds", &self.queue_waits),
            ("decode_step_seconds", &self.decode_steps),
            ("inter_token_seconds", &self.inter_tokens),
            ("batch_size", &self.batch_sizes),
            ("step_occupancy", &self.occupancy),
        ]
    }

    /// Fold `other`'s samples and counters into `self` — how the registry
    /// builds a cross-route aggregate. Queue depth sums (total queued
    /// across routes); the high-water mark takes the per-route max.
    pub fn absorb(&self, other: &Metrics) {
        self.requests.fetch_add(other.requests(), Ordering::Relaxed);
        self.batches.fetch_add(other.batches(), Ordering::Relaxed);
        self.tokens.fetch_add(other.tokens(), Ordering::Relaxed);
        self.queue_depth.fetch_add(other.queue_depth() as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(other.max_queue_depth() as u64, Ordering::Relaxed);
        self.latencies.absorb(&other.latencies);
        self.ttfts.absorb(&other.ttfts);
        self.queue_waits.absorb(&other.queue_waits);
        self.decode_steps.absorb(&other.decode_steps);
        self.inter_tokens.absorb(&other.inter_tokens);
        self.batch_sizes.absorb(&other.batch_sizes);
        self.occupancy.absorb(&other.occupancy);
        self.busy.add(other.busy.get());
        for stage in Stage::ALL {
            self.stage_busy[stage.idx()].add(other.stage_busy_s(stage));
        }
        self.spec_drafted.fetch_add(other.spec_drafted(), Ordering::Relaxed);
        self.spec_accepted.fetch_add(other.spec_accepted(), Ordering::Relaxed);
        self.spec_accepts.absorb(&other.spec_accepts);
        // Page gauges and prefix counters sum across routes (each route
        // owns its own pool).
        let kp = other.kv_pages();
        self.kv_pages_total.fetch_add(kp.pages_total as u64, Ordering::Relaxed);
        self.kv_pages_used.fetch_add(kp.pages_used as u64, Ordering::Relaxed);
        self.kv_pages_shared.fetch_add(kp.pages_shared as u64, Ordering::Relaxed);
        self.prefix_hits.fetch_add(kp.prefix_hits, Ordering::Relaxed);
        self.prefix_misses.fetch_add(kp.prefix_misses, Ordering::Relaxed);
        self.prefix_evictions.fetch_add(kp.prefix_evictions, Ordering::Relaxed);
        self.prefix_saved_tokens.fetch_add(kp.prefix_saved_tokens, Ordering::Relaxed);
    }

    /// Structured JSON export: counters/gauges as numbers, each histogram
    /// as `{count, sum, p50, p95, p99}`, stage busy-seconds keyed by
    /// stage name.
    pub fn export_json(&self) -> Json {
        fn hist(h: &Histogram) -> Json {
            obj(vec![
                ("count", n(h.count() as f64)),
                ("sum", n(h.sum())),
                ("p50", n(h.percentile(50.0))),
                ("p95", n(h.percentile(95.0))),
                ("p99", n(h.percentile(99.0))),
            ])
        }
        let mut fields = vec![
            ("requests", n(self.requests() as f64)),
            ("batches", n(self.batches() as f64)),
            ("tokens", n(self.tokens() as f64)),
            ("queue_depth", n(self.queue_depth() as f64)),
            ("max_queue_depth", n(self.max_queue_depth() as f64)),
            ("busy_s", n(self.busy_seconds())),
            ("tok_per_busy_s", n(self.tokens_per_busy_second())),
            (
                "stage_busy_s",
                Json::Obj(
                    Stage::ALL
                        .iter()
                        .map(|&st| (st.name().to_string(), n(self.stage_busy_s(st))))
                        .collect(),
                ),
            ),
            (
                "spec",
                obj(vec![
                    ("drafted", n(self.spec_drafted() as f64)),
                    ("accepted", n(self.spec_accepted() as f64)),
                    ("acceptance_rate", n(self.spec_acceptance_rate())),
                    ("accept_p50", n(self.spec_accept_pct(50.0))),
                ]),
            ),
            (
                "kv_pages",
                obj(vec![
                    ("total", n(self.kv_pages().pages_total as f64)),
                    ("used", n(self.kv_pages().pages_used as f64)),
                    ("shared", n(self.kv_pages().pages_shared as f64)),
                ]),
            ),
            (
                "prefix_cache",
                obj(vec![
                    ("hits", n(self.kv_pages().prefix_hits as f64)),
                    ("misses", n(self.kv_pages().prefix_misses as f64)),
                    ("evictions", n(self.kv_pages().prefix_evictions as f64)),
                    ("saved_tokens", n(self.kv_pages().prefix_saved_tokens as f64)),
                ]),
            ),
        ];
        for (name, h) in self.histograms() {
            fields.push((name, hist(h)));
        }
        obj(fields)
    }

    /// One-line summary (legacy format, kept stable for log scrapers).
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} tokens={} queue={}(max {}) \
             p50={:.1}ms p99={:.1}ms qwait_p50={:.1}ms qwait_p95={:.1}ms \
             ttft_p50={:.1}ms ttft_p95={:.1}ms \
             decode_p50={:.2}ms decode_p95={:.2}ms tok/s={:.1} \
             spec_accept={:.2} ({}/{})",
            self.requests(),
            self.batches(),
            self.mean_batch_size(),
            self.tokens(),
            self.queue_depth(),
            self.max_queue_depth(),
            self.latency_pct(50.0) * 1e3,
            self.latency_pct(99.0) * 1e3,
            self.queue_wait_pct(50.0) * 1e3,
            self.queue_wait_pct(95.0) * 1e3,
            self.ttft_pct(50.0) * 1e3,
            self.ttft_pct(95.0) * 1e3,
            self.decode_pct(50.0) * 1e3,
            self.decode_pct(95.0) * 1e3,
            self.tokens_per_busy_second(),
            self.spec_acceptance_rate(),
            self.spec_accepted(),
            self.spec_drafted(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Histogram percentiles are bucket representatives: assert within one
    /// bucket's relative error instead of exact equality.
    fn close(got: f64, want: f64) -> bool {
        (got / want - 1.0).abs() < 0.05
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_request(0.030);
        m.record_batch(2, 8, 0.040);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.tokens(), 8);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert!(m.latency_pct(50.0) >= 0.010);
        assert!(close(m.latency_pct(99.0), 0.030));
        assert!((m.tokens_per_busy_second() - 200.0).abs() < 1.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.latency_pct(99.0), 0.0);
        assert_eq!(m.ttft_pct(50.0), 0.0);
        assert_eq!(m.queue_wait_pct(95.0), 0.0);
        assert_eq!(m.decode_pct(95.0), 0.0);
        assert_eq!(m.tokens_per_busy_second(), 0.0);
        assert_eq!(m.mean_step_occupancy(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }

    #[test]
    fn queue_wait_percentiles_track_admission() {
        let m = Metrics::new();
        m.record_queue_wait(0.002);
        m.record_queue_wait(0.004);
        m.record_queue_wait(0.050);
        assert!(close(m.queue_wait_pct(50.0), 0.004));
        assert!(close(m.queue_wait_pct(95.0), 0.050));
        // Queue wait is its own histogram — TTFT stays untouched.
        assert_eq!(m.ttft_pct(50.0), 0.0);
        let s = m.summary();
        assert!(s.contains("qwait_p50=4.0ms"), "{s}");
        assert!(s.contains("qwait_p95="), "{s}");
    }

    #[test]
    fn scheduler_gauges_and_percentiles() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.max_queue_depth(), 3);

        m.record_ttft(0.010);
        m.record_ttft(0.020);
        m.record_ttft(0.100);
        assert!(close(m.ttft_pct(50.0), 0.020));
        assert!(close(m.ttft_pct(95.0), 0.100));

        // Prefill counts tokens + busy but not decode latency.
        m.record_prefill(1, 0.050);
        assert_eq!(m.tokens(), 1);
        assert_eq!(m.decode_pct(50.0), 0.0);

        m.record_decode_step(4, 4, 0.002);
        m.record_decode_step(4, 4, 0.004);
        m.record_decode_step(2, 2, 0.030);
        assert_eq!(m.tokens(), 11);
        assert!(close(m.decode_pct(50.0), 0.004));
        assert!(close(m.decode_pct(95.0), 0.030));

        let s = m.summary();
        assert!(s.contains("ttft_p50="), "{s}");
        assert!(s.contains("decode_p95="), "{s}");
        assert!(s.contains("queue=1(max 3)"), "{s}");
    }

    #[test]
    fn inter_token_gaps_recorded_and_exported() {
        let m = Metrics::new();
        assert_eq!(m.inter_token_pct(50.0), 0.0);
        m.record_inter_token(0.002);
        m.record_inter_token(0.004);
        m.record_inter_token(0.050);
        assert!(close(m.inter_token_pct(50.0), 0.004));
        assert!(close(m.inter_token_pct(95.0), 0.050));
        // Inter-token gaps are their own export family, separate from the
        // engine-time decode_step histogram.
        let j = m.export_json();
        let fam = j.get("inter_token_seconds").expect("inter_token_seconds family");
        assert_eq!(fam.get("count").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            j.get("decode_step_seconds").unwrap().get("count").and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn decode_step_amortises_latency_across_accepted_tokens() {
        let m = Metrics::new();
        // One sequence landed 4 tokens in a 0.008s speculative step: each
        // token cost 2ms, not 8ms.
        m.record_decode_step(4, 1, 0.008);
        assert_eq!(m.tokens(), 4);
        assert!(close(m.decode_pct(50.0), 0.002));
        // A zero-token step records nothing.
        m.record_decode_step(0, 3, 0.010);
        assert_eq!(m.tokens(), 4);
    }

    #[test]
    fn spec_counters_and_acceptance() {
        let m = Metrics::new();
        assert_eq!(m.spec_drafted(), 0);
        assert_eq!(m.spec_acceptance_rate(), 0.0);

        m.record_spec_step(4, 3);
        m.record_spec_step(4, 1);
        assert_eq!(m.spec_drafted(), 8);
        assert_eq!(m.spec_accepted(), 4);
        assert!((m.spec_acceptance_rate() - 0.5).abs() < 1e-12);

        m.record_spec_request(8, 4);
        m.record_spec_request(0, 0); // ignored: nothing drafted
        // Exact (raw-sample ring, not bucketed).
        assert!((m.spec_accept_pct(50.0) - 0.5).abs() < 1e-12);

        let s = m.summary();
        assert!(s.contains("spec_accept=0.50 (4/8)"), "{s}");
    }

    #[test]
    fn batch_sizes_recorded_not_faked() {
        let m = Metrics::new();
        // Old mean_batch_size faked requests/batches; with nothing but
        // uneven batches recorded, the histogram gives the real mean.
        m.record_batch(1, 4, 0.001);
        m.record_batch(7, 4, 0.001);
        assert_eq!(m.requests(), 0); // no requests retired yet
        assert!((m.mean_batch_size() - 4.0).abs() < 1e-9);
        assert!(close(m.batch_size_pct(100.0), 7.0));
    }

    #[test]
    fn step_occupancy_tracks_active_slots() {
        let m = Metrics::new();
        for occ in [1, 4, 4, 4] {
            m.record_step_occupancy(occ);
        }
        assert!((m.mean_step_occupancy() - 3.25).abs() < 1e-9);
        assert!(close(m.step_occupancy_pct(50.0), 4.0));
    }

    #[test]
    fn stage_busy_attribution_splits_spec_phases() {
        let m = Metrics::new();
        m.record_prefill(8, 0.010);
        m.record_decode_step(2, 2, 0.004);
        m.record_spec_decode_step(6, 2, 0.009, 0.003);
        assert!((m.stage_busy_s(Stage::Prefill) - 0.010).abs() < 1e-12);
        assert!((m.stage_busy_s(Stage::Decode) - 0.004).abs() < 1e-12);
        assert!((m.stage_busy_s(Stage::SpecDraft) - 0.003).abs() < 1e-12);
        assert!((m.stage_busy_s(Stage::SpecVerify) - 0.006).abs() < 1e-12);
        // Stage attribution partitions total busy time.
        let total: f64 = Stage::ALL.iter().map(|&st| m.stage_busy_s(st)).sum();
        assert!((total - m.busy_seconds()).abs() < 1e-12);
    }

    #[test]
    fn absorb_merges_routes() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_request(0.010);
        a.record_queue_depth(2);
        b.record_request(0.030);
        b.record_queue_depth(5);
        b.record_spec_step(4, 2);
        let agg = Metrics::new();
        agg.absorb(&a);
        agg.absorb(&b);
        assert_eq!(agg.requests(), 2);
        assert_eq!(agg.queue_depth(), 7); // summed across routes
        assert_eq!(agg.max_queue_depth(), 5);
        assert_eq!(agg.spec_drafted(), 4);
        assert!(close(agg.latency_pct(99.0), 0.030));
    }

    #[test]
    fn kv_page_snapshot_stores_and_absorbs() {
        let m = Metrics::new();
        assert_eq!(m.kv_pages().pages_total, 0);
        let snap = KvPageStats {
            pages_total: 32,
            pages_used: 10,
            pages_shared: 3,
            prefix_hits: 4,
            prefix_misses: 2,
            prefix_evictions: 1,
            prefix_saved_tokens: 64,
        };
        m.record_kv_pages(snap);
        // Last-tick-wins store semantics, not accumulation.
        m.record_kv_pages(snap);
        let got = m.kv_pages();
        assert_eq!(got.pages_used, 10);
        assert_eq!(got.prefix_hits, 4);
        assert_eq!(got.prefix_saved_tokens, 64);
        let agg = Metrics::new();
        agg.absorb(&m);
        agg.absorb(&m);
        // Routes sum in the aggregate.
        assert_eq!(agg.kv_pages().pages_total, 64);
        assert_eq!(agg.kv_pages().prefix_hits, 8);
        let j = m.export_json();
        assert_eq!(j.get("kv_pages").unwrap().get("used").and_then(Json::as_f64), Some(10.0));
        assert_eq!(
            j.get("prefix_cache").unwrap().get("saved_tokens").and_then(Json::as_f64),
            Some(64.0)
        );
    }

    #[test]
    fn export_json_shape() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_ttft(0.005);
        let j = m.export_json();
        assert_eq!(j.get("requests").and_then(Json::as_f64), Some(1.0));
        let lat = j.get("request_latency_seconds").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_f64), Some(1.0));
        assert!(lat.get("p50").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(j.get("stage_busy_s").unwrap().get("prefill").is_some());
        assert!(j.get("spec").unwrap().get("acceptance_rate").is_some());
    }
}
