//! Serving metrics: request/batch counters + latency aggregates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-light metrics registry shared by router + workers.
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    tokens: AtomicU64,
    /// Recent request latencies (seconds), capped ring.
    latencies: Mutex<Vec<f64>>,
    /// Total engine-busy seconds.
    busy: Mutex<f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            latencies: Mutex::new(Vec::new()),
            busy: Mutex::new(0.0),
        }
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() >= 10_000 {
            l.remove(0);
        }
        l.push(latency_s);
    }

    pub fn record_batch(&self, batch_size: usize, new_tokens: usize, elapsed_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        *self.busy.lock().unwrap() += elapsed_s;
        let _ = batch_size;
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Mean batch size so far.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches().max(1);
        self.requests() as f64 / b as f64
    }

    /// Latency percentile (0..100) over the recent window.
    pub fn latency_pct(&self, pct: f64) -> f64 {
        let mut l = self.latencies.lock().unwrap().clone();
        if l.is_empty() {
            return 0.0;
        }
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((pct / 100.0) * (l.len() - 1) as f64).round() as usize;
        l[idx.min(l.len() - 1)]
    }

    /// Decode throughput: generated tokens per engine-busy second.
    pub fn tokens_per_busy_second(&self) -> f64 {
        let busy = *self.busy.lock().unwrap();
        if busy <= 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / busy
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} tokens={} p50={:.1}ms p99={:.1}ms tok/s={:.1}",
            self.requests(),
            self.batches(),
            self.mean_batch_size(),
            self.tokens(),
            self.latency_pct(50.0) * 1e3,
            self.latency_pct(99.0) * 1e3,
            self.tokens_per_busy_second(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_request(0.030);
        m.record_batch(2, 8, 0.040);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.tokens(), 8);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert!(m.latency_pct(50.0) >= 0.010);
        assert!(m.latency_pct(99.0) <= 0.031);
        assert!((m.tokens_per_busy_second() - 200.0).abs() < 1.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.latency_pct(99.0), 0.0);
        assert_eq!(m.tokens_per_busy_second(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }
}
