//! Serving metrics: request/batch counters, latency aggregates, and the
//! continuous-scheduler gauges (queue depth, queue wait, time-to-first-
//! token and per-token decode latency percentiles). Queue wait
//! (enqueue→admit) is recorded separately from TTFT so admission-policy
//! effects — who gets a cache slot first under FIFO / SJF / fair-share —
//! are visible on their own, not folded into prefill time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Samples kept per latency window.
const WINDOW: usize = 10_000;

fn push_capped(samples: &Mutex<Vec<f64>>, v: f64) {
    let mut l = samples.lock().unwrap();
    if l.len() >= WINDOW {
        l.remove(0);
    }
    l.push(v);
}

fn percentile(samples: &Mutex<Vec<f64>>, pct: f64) -> f64 {
    let mut l = samples.lock().unwrap().clone();
    if l.is_empty() {
        return 0.0;
    }
    l.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((pct / 100.0) * (l.len() - 1) as f64).round() as usize;
    l[idx.min(l.len() - 1)]
}

/// Lock-light metrics registry shared by router + workers.
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    tokens: AtomicU64,
    /// Most recent queue depth observed at admission.
    queue_depth: AtomicU64,
    /// High-water mark of the queue depth.
    max_queue_depth: AtomicU64,
    /// Recent request latencies (seconds), capped ring.
    latencies: Mutex<Vec<f64>>,
    /// Recent submit→first-token latencies (seconds), capped ring.
    ttfts: Mutex<Vec<f64>>,
    /// Recent enqueue→admit waits (seconds), capped ring.
    queue_waits: Mutex<Vec<f64>>,
    /// Recent decode-step durations (seconds) — the per-token decode
    /// latency every active sequence paid for that step.
    decode_steps: Mutex<Vec<f64>>,
    /// Total engine-busy seconds.
    busy: Mutex<f64>,
    /// Tokens drafted by the compressed twin on speculative routes.
    spec_drafted: AtomicU64,
    /// Drafted tokens the dense target confirmed.
    spec_accepted: AtomicU64,
    /// Per-request acceptance rates (accepted/drafted), capped ring.
    spec_accepts: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            max_queue_depth: AtomicU64::new(0),
            latencies: Mutex::new(Vec::new()),
            ttfts: Mutex::new(Vec::new()),
            queue_waits: Mutex::new(Vec::new()),
            decode_steps: Mutex::new(Vec::new()),
            busy: Mutex::new(0.0),
            spec_drafted: AtomicU64::new(0),
            spec_accepted: AtomicU64::new(0),
            spec_accepts: Mutex::new(Vec::new()),
        }
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        push_capped(&self.latencies, latency_s);
    }

    pub fn record_batch(&self, batch_size: usize, new_tokens: usize, elapsed_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        *self.busy.lock().unwrap() += elapsed_s;
        let _ = batch_size;
    }

    /// Record the queue depth observed when a request was admitted.
    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Record one request's submit→first-token latency.
    pub fn record_ttft(&self, ttft_s: f64) {
        push_capped(&self.ttfts, ttft_s);
    }

    /// Record one request's enqueue→admit wait (how long it sat in the
    /// queue before an admission policy picked it).
    pub fn record_queue_wait(&self, wait_s: f64) {
        push_capped(&self.queue_waits, wait_s);
    }

    /// Record prefill work: tokens count toward throughput and the elapsed
    /// time toward engine-busy, but NOT into the decode-latency histogram
    /// (prefill passes are prompt-sized, decode steps are single-token).
    pub fn record_prefill(&self, new_tokens: usize, elapsed_s: f64) {
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        *self.busy.lock().unwrap() += elapsed_s;
    }

    /// Record one continuous decode step that emitted `new_tokens` tokens
    /// across `seqs` active sequences. The per-token decode latency is
    /// `elapsed_s * seqs / new_tokens`: each sequence waited `elapsed_s`
    /// for the step, and a speculative step that lands several accepted
    /// tokens per sequence amortises that wait across all of them (on the
    /// classic one-token-per-sequence path `seqs == new_tokens` and this
    /// reduces to `elapsed_s`, the old semantics).
    pub fn record_decode_step(&self, new_tokens: usize, seqs: usize, elapsed_s: f64) {
        if new_tokens == 0 {
            return;
        }
        self.tokens.fetch_add(new_tokens as u64, Ordering::Relaxed);
        *self.busy.lock().unwrap() += elapsed_s;
        push_capped(&self.decode_steps, elapsed_s * seqs as f64 / new_tokens as f64);
    }

    /// Record one speculative verify step: the draft proposed `drafted`
    /// tokens and the target accepted `accepted` of them.
    pub fn record_spec_step(&self, drafted: usize, accepted: usize) {
        self.spec_drafted.fetch_add(drafted as u64, Ordering::Relaxed);
        self.spec_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
    }

    /// Record one finished request's lifetime draft acceptance; no-op when
    /// nothing was drafted (e.g. single-token or fallback-only requests).
    pub fn record_spec_request(&self, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return;
        }
        push_capped(&self.spec_accepts, accepted as f64 / drafted as f64);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Queue depth at the most recent admission.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed) as usize
    }

    /// Deepest queue observed so far.
    pub fn max_queue_depth(&self) -> usize {
        self.max_queue_depth.load(Ordering::Relaxed) as usize
    }

    /// Mean batch size so far (fixed-batch routes; 0 when no batches were
    /// recorded, e.g. on continuous routes).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        self.requests() as f64 / b as f64
    }

    /// Request-latency percentile (0..100) over the recent window.
    pub fn latency_pct(&self, pct: f64) -> f64 {
        percentile(&self.latencies, pct)
    }

    /// Time-to-first-token percentile (0..100) over the recent window.
    pub fn ttft_pct(&self, pct: f64) -> f64 {
        percentile(&self.ttfts, pct)
    }

    /// Queue-wait (enqueue→admit) percentile (0..100) over the recent
    /// window — the knob admission policies actually move.
    pub fn queue_wait_pct(&self, pct: f64) -> f64 {
        percentile(&self.queue_waits, pct)
    }

    /// Per-token decode-latency percentile (0..100) over the recent window.
    pub fn decode_pct(&self, pct: f64) -> f64 {
        percentile(&self.decode_steps, pct)
    }

    /// Total tokens drafted on speculative routes.
    pub fn spec_drafted(&self) -> u64 {
        self.spec_drafted.load(Ordering::Relaxed)
    }

    /// Total drafted tokens the target confirmed.
    pub fn spec_accepted(&self) -> u64 {
        self.spec_accepted.load(Ordering::Relaxed)
    }

    /// Aggregate draft acceptance rate (accepted / drafted); 0 before any
    /// speculative step ran.
    pub fn spec_acceptance_rate(&self) -> f64 {
        let d = self.spec_drafted();
        if d == 0 {
            return 0.0;
        }
        self.spec_accepted() as f64 / d as f64
    }

    /// Per-request acceptance-rate percentile (0..100) over the recent
    /// window.
    pub fn spec_accept_pct(&self, pct: f64) -> f64 {
        percentile(&self.spec_accepts, pct)
    }

    /// Decode throughput: generated tokens per engine-busy second.
    pub fn tokens_per_busy_second(&self) -> f64 {
        let busy = *self.busy.lock().unwrap();
        if busy <= 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / busy
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} tokens={} queue={}(max {}) \
             p50={:.1}ms p99={:.1}ms qwait_p50={:.1}ms qwait_p95={:.1}ms \
             ttft_p50={:.1}ms ttft_p95={:.1}ms \
             decode_p50={:.2}ms decode_p95={:.2}ms tok/s={:.1} \
             spec_accept={:.2} ({}/{})",
            self.requests(),
            self.batches(),
            self.mean_batch_size(),
            self.tokens(),
            self.queue_depth(),
            self.max_queue_depth(),
            self.latency_pct(50.0) * 1e3,
            self.latency_pct(99.0) * 1e3,
            self.queue_wait_pct(50.0) * 1e3,
            self.queue_wait_pct(95.0) * 1e3,
            self.ttft_pct(50.0) * 1e3,
            self.ttft_pct(95.0) * 1e3,
            self.decode_pct(50.0) * 1e3,
            self.decode_pct(95.0) * 1e3,
            self.tokens_per_busy_second(),
            self.spec_acceptance_rate(),
            self.spec_accepted(),
            self.spec_drafted(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_request(0.030);
        m.record_batch(2, 8, 0.040);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.tokens(), 8);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert!(m.latency_pct(50.0) >= 0.010);
        assert!(m.latency_pct(99.0) <= 0.031);
        assert!((m.tokens_per_busy_second() - 200.0).abs() < 1.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.latency_pct(99.0), 0.0);
        assert_eq!(m.ttft_pct(50.0), 0.0);
        assert_eq!(m.queue_wait_pct(95.0), 0.0);
        assert_eq!(m.decode_pct(95.0), 0.0);
        assert_eq!(m.tokens_per_busy_second(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }

    #[test]
    fn queue_wait_percentiles_track_admission() {
        let m = Metrics::new();
        m.record_queue_wait(0.002);
        m.record_queue_wait(0.004);
        m.record_queue_wait(0.050);
        assert!((m.queue_wait_pct(50.0) - 0.004).abs() < 1e-12);
        assert!((m.queue_wait_pct(95.0) - 0.050).abs() < 1e-12);
        // Queue wait is its own histogram — TTFT stays untouched.
        assert_eq!(m.ttft_pct(50.0), 0.0);
        let s = m.summary();
        assert!(s.contains("qwait_p50=4.0ms"), "{s}");
        assert!(s.contains("qwait_p95=50.0ms"), "{s}");
    }

    #[test]
    fn scheduler_gauges_and_percentiles() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.max_queue_depth(), 3);

        m.record_ttft(0.010);
        m.record_ttft(0.020);
        m.record_ttft(0.100);
        assert!((m.ttft_pct(50.0) - 0.020).abs() < 1e-12);
        assert!((m.ttft_pct(95.0) - 0.100).abs() < 1e-12);

        // Prefill counts tokens + busy but not decode latency.
        m.record_prefill(1, 0.050);
        assert_eq!(m.tokens(), 1);
        assert_eq!(m.decode_pct(50.0), 0.0);

        m.record_decode_step(4, 4, 0.002);
        m.record_decode_step(4, 4, 0.004);
        m.record_decode_step(2, 2, 0.030);
        assert_eq!(m.tokens(), 11);
        assert!((m.decode_pct(50.0) - 0.004).abs() < 1e-12);
        assert!((m.decode_pct(95.0) - 0.030).abs() < 1e-12);

        let s = m.summary();
        assert!(s.contains("ttft_p50="), "{s}");
        assert!(s.contains("decode_p95="), "{s}");
        assert!(s.contains("queue=1(max 3)"), "{s}");
    }

    #[test]
    fn decode_step_amortises_latency_across_accepted_tokens() {
        let m = Metrics::new();
        // One sequence landed 4 tokens in a 0.008s speculative step: each
        // token cost 2ms, not 8ms.
        m.record_decode_step(4, 1, 0.008);
        assert_eq!(m.tokens(), 4);
        assert!((m.decode_pct(50.0) - 0.002).abs() < 1e-12);
        // A zero-token step records nothing.
        m.record_decode_step(0, 3, 0.010);
        assert_eq!(m.tokens(), 4);
    }

    #[test]
    fn spec_counters_and_acceptance() {
        let m = Metrics::new();
        assert_eq!(m.spec_drafted(), 0);
        assert_eq!(m.spec_acceptance_rate(), 0.0);

        m.record_spec_step(4, 3);
        m.record_spec_step(4, 1);
        assert_eq!(m.spec_drafted(), 8);
        assert_eq!(m.spec_accepted(), 4);
        assert!((m.spec_acceptance_rate() - 0.5).abs() < 1e-12);

        m.record_spec_request(8, 4);
        m.record_spec_request(0, 0); // ignored: nothing drafted
        assert!((m.spec_accept_pct(50.0) - 0.5).abs() < 1e-12);

        let s = m.summary();
        assert!(s.contains("spec_accept=0.50 (4/8)"), "{s}");
    }
}
