//! Serving stack: request router + dynamic batcher + TCP front-end.
//!
//! The L3 coordination layer for deploying compressed models (vLLM-router
//! flavored, std-thread based — the vendored crate set has no tokio):
//!
//! * [`engine`] — greedy-decode generation over a (compressed) model.
//! * [`batcher`] — collects concurrent requests into decode batches under
//!   a max-batch/max-wait policy (the paper serves with small decode
//!   batches, per Xia et al. / Zheng et al.).
//! * [`router`] — routes requests to named engines (model registry).
//! * [`api`] — newline-delimited-JSON TCP protocol + a blocking client.
//! * [`metrics`] — latency/throughput counters the benches read.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, GenRequest, GenResult};
pub use metrics::Metrics;
pub use router::Router;
