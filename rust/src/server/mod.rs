//! Serving stack: request router + schedulers + TCP front-end.
//!
//! The L3 coordination layer for deploying compressed models (vLLM-router
//! flavored, std-thread based — the vendored crate set has no tokio):
//!
//! * [`engine`] — greedy-decode generation over a (compressed) model,
//!   split into explicit serving phases: [`engine::Engine::prefill`]
//!   admits one request into a per-sequence `model::KvCachePool` slot,
//!   [`engine::Engine::decode_step`] advances every in-flight sequence one
//!   token in a single batched forward (`model::forward_slots`), and
//!   `generate_batch` is the run-to-completion wrapper. Per-slot prefill
//!   means no left-padding: batched greedy output is token-for-token
//!   identical to solo output. Cache slots are ring buffers with position
//!   rebasing (logical position `L` lives at physical row `L % max_seq`,
//!   its embedding at the window-relative index), so `decode_step` is
//!   depth-independent — generation past the context length costs one KV
//!   overwrite + one window attention pass, not a sliding-window
//!   re-prefill (`benches/decode.rs` records the flat per-token curve;
//!   the `model::KvLayout::Shift` reference pins the semantics).
//!   Compressed engines dispatch every linear matmul to packed kernels
//!   (`Engine::with_kernels` → `kernels::LinearOp`) — the paper's
//!   Fig. 3/4 speedups at the token-generation level.
//! * [`scheduler`] — the continuous-batching step-loop: admits queued
//!   requests into the running decode batch as cache slots free up and
//!   retires each sequence at its own `max_new`/stop token, so no request
//!   pays for the slowest member of a lockstep batch. `benches/serve.rs`
//!   measures it against the fixed-batch baseline under Poisson arrivals.
//!   The serving KV cache pool's storage dtype follows the engine's
//!   (`Engine::with_kv_dtype`) unless overridden per route via
//!   `SchedPolicy::kv_dtype` (a `model::KvDtype`): int8 / fp8 cached K/V
//!   holds ~4× fewer bytes per in-flight sequence while greedy output
//!   stays batching-invariant.
//! * [`batcher`] — the shared request queue: fixed batch formation under a
//!   max-batch/max-wait policy for the legacy worker, non-blocking
//!   `try_take` + untimed `wait_pending` admission for the scheduler.
//! * [`router`] — routes requests to named engines (model registry), one
//!   worker per engine in either serving mode.
//! * [`api`] — newline-delimited-JSON TCP protocol + a blocking client.
//! * [`metrics`] — counters, queue depth, TTFT and per-token decode
//!   latency percentiles the benches read.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod scheduler;

pub use crate::model::{KvDtype, KvLayout};
pub use batcher::{BatchPolicy, Batcher, Pending};
pub use engine::{Engine, GenRequest, GenResult, SeqState};
pub use metrics::Metrics;
pub use router::Router;
pub use scheduler::{SchedPolicy, Scheduler};
