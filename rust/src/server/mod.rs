//! Serving stack: request router + schedulers + TCP front-end.
//!
//! The L3 coordination layer for deploying compressed models (vLLM-router
//! flavored, std-thread based — the vendored crate set has no tokio):
//!
//! * [`engine`] — greedy-decode generation over a (compressed) model,
//!   split into explicit serving phases: [`engine::Engine::prefill_begin`]
//!   admits one request into a per-sequence `model::KvCachePool` slot as a
//!   resumable [`engine::PrefillState`] (no forward yet), and
//!   [`engine::Engine::step_chunked`] runs ONE batched forward
//!   (`model::forward_slots`) per serving tick that both feeds each
//!   in-progress prefill a bounded chunk of its prompt and advances every
//!   in-flight decode sequence one token. `prefill`/`prefill_batch` (a
//!   single unbounded chunk), `decode_step` (a prefill-free tick) and
//!   `generate_batch` are wrappers over the same primitive. Chunked
//!   prefill is token-for-token identical to one-shot prefill for every
//!   chunk size and KV dtype (bit-equal logits on f32 — property-tested),
//!   and per-slot prefill means no left-padding: batched greedy output is
//!   token-for-token identical to solo output. Cache slots are ring
//!   buffers with position rebasing, so `decode_step` is
//!   depth-independent — generation past the context length costs one KV
//!   overwrite + one window attention pass, not a sliding-window
//!   re-prefill (`benches/decode.rs` records the flat per-token curve).
//!   Compressed engines dispatch every linear matmul to packed kernels
//!   (`Engine::with_kernels` → `kernels::LinearOp`) — the paper's
//!   Fig. 3/4 speedups at the token-generation level.
//! * [`scheduler`] — the continuous-batching **token-budget step-loop**:
//!   each tick admits queued requests into free cache slots per the
//!   route's admission policy, then runs one `step_chunked` forward
//!   bounded by `SchedPolicy::step_tokens` (live decodes first, prompt
//!   chunks of ≤ `chunk_tokens` filling the rest). A long prompt
//!   therefore never head-of-line-blocks the in-flight decodes — the
//!   serve bench's head-of-line scenario measures chunked vs monolithic
//!   TTFT directly. Sequences retire at their own `max_new`/stop token.
//!   The serving KV cache pool's storage dtype follows the engine's
//!   (`Engine::with_kv_dtype`) unless overridden per route via
//!   `SchedPolicy::kv_dtype` (a `model::KvDtype`): int8 / fp8 cached K/V
//!   holds ~4× fewer bytes per in-flight sequence, and f16 / bf16 holds
//!   2× fewer at near-f32 fidelity (attention reads the 16-bit rows
//!   directly through its half fast path — no f32 decode slab), while
//!   greedy output stays batching-invariant either way. The pool itself
//!   is **page-granular** (fixed `model::PAGE_ROWS`-row pages, a global
//!   ref-counted frame pool, per-sequence page tables with copy-on-write
//!   `fork`), which buys the scheduler two more moves: **preemption** —
//!   when a strictly higher-priority request waits on a full route, a
//!   lowest-priority victim's pages are freed and the victim requeued as
//!   a resumable prefill (token-identical to never having been paused;
//!   `SchedPolicy::preempt_every` forces it for tests) — and **prefix
//!   caching** — full prompt-prefix pages are content-hashed and shared
//!   across requests, so a repeated system prompt prefills once and
//!   every later hit maps the pages and skips that compute (the serve
//!   bench's `prefix-shared` scenario gates the hit TTFT p95). Engine
//!   construction also runs the one-shot kernel autotuner
//!   (`kernels::tune`), which picks the packed-kernel and attention tile
//!   shapes for this machine once per process.
//! * [`spec`] — self-speculative decoding: [`spec::SpecEngine`] pairs the
//!   SLiM-compressed engine (draft) with the dense engine (target) over
//!   twin lockstep KV pools. Each spec tick greedily drafts up to
//!   `SchedPolicy::draft_k` tokens per sequence on the cheap kernels,
//!   then verifies the whole batch of drafts in ONE batched target
//!   forward (multi-token continuation spans packed alongside prefill
//!   chunks); the longest agreeing prefix is accepted, the first
//!   disagreement is replaced by the target's own pick, and both pools
//!   roll back via `model::KvCachePool::truncate`. Output is
//!   token-identical to target-only greedy by construction
//!   (property-tested across KV dtypes and draft depths) — the draft
//!   only decides how many target tokens land per step, never which.
//! * [`batcher`] — the shared request queue: fixed batch formation under a
//!   max-batch/max-wait policy for the legacy worker; non-blocking
//!   policy-driven `take_admit` + untimed `wait_pending` admission for
//!   the scheduler. [`batcher::AdmitPolicy`] picks *which* queued
//!   requests admit when slots are scarce: FIFO arrival order,
//!   shortest-job-first on `max_new`, or per-client fair share
//!   (round-robin over `GenRequest::client_id`, `priority` first).
//! * [`router`] — routes requests to named engines (model registry), one
//!   worker per engine in either serving mode; `submit_with` carries the
//!   full `RequestOpts` (stop, priority, client id, sampling knobs), and
//!   `submit_stream_with` / the `session_*` methods expose streamed
//!   delivery and stateful multi-turn sessions on scheduler routes.
//! * [`session`] — the per-route session table behind multi-turn serving:
//!   each open session keeps its conversation history and a parked KV
//!   cache slot between turns (LRU-evictable under slot pressure), so
//!   turn N+1 prefills only its new tokens.
//! * [`proto`] — the typed wire protocol: `Request`/`Envelope` parsing
//!   with strict unknown-field rejection, the v1/v2 version rules, and
//!   the stable error codes (`proto::codes`) — see `docs/PROTOCOL.md`.
//! * [`api`] — newline-delimited-JSON TCP front-end over [`proto`] + a
//!   blocking client: generate (one-shot or `"stream":true` incremental
//!   frames), session commands, metrics/trace/models introspection.
//! * [`metrics`] — per-route counters, queue depth,
//!   queue-wait/TTFT/decode-latency percentiles the benches read, and
//!   the KV page-pool occupancy gauges + prefix-cache counters
//!   (`Metrics::kv_pages`) exported as `slim_kv_pages_*` /
//!   `slim_prefix_cache_*` in the Prometheus exposition.
//! * [`obs`] — the observability substrate the above emit into.
//!
//! # Observability
//!
//! Two structures, split by cost budget (`server::obs`):
//!
//! * **Metrics registry** ([`obs::Registry`]): every route owns a
//!   [`Metrics`] whose distributions are lock-free log-bucketed
//!   histograms ([`obs::Histogram`]) — the record path is a handful of
//!   relaxed atomic adds (no `Mutex`, no allocation per sample), so the
//!   scheduler can record from its hot tick loop; percentile queries walk
//!   a fixed ~480-bucket array (O(buckets), ≤ ~4.5% relative error) and
//!   never block recording. Busy seconds are attributed per
//!   [`metrics::Stage`] (prefill / decode / spec-draft / spec-verify), so
//!   a route's tok/s decomposes into where the time went.
//! * **Flight recorder** ([`obs::FlightRecorder`]): a fixed-capacity
//!   shared ring of structured lifecycle events (enqueued → admitted →
//!   each prefill chunk → each decode/verify step → retired, with request
//!   id, route, slot, token counts, monotonic µs timestamps). Recording
//!   is one fixed-size slot write under a short mutex, a few events per
//!   scheduler *tick* (not per token) — cheap enough to leave on; the
//!   `metrics-overhead` bench gates the full-tracing serve-throughput
//!   cost at ≤ 5%.
//!
//! Export surfaces (see [`api`]): `{"cmd":"metrics"}` structured JSON per
//! route (+ legacy `"summary"` line), `{"cmd":"metrics_prom"}` Prometheus
//! text, `{"cmd":"trace"}` Chrome trace-event JSON loadable in Perfetto.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod obs;
pub mod proto;
pub mod router;
pub mod scheduler;
pub mod session;
pub mod spec;

pub use crate::model::{KvDtype, KvLayout, SampleParams};
pub use batcher::{AdmitPolicy, AdmitState, BatchPolicy, Batcher, Pending};
pub use engine::{Engine, GenRequest, GenResult, PrefillState, SeqState, StepStats, StreamEvent};
pub use metrics::{Metrics, Stage};
pub use obs::{FlightRecorder, Histogram, Registry, RouteObs, SampleRing};
pub use proto::ProtoError;
pub use router::{RequestOpts, RouteInfo, Router};
pub use scheduler::{SchedPolicy, Scheduler};
pub use session::{SessionError, SessionTable};
pub use spec::{SpecEngine, SpecStepStats};
