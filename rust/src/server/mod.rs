//! Serving stack: request router + dynamic batcher + TCP front-end.
//!
//! The L3 coordination layer for deploying compressed models (vLLM-router
//! flavored, std-thread based — the vendored crate set has no tokio):
//!
//! * [`engine`] — greedy-decode generation over a (compressed) model.
//!   Generation is split into the standard serving phases: the prompt is
//!   *prefilled* once into a `model::KvCache`, then each token is a
//!   single-position incremental *decode* step (`model::forward_cached`),
//!   so per-token cost is linear — not quadratic — in sequence length.
//!   Compressed engines can dispatch every linear matmul to packed kernels
//!   (`Engine::with_kernels` → `kernels::LinearOp`); `benches/decode.rs`
//!   measures the resulting end-to-end prefill/decode speedups — the
//!   paper's Fig. 3/4 decomposition at the token-generation level.
//! * [`batcher`] — collects concurrent requests into decode batches under
//!   a max-batch/max-wait policy (the paper serves with small decode
//!   batches, per Xia et al. / Zheng et al.).
//! * [`router`] — routes requests to named engines (model registry).
//! * [`api`] — newline-delimited-JSON TCP protocol + a blocking client.
//! * [`metrics`] — latency/throughput counters the benches read.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, GenRequest, GenResult};
pub use metrics::Metrics;
pub use router::Router;
