//! Typed wire protocol for the TCP JSON api (`server::api`).
//!
//! One request line parses into an [`Envelope`] — a protocol version plus
//! a typed [`Request`] — or a typed [`ProtoError`] with a stable
//! machine-readable code from [`codes`]. The full grammar, with example
//! transcripts, lives in `docs/PROTOCOL.md`; this module is the single
//! source of truth for what parses.
//!
//! Versioning: a request carrying `"v": 2` opts into the structured v2
//! surface (`{"ok":false,"v":2,"error":{"code","message"}}` errors and
//! `"v":2` stamped on success frames). A request with no `"v"` field (or
//! an explicit `"v": 1`) is legacy v1: same commands, but errors stay the
//! original flat string shape `{"ok":false,"error":"..."}`. Unknown
//! versions are rejected. Unknown *fields* are rejected in both versions —
//! a misspelled knob must fail loudly, not silently fall back to a
//! default.

use crate::model::SampleParams;
use crate::util::json::{obj, s, Json};
use std::collections::BTreeMap;

/// Current protocol version. Requests without a `"v"` field speak v1.
pub const VERSION: u64 = 2;

/// Stable error codes carried in v2 error envelopes. Tests and clients
/// match on these, never on message text.
pub mod codes {
    /// The line was not valid JSON (or not a JSON object).
    pub const BAD_JSON: &str = "bad_json";
    /// Structurally valid but semantically malformed request: missing or
    /// mistyped field, unknown field, out-of-range knob, bad token.
    pub const BAD_REQUEST: &str = "bad_request";
    /// Unknown `"cmd"` value.
    pub const UNKNOWN_CMD: &str = "unknown_cmd";
    /// No route registered under the requested model name.
    pub const UNKNOWN_MODEL: &str = "unknown_model";
    /// Bad `kv_dtype` assertion: unknown dtype name, or a known name that
    /// differs from the route's serving dtype.
    pub const BAD_DTYPE: &str = "bad_dtype";
    /// No live session with the given id on this route.
    pub const UNKNOWN_SESSION: &str = "unknown_session";
    /// The session already has a turn in flight.
    pub const SESSION_BUSY: &str = "session_busy";
    /// The route does not serve sessions.
    pub const SESSIONS_DISABLED: &str = "sessions_disabled";
    /// The route's session table is at `max_sessions`.
    pub const SESSION_LIMIT: &str = "session_limit";
    /// Server-side failure (timeout, route worker gone).
    pub const INTERNAL: &str = "internal";
}

/// A typed protocol error: stable `code` + human-readable `message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoError {
    pub code: &'static str,
    pub message: String,
}

impl ProtoError {
    pub fn new(code: &'static str, message: impl Into<String>) -> Self {
        ProtoError { code, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new(codes::BAD_REQUEST, message)
    }
}

/// A parsed generate command (streaming is a flag, not a separate shape).
#[derive(Clone, Debug, PartialEq)]
pub struct Generate {
    pub model: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub stop: Option<u32>,
    pub priority: i32,
    pub client_id: u64,
    /// Optional assertion on the route's serving KV cache dtype.
    pub kv_dtype: Option<String>,
    pub sample: SampleParams,
    /// Deliver incrementally as `token`/`done` frames instead of one
    /// response line.
    pub stream: bool,
}

/// A parsed session-append command: one conversation turn.
#[derive(Clone, Debug, PartialEq)]
pub struct Append {
    pub model: String,
    pub session: u64,
    /// The turn's NEW tokens only; the server prepends the history.
    pub tokens: Vec<u32>,
    pub max_new: usize,
    pub stop: Option<u32>,
    pub priority: i32,
    pub client_id: u64,
    pub sample: SampleParams,
    pub stream: bool,
}

/// Every request the wire protocol understands.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Generate(Generate),
    SessionOpen { model: String },
    SessionAppend(Append),
    SessionDrop { model: String, session: u64 },
    Metrics,
    MetricsProm,
    Trace { last: Option<usize> },
    Models,
}

/// A parsed request plus the protocol version it arrived under (the
/// version shapes the response, error frames especially).
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub v: u64,
    pub req: Request,
}

/// Parse one request line. On failure the error carries the version the
/// reply should speak — v1 when the line was too broken to tell.
pub fn parse(line: &str) -> Result<Envelope, (u64, ProtoError)> {
    let json = Json::parse(line)
        .map_err(|e| (1, ProtoError::new(codes::BAD_JSON, format!("bad json: {e}"))))?;
    let Json::Obj(map) = &json else {
        return Err((1, ProtoError::new(codes::BAD_JSON, "request must be a JSON object")));
    };
    let v = match map.get("v") {
        None => 1,
        Some(x) => match x.as_f64() {
            Some(f) if f == 1.0 => 1,
            Some(f) if f == 2.0 => 2,
            _ => {
                let err = ProtoError::bad_request(format!(
                    "unsupported protocol version {} (this server speaks 1 and 2)",
                    x.to_string_compact()
                ));
                return Err((1, err));
            }
        },
    };
    parse_request(map).map(|req| Envelope { v, req }).map_err(|e| (v, e))
}

fn parse_request(map: &BTreeMap<String, Json>) -> Result<Request, ProtoError> {
    let mut f = Fields::new(map);
    f.take("v"); // consumed above
    let cmd = match f.take("cmd") {
        None => None,
        Some(c) => Some(
            c.as_str()
                .ok_or_else(|| ProtoError::bad_request("field \"cmd\" must be a string"))?,
        ),
    };
    let req = match cmd {
        // A bare `{"model": ..., "prompt": ...}` line is an implicit
        // generate — the v1 shape, still valid in v2.
        None | Some("generate") => Request::Generate(parse_generate(&mut f)?),
        Some("session_open") => Request::SessionOpen { model: take_model(&mut f)? },
        Some("session_append") => Request::SessionAppend(parse_append(&mut f)?),
        Some("session_drop") => {
            let model = take_model(&mut f)?;
            let session = as_u64(f.require("session")?, "session")?;
            Request::SessionDrop { model, session }
        }
        Some("metrics") => Request::Metrics,
        Some("metrics_prom") => Request::MetricsProm,
        Some("trace") => {
            let last = match f.take("last") {
                None => None,
                Some(x) => Some(as_u64(x, "last")? as usize),
            };
            Request::Trace { last }
        }
        Some("models") => Request::Models,
        Some(other) => {
            return Err(ProtoError::new(codes::UNKNOWN_CMD, format!("unknown cmd {other}")))
        }
    };
    f.finish()?;
    Ok(req)
}

fn parse_generate(f: &mut Fields<'_>) -> Result<Generate, ProtoError> {
    let model = take_model(f)?;
    let prompt = as_tokens(f.require("prompt")?, "prompt")?;
    let kv_dtype = match f.take("kv_dtype") {
        None => None,
        Some(x) => Some(
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| ProtoError::bad_request("field \"kv_dtype\" must be a string"))?,
        ),
    };
    let common = parse_gen_common(f)?;
    Ok(Generate {
        model,
        prompt,
        max_new: common.max_new,
        stop: common.stop,
        priority: common.priority,
        client_id: common.client_id,
        kv_dtype,
        sample: common.sample,
        stream: common.stream,
    })
}

fn parse_append(f: &mut Fields<'_>) -> Result<Append, ProtoError> {
    let model = take_model(f)?;
    let session = as_u64(f.require("session")?, "session")?;
    let tokens = as_tokens(f.require("tokens")?, "tokens")?;
    let common = parse_gen_common(f)?;
    Ok(Append {
        model,
        session,
        tokens,
        max_new: common.max_new,
        stop: common.stop,
        priority: common.priority,
        client_id: common.client_id,
        sample: common.sample,
        stream: common.stream,
    })
}

/// Generation knobs shared by `generate` and `session_append`.
struct GenCommon {
    max_new: usize,
    stop: Option<u32>,
    priority: i32,
    client_id: u64,
    sample: SampleParams,
    stream: bool,
}

/// Server-side cap on any one request's generation budget.
pub const MAX_NEW_CAP: usize = 256;

fn parse_gen_common(f: &mut Fields<'_>) -> Result<GenCommon, ProtoError> {
    let max_new = match f.take("max_new") {
        None => 16,
        Some(x) => (as_u64(x, "max_new")? as usize).min(MAX_NEW_CAP),
    };
    let stop = match f.take("stop") {
        None => None,
        Some(x) => Some(as_u64(x, "stop")? as u32),
    };
    let priority = match f.take("priority") {
        None => 0,
        Some(x) => x
            .as_f64()
            .map(|p| p as i32)
            .ok_or_else(|| ProtoError::bad_request("field \"priority\" must be a number"))?,
    };
    let client_id = match f.take("client_id") {
        None => 0,
        Some(x) => as_u64(x, "client_id")?,
    };
    let mut sample = SampleParams::greedy();
    if let Some(x) = f.take("temperature") {
        sample.temperature = x
            .as_f64()
            .ok_or_else(|| ProtoError::bad_request("field \"temperature\" must be a number"))?
            as f32;
    }
    if let Some(x) = f.take("top_k") {
        sample.top_k = as_u64(x, "top_k")? as usize;
    }
    if let Some(x) = f.take("top_p") {
        sample.top_p = x
            .as_f64()
            .ok_or_else(|| ProtoError::bad_request("field \"top_p\" must be a number"))?
            as f32;
    }
    if let Some(x) = f.take("seed") {
        sample.seed = as_u64(x, "seed")?;
    }
    sample.validate().map_err(ProtoError::bad_request)?;
    let stream = match f.take("stream") {
        None => false,
        Some(x) => x
            .as_bool()
            .ok_or_else(|| ProtoError::bad_request("field \"stream\" must be a boolean"))?,
    };
    Ok(GenCommon { max_new, stop, priority, client_id, sample, stream })
}

/// Field cursor: `take` marks a key as understood; `finish` rejects any
/// key the command never consumed, so typos fail loudly.
struct Fields<'a> {
    map: &'a BTreeMap<String, Json>,
    used: Vec<&'static str>,
}

impl<'a> Fields<'a> {
    fn new(map: &'a BTreeMap<String, Json>) -> Self {
        Fields { map, used: Vec::new() }
    }

    fn take(&mut self, key: &'static str) -> Option<&'a Json> {
        self.used.push(key);
        self.map.get(key)
    }

    fn require(&mut self, key: &'static str) -> Result<&'a Json, ProtoError> {
        self.take(key)
            .ok_or_else(|| ProtoError::bad_request(format!("missing field \"{key}\"")))
    }

    fn finish(self) -> Result<(), ProtoError> {
        for k in self.map.keys() {
            if !self.used.contains(&k.as_str()) {
                return Err(ProtoError::bad_request(format!("unknown field \"{k}\"")));
            }
        }
        Ok(())
    }
}

fn take_model(f: &mut Fields<'_>) -> Result<String, ProtoError> {
    f.require("model")?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| ProtoError::bad_request("field \"model\" must be a string"))
}

fn as_u64(v: &Json, key: &str) -> Result<u64, ProtoError> {
    match v.as_f64() {
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 => Ok(x as u64),
        _ => Err(ProtoError::bad_request(format!(
            "field \"{key}\" must be a non-negative integer"
        ))),
    }
}

fn as_tokens(v: &Json, key: &str) -> Result<Vec<u32>, ProtoError> {
    let arr = v.as_arr().ok_or_else(|| {
        ProtoError::bad_request(format!("field \"{key}\" must be an array of token ids"))
    })?;
    arr.iter()
        .map(|t| as_u64(t, key).map(|u| u as u32))
        .collect::<Result<Vec<u32>, ProtoError>>()
        .map_err(|_| {
            ProtoError::bad_request(format!("field \"{key}\" must contain integer token ids"))
        })
}

/// Shape an error for the wire: v1 keeps the legacy flat string, v2
/// carries the structured `{code, message}` object plus the version stamp.
pub fn error_json(v: u64, err: &ProtoError) -> Json {
    if v >= 2 {
        obj(vec![
            ("ok", Json::Bool(false)),
            ("v", crate::util::json::n(2.0)),
            ("error", obj(vec![("code", s(err.code)), ("message", s(&err.message))])),
        ])
    } else {
        obj(vec![("ok", Json::Bool(false)), ("error", s(&err.message))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(line: &str) -> Envelope {
        parse(line).expect(line)
    }

    fn perr(line: &str) -> (u64, ProtoError) {
        parse(line).expect_err(line)
    }

    #[test]
    fn v1_generate_shape_parses_with_defaults() {
        let env = p(r#"{"model":"m","prompt":[1,2,3]}"#);
        assert_eq!(env.v, 1);
        let Request::Generate(g) = env.req else { panic!("not generate") };
        assert_eq!(g.model, "m");
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert_eq!(g.max_new, 16);
        assert_eq!(g.stop, None);
        assert!(g.sample.is_greedy());
        assert!(!g.stream);
        assert_eq!(g.kv_dtype, None);
    }

    #[test]
    fn v2_generate_with_all_knobs() {
        let env = p(
            r#"{"v":2,"cmd":"generate","model":"m","prompt":[5],"max_new":4,"stop":9,
                "priority":-1,"client_id":3,"kv_dtype":"f32","temperature":0.7,"top_k":40,
                "top_p":0.9,"seed":123,"stream":true}"#,
        );
        assert_eq!(env.v, 2);
        let Request::Generate(g) = env.req else { panic!("not generate") };
        assert_eq!(g.max_new, 4);
        assert_eq!(g.stop, Some(9));
        assert_eq!(g.priority, -1);
        assert_eq!(g.client_id, 3);
        assert_eq!(g.kv_dtype.as_deref(), Some("f32"));
        assert!((g.sample.temperature - 0.7).abs() < 1e-6);
        assert_eq!((g.sample.top_k, g.sample.seed), (40, 123));
        assert!(g.stream);
    }

    #[test]
    fn max_new_is_capped() {
        let env = p(r#"{"model":"m","prompt":[1],"max_new":100000}"#);
        let Request::Generate(g) = env.req else { panic!() };
        assert_eq!(g.max_new, MAX_NEW_CAP);
    }

    #[test]
    fn session_commands_roundtrip() {
        let env = p(r#"{"v":2,"cmd":"session_open","model":"m"}"#);
        assert_eq!(env.req, Request::SessionOpen { model: "m".into() });
        let env = p(r#"{"v":2,"cmd":"session_append","model":"m","session":7,"tokens":[4,5]}"#);
        let Request::SessionAppend(a) = env.req else { panic!("not append") };
        assert_eq!((a.session, a.tokens.clone()), (7, vec![4, 5]));
        assert!(!a.stream);
        let env = p(r#"{"v":2,"cmd":"session_drop","model":"m","session":7}"#);
        assert_eq!(env.req, Request::SessionDrop { model: "m".into(), session: 7 });
    }

    #[test]
    fn admin_commands_roundtrip() {
        assert_eq!(p(r#"{"cmd":"metrics"}"#).req, Request::Metrics);
        assert_eq!(p(r#"{"cmd":"metrics_prom"}"#).req, Request::MetricsProm);
        assert_eq!(p(r#"{"cmd":"trace"}"#).req, Request::Trace { last: None });
        assert_eq!(p(r#"{"cmd":"trace","last":5}"#).req, Request::Trace { last: Some(5) });
        assert_eq!(p(r#"{"cmd":"models","v":2}"#).req, Request::Models);
    }

    #[test]
    fn malformed_lines_fail_typed() {
        // Truncated / non-JSON input.
        assert_eq!(perr("{\"model\":").1.code, codes::BAD_JSON);
        assert_eq!(perr("not json").1.code, codes::BAD_JSON);
        assert_eq!(perr("[1,2]").1.code, codes::BAD_JSON);
        // Wrong field types.
        assert_eq!(perr(r#"{"model":7,"prompt":[1]}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"model":"m","prompt":"hi"}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"model":"m","prompt":[1.5]}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"model":"m","prompt":[-3]}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(
            perr(r#"{"model":"m","prompt":[1],"stream":"yes"}"#).1.code,
            codes::BAD_REQUEST
        );
        assert_eq!(perr(r#"{"cmd":7}"#).1.code, codes::BAD_REQUEST);
        // Missing required fields.
        assert_eq!(perr(r#"{"model":"m"}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"cmd":"session_append","model":"m"}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"cmd":"session_drop","model":"m"}"#).1.code, codes::BAD_REQUEST);
        assert_eq!(perr(r#"{"cmd":"session_open"}"#).1.code, codes::BAD_REQUEST);
        // Unknown command.
        assert_eq!(perr(r#"{"cmd":"shutdown"}"#).1.code, codes::UNKNOWN_CMD);
        // Out-of-range sampling knobs die at the protocol boundary.
        assert_eq!(
            perr(r#"{"model":"m","prompt":[1],"temperature":-2}"#).1.code,
            codes::BAD_REQUEST
        );
        assert_eq!(perr(r#"{"model":"m","prompt":[1],"top_p":0}"#).1.code, codes::BAD_REQUEST);
    }

    #[test]
    fn unknown_fields_rejected_in_both_versions() {
        for line in [
            r#"{"model":"m","prompt":[1],"max_tokens":5}"#,
            r#"{"v":2,"model":"m","prompt":[1],"max_tokens":5}"#,
            r#"{"cmd":"metrics","extra":1}"#,
            r#"{"v":2,"cmd":"session_open","model":"m","prompt":[1]}"#,
            // `page_size` / `prefix_cache` are response-only capability
            // fields on the `models` reply — never request knobs.
            r#"{"v":2,"cmd":"models","page_size":16}"#,
            r#"{"v":2,"model":"m","prompt":[1],"prefix_cache":true}"#,
        ] {
            let (_, err) = perr(line);
            assert_eq!(err.code, codes::BAD_REQUEST, "{line}");
            assert!(err.message.contains("unknown field"), "{line}: {}", err.message);
        }
    }

    #[test]
    fn version_handling() {
        assert_eq!(p(r#"{"v":1,"cmd":"models"}"#).v, 1);
        assert_eq!(p(r#"{"v":2,"cmd":"models"}"#).v, 2);
        // Unsupported or mistyped versions are rejected, answered in v1.
        let (v, err) = perr(r#"{"v":3,"cmd":"models"}"#);
        assert_eq!((v, err.code), (1, codes::BAD_REQUEST));
        let (v, _) = perr(r#"{"v":"2","cmd":"models"}"#);
        assert_eq!(v, 1);
    }

    #[test]
    fn error_json_shapes_by_version() {
        let err = ProtoError::new(codes::UNKNOWN_MODEL, "unknown model x");
        let v1 = error_json(1, &err);
        assert_eq!(v1.get("error").and_then(Json::as_str), Some("unknown model x"));
        let v2 = error_json(2, &err);
        assert_eq!(v2.get("v").and_then(Json::as_f64), Some(2.0));
        let e = v2.get("error").expect("structured error");
        assert_eq!(e.get("code").and_then(Json::as_str), Some("unknown_model"));
        assert_eq!(e.get("message").and_then(Json::as_str), Some("unknown model x"));
    }
}
