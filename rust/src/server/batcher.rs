//! Request queue: collect concurrent requests for the serving workers.
//!
//! Two consumption styles share one thread-safe queue:
//!
//! * [`Batcher::next_batch`] — fixed batches: dispatch when `max_batch`
//!   requests are queued OR the oldest queued request has waited
//!   `max_wait`; never dispatch empty. Small decode batches are the
//!   paper's serving regime (§4 Speedup).
//! * [`Batcher::try_take`] / [`Batcher::wait_pending`] — continuous
//!   admission: the scheduler (`server::scheduler`) drains whatever is
//!   queued up to its free cache slots between decode steps, and parks on
//!   the condvar (untimed — submit/close notify it, so an idle server
//!   does not wake on a poll interval) only when nothing is in flight.

use super::engine::{GenRequest, GenResult};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A queued request plus its submit-time metadata, handed to consumers.
pub struct Pending {
    pub req: GenRequest,
    /// When the request entered the queue (for TTFT / latency metrics).
    pub enqueued: Instant,
    /// Where the finished [`GenResult`] goes.
    pub result_slot: std::sync::mpsc::Sender<GenResult>,
}

/// Thread-safe request queue with batch-forming semantics.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    closed: Mutex<bool>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            closed: Mutex::new(false),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Submit a request; returns a receiver for its result.
    pub fn submit(&self, req: GenRequest) -> std::sync::mpsc::Receiver<GenResult> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.queue.lock().unwrap();
            q.push_back(Pending { req, enqueued: Instant::now(), result_slot: tx });
        }
        self.notify.notify_all();
        rx
    }

    /// Stop the batcher; pending `next_batch`/`wait_pending` calls return
    /// None/false once the queue drains.
    ///
    /// Holds the queue lock while flipping the flag and notifying: a
    /// consumer that just read `closed == false` under the queue lock is
    /// either still holding it (we block until it parks in `wait`, which
    /// releases the lock atomically — then our notify reaches it) or will
    /// re-check and see `true`. Without this, close() could slip between a
    /// consumer's check and its untimed park, leaving it asleep forever
    /// (the old 50 ms poll masked that window).
    pub fn close(&self) {
        let _queue_held = self.queue.lock().unwrap();
        *self.closed.lock().unwrap() = true;
        self.notify.notify_all();
    }

    /// Queue depth (for metrics).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Pop up to `max` queued requests without blocking (continuous
    /// admission between decode steps).
    pub fn try_take(&self, max: usize) -> Vec<Pending> {
        let mut q = self.queue.lock().unwrap();
        let take = q.len().min(max);
        q.drain(..take).collect()
    }

    /// Block until the queue is non-empty (true) or the batcher is closed
    /// with nothing left to serve (false). Untimed condvar park: an idle
    /// consumer wakes only on submit/close.
    pub fn wait_pending(&self) -> bool {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.is_empty() {
                return true;
            }
            if *self.closed.lock().unwrap() {
                return false;
            }
            q = self.notify.wait(q).unwrap();
        }
    }

    /// Block until a batch is ready (policy-driven) or closed.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if *self.closed.lock().unwrap() && q.is_empty() {
                return None;
            }
            if !q.is_empty() {
                let oldest_wait = q.front().unwrap().enqueued.elapsed();
                if q.len() >= self.policy.max_batch || oldest_wait >= self.policy.max_wait {
                    let take = q.len().min(self.policy.max_batch);
                    return Some(q.drain(..take).collect());
                }
                // Wait out the remaining deadline of the oldest request.
                let remaining = self.policy.max_wait - oldest_wait;
                let (guard, _) = self.notify.wait_timeout(q, remaining).unwrap();
                q = guard;
            } else {
                // Idle: park untimed — submit/close notify the condvar, so
                // an empty queue no longer wakes on a 50 ms poll loop.
                q = self.notify.wait(q).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> GenRequest {
        GenRequest { id, prompt: vec![1], max_new: 1, stop: None }
    }

    #[test]
    fn batches_fill_to_max() {
        let b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(5) });
        for i in 0..3 {
            let _rx = b.submit(req(i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) });
        let _rx = b.submit(req(7));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_unblocks() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn try_take_is_nonblocking_and_bounded() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.try_take(4).is_empty());
        let mut rxs = Vec::new();
        for i in 0..3 {
            rxs.push(b.submit(req(i)));
        }
        assert!(b.wait_pending());
        let first = b.try_take(2);
        assert_eq!(first.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![0, 1]);
        let rest = b.try_take(4);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].req.id, 2);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn wait_pending_unblocks_on_close() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.wait_pending());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(!h.join().unwrap());
        // Closed but non-empty still reports pending work (drain first).
        let b3 = Batcher::new(BatchPolicy::default());
        let _rx = b3.submit(req(1));
        b3.close();
        assert!(b3.wait_pending());
        let _ = b3.try_take(1);
        assert!(!b3.wait_pending());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }));
        let n = 40;
        let mut rxs = Vec::new();
        for i in 0..n {
            rxs.push(b.submit(req(i)));
        }
        let b2 = b.clone();
        let worker = std::thread::spawn(move || {
            let mut served = 0;
            while served < n {
                if let Some(batch) = b2.next_batch() {
                    for p in batch {
                        let _ = p.result_slot.send(GenResult { id: p.req.id, tokens: vec![] });
                        served += 1;
                    }
                } else {
                    break;
                }
            }
        });
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(5)).unwrap().id)
            .collect();
        worker.join().unwrap();
        ids.sort();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
    }
}
