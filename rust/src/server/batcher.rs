//! Dynamic batcher: collect concurrent requests into decode batches.
//!
//! Policy: dispatch when `max_batch` requests are queued OR the oldest
//! queued request has waited `max_wait`; never dispatch empty. Small decode
//! batches are the paper's serving regime (§4 Speedup).

use super::engine::{GenRequest, GenResult};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

struct Queued {
    req: GenRequest,
    enqueued: Instant,
    result_slot: std::sync::mpsc::Sender<GenResult>,
}

/// Thread-safe request queue with batch-forming semantics.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Mutex<VecDeque<Queued>>,
    notify: Condvar,
    closed: Mutex<bool>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            closed: Mutex::new(false),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Submit a request; returns a receiver for its result.
    pub fn submit(&self, req: GenRequest) -> std::sync::mpsc::Receiver<GenResult> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.queue.lock().unwrap();
            q.push_back(Queued { req, enqueued: Instant::now(), result_slot: tx });
        }
        self.notify.notify_all();
        rx
    }

    /// Stop the batcher; pending `next_batch` calls return None.
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.notify.notify_all();
    }

    /// Queue depth (for metrics).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Block until a batch is ready (policy-driven) or closed.
    /// Returns the requests plus their result senders.
    #[allow(clippy::type_complexity)]
    pub fn next_batch(
        &self,
    ) -> Option<(Vec<GenRequest>, Vec<std::sync::mpsc::Sender<GenResult>>)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if *self.closed.lock().unwrap() && q.is_empty() {
                return None;
            }
            if !q.is_empty() {
                let oldest_wait = q.front().unwrap().enqueued.elapsed();
                if q.len() >= self.policy.max_batch || oldest_wait >= self.policy.max_wait {
                    let take = q.len().min(self.policy.max_batch);
                    let mut reqs = Vec::with_capacity(take);
                    let mut slots = Vec::with_capacity(take);
                    for _ in 0..take {
                        let item = q.pop_front().unwrap();
                        reqs.push(item.req);
                        slots.push(item.result_slot);
                    }
                    return Some((reqs, slots));
                }
                // Wait out the remaining deadline of the oldest request.
                let remaining = self.policy.max_wait - oldest_wait;
                let (guard, _) = self.notify.wait_timeout(q, remaining).unwrap();
                q = guard;
            } else {
                let (guard, _) = self
                    .notify
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> GenRequest {
        GenRequest { id, prompt: vec![1], max_new: 1 }
    }

    #[test]
    fn batches_fill_to_max() {
        let b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(5) });
        for i in 0..3 {
            let _rx = b.submit(req(i));
        }
        let (reqs, slots) = b.next_batch().unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(slots.len(), 3);
        assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) });
        let _rx = b.submit(req(7));
        let t0 = Instant::now();
        let (reqs, _) = b.next_batch().unwrap();
        assert_eq!(reqs.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_unblocks() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }));
        let n = 40;
        let mut rxs = Vec::new();
        for i in 0..n {
            rxs.push(b.submit(req(i)));
        }
        let b2 = b.clone();
        let worker = std::thread::spawn(move || {
            let mut served = 0;
            while served < n {
                if let Some((reqs, slots)) = b2.next_batch() {
                    for (r, s) in reqs.iter().zip(slots) {
                        let _ = s.send(GenResult { id: r.id, tokens: vec![] });
                        served += 1;
                    }
                } else {
                    break;
                }
            }
        });
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(5)).unwrap().id)
            .collect();
        worker.join().unwrap();
        ids.sort();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
    }
}
